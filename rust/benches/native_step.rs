// Native-backend step cost — the start of the CPU perf trajectory.
//
// Times the pure-Rust train step (im2col + blocked SGEMM forward /
// backward + SGD momentum) on synthetic batches and emits
// `target/bench_results/BENCH_native_step.json` with steps/sec and
// images/sec for alexnet-micro (plus an alexnet-tiny reading in the
// table/CSV), so future optimizations have a baseline to beat.

include!("harness.rs");

use theano_mgpu::backend::{NativeBackend, StepBackend};
use theano_mgpu::params::ParamStore;
use theano_mgpu::sim::flops::{alexnet_micro, alexnet_tiny, ArchDesc};
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::Pcg32;

fn step_median(b: &mut Bench, arch: &ArchDesc, batch: usize, warmup: usize, runs: usize) -> f64 {
    let mut backend = NativeBackend::new(arch, 0.5);
    let model = backend.model().clone();
    let mut store = ParamStore::init(&model.params, 1);
    let mut rng = Pcg32::seeded(9);
    let hw = model.image_hw;
    let images =
        HostTensor::rand_normal(Shape::of(&[batch, model.in_channels, hw, hw]), &mut rng, 1.0);
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(model.num_classes as u32) as i32).collect();
    let mut step = 0i32;
    b.case(&format!("{} b{batch} train step", arch.name), warmup, runs, || {
        backend.train_step(&images, &labels, 0.01, step, &mut store).unwrap();
        step += 1;
    })
}

fn main() {
    let mut b = Bench::new("native_step");

    let micro = alexnet_micro();
    let micro_batch = 8usize;
    let med = step_median(&mut b, &micro, micro_batch, 3, 10);
    let steps_per_sec = 1.0 / med;
    let images_per_sec = micro_batch as f64 / med;
    b.record("alexnet-micro b8 steps/sec", steps_per_sec, "steps/s");
    b.record("alexnet-micro b8 images/sec", images_per_sec, "img/s");

    let tiny = alexnet_tiny();
    let tiny_med = step_median(&mut b, &tiny, 16, 1, 3);
    b.record("alexnet-tiny b16 images/sec", 16.0 / tiny_med, "img/s");

    b.write_csv();

    // Machine-readable perf record (consumed by CI / trend tracking).
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("BENCH_native_step.json");
    let json = format!(
        "{{\"bench\": \"native_step\", \"model\": \"{}\", \"batch\": {micro_batch}, \
         \"median_step_seconds\": {med:.6}, \"steps_per_sec\": {steps_per_sec:.3}, \
         \"images_per_sec\": {images_per_sec:.3}}}\n",
        micro.name
    );
    let _ = std::fs::write(&path, json);
    println!("  -> {}", path.display());
}
