// Native-backend step cost — the CPU perf trajectory, now with the
// intra-op thread sweep.
//
// Times the pure-Rust train step (im2col + blocked SGEMM forward /
// backward + SGD momentum) on synthetic batches for
// `threads ∈ {1, 2, 4, 8}` and emits
// `target/bench_results/BENCH_native_step.json` with per-thread-count
// steps/sec plus speedup-vs-1-thread (the intra-op scaling curve CI
// tracks), alongside the original 1-thread baseline fields so the
// trajectory stays comparable across PRs.

include!("harness.rs");

use theano_mgpu::backend::{NativeBackend, StepBackend};
use theano_mgpu::params::ParamStore;
use theano_mgpu::sim::flops::{alexnet_micro, alexnet_tiny, alexnet_tiny_faithful, ArchDesc};
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::Pcg32;

fn step_median(
    b: &mut Bench,
    arch: &ArchDesc,
    batch: usize,
    threads: usize,
    warmup: usize,
    runs: usize,
) -> f64 {
    let mut backend = NativeBackend::with_threads(arch, 0.5, threads);
    let model = backend.model().clone();
    let mut store = ParamStore::init(&model.params, 1);
    let mut rng = Pcg32::seeded(9);
    let hw = model.image_hw;
    let images =
        HostTensor::rand_normal(Shape::of(&[batch, model.in_channels, hw, hw]), &mut rng, 1.0);
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(model.num_classes as u32) as i32).collect();
    let mut step = 0i32;
    b.case(&format!("{} b{batch} t{threads} train step", arch.name), warmup, runs, || {
        backend.train_step(&images, &labels, 0.01, step, &mut store).unwrap();
        step += 1;
    })
}

fn main() {
    let mut b = Bench::new("native_step");

    // Same model/batch as the PR 2 record so the top-level JSON fields
    // and the label-keyed CSV rows stay comparable across PRs.
    let micro = alexnet_micro();
    let micro_batch = 8usize;
    let threads = [1usize, 2, 4, 8];

    // Thread sweep on alexnet-micro: medians, steps/sec, speedup.
    let mut medians = Vec::new();
    for &t in &threads {
        medians.push(step_median(&mut b, &micro, micro_batch, t, 3, 10));
    }
    let base = medians[0];
    // Trajectory-continuity rows (identical labels to the PR 2 bench):
    // the 1-thread baseline under the original names.
    b.record("alexnet-micro b8 steps/sec", 1.0 / base, "steps/s");
    b.record("alexnet-micro b8 images/sec", micro_batch as f64 / base, "img/s");
    let mut sweep_rows = Vec::new();
    for (&t, &med) in threads.iter().zip(&medians) {
        let steps_per_sec = 1.0 / med;
        let speedup = base / med;
        b.record(
            &format!("alexnet-micro b{micro_batch} t{t} steps/sec"),
            steps_per_sec,
            "steps/s",
        );
        b.record(&format!("alexnet-micro b{micro_batch} t{t} speedup vs t1"), speedup, "x");
        sweep_rows.push(format!(
            "{{\"threads\": {t}, \"median_step_seconds\": {med:.6}, \
             \"steps_per_sec\": {steps_per_sec:.3}, \"images_per_sec\": {:.3}, \
             \"speedup_vs_1\": {speedup:.3}}}",
            micro_batch as f64 / med
        ));
    }

    let tiny = alexnet_tiny();
    let tiny_med = step_median(&mut b, &tiny, 16, 1, 1, 3);
    b.record("alexnet-tiny b16 images/sec", 16.0 / tiny_med, "img/s");

    // The grouped-conv + LRN step cost (tiny geometry, faithful
    // structure): tracks what the per-group GEMM panels and the LRN
    // window pass cost relative to the ungrouped tiny step above.
    let faithful = alexnet_tiny_faithful();
    let faithful_med = step_median(&mut b, &faithful, 16, 1, 1, 3);
    b.record("alexnet-tiny-faithful b16 images/sec", 16.0 / faithful_med, "img/s");

    b.write_csv();

    // Machine-readable perf record (consumed by CI / trend tracking).
    // Top-level fields are the 1-thread baseline for trajectory
    // continuity; `sweep` carries the intra-op scaling curve.
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("BENCH_native_step.json");
    let json = format!(
        "{{\"bench\": \"native_step\", \"model\": \"{}\", \"batch\": {micro_batch}, \
         \"gemm_isa\": \"{}\", \"median_step_seconds\": {base:.6}, \"steps_per_sec\": {:.3}, \
         \"images_per_sec\": {:.3}, \"available_cores\": {}, \"sweep\": [{}], \
         \"grouped_lrn\": {{\"model\": \"{}\", \"batch\": 16, \
         \"median_step_seconds\": {faithful_med:.6}, \"images_per_sec\": {:.3}}}}}\n",
        micro.name,
        theano_mgpu::backend::native::simd::active_isa(),
        1.0 / base,
        micro_batch as f64 / base,
        theano_mgpu::util::available_cores(),
        sweep_rows.join(", "),
        faithful.name,
        16.0 / faithful_med
    );
    let _ = std::fs::write(&path, json);
    println!("  -> {}", path.display());
}
