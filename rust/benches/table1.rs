//! E1 — regenerate the paper's **Table 1** (training time per 20
//! iterations across backends x GPUs x loading mode + Caffe columns).
//!
//! With artifacts present the compute costs are *measured* through the
//! PJRT runtime (real calibration); otherwise canned calibration keeps
//! the bench runnable.  Prints the table in the paper's layout and the
//! derived factor claims next to the paper's own numbers.

include!("harness.rs");

use theano_mgpu::sim::calibrate::{CalibratedCosts, Calibration};
use theano_mgpu::sim::table1::{render, table1, Table1Options, PAPER_BACKENDS};

fn main() {
    let mut b = Bench::new("table1");

    let costs = if artifacts_present() {
        let scratch = std::env::temp_dir().join("tmg_bench_calib");
        match Calibration::measure(std::path::Path::new("artifacts"), &scratch, 5) {
            Ok(c) => {
                println!("  (real calibration)");
                c
            }
            Err(e) => {
                println!("  (calibration failed: {e}; using canned)");
                CalibratedCosts::canned()
            }
        }
    } else {
        println!("  (artifacts missing; canned calibration)");
        CalibratedCosts::canned()
    };
    for (backend, s) in &costs.backend_step_s {
        b.record(&format!("calibrated step [{backend}]"), *s, "s");
    }

    let mut opts = Table1Options::with_costs(costs);
    println!("\n-- measured synthetic-corpus loader --");
    let cells_raw = table1(&opts).unwrap();
    println!("{}", render(&cells_raw));

    // ImageNet-decode-class loading (~2 ms/image, the cost implied by
    // the paper's own serial-vs-parallel delta): the regime where the
    // paper's 19-25% loading saving lives.
    opts.load_ms_override = Some(2.0);
    println!("-- ImageNet-decode-class loader (2 ms/image) --");
    let cells = table1(&opts).unwrap();
    println!("{}", render(&cells));

    let pick = |be: &str, g: usize, p: bool| {
        cells
            .iter()
            .find(|c| c.backend == be && c.gpus == g && c.parallel_loading == p)
            .unwrap()
            .per20_s
    };
    for be in PAPER_BACKENDS {
        b.record(&format!("table1 {be} 2gpu par"), pick(be, 2, true), "s/20it");
        b.record(&format!("table1 {be} 1gpu par"), pick(be, 1, true), "s/20it");
        b.record(&format!("table1 {be} 2gpu ser"), pick(be, 2, false), "s/20it");
        b.record(&format!("table1 {be} 1gpu ser"), pick(be, 1, false), "s/20it");
        b.record(
            &format!("factor {be} 2gpu-speedup (paper ~1.66-1.70x)"),
            pick(be, 1, true) / pick(be, 2, true),
            "x",
        );
        b.record(
            &format!("factor {be} loading-saving 1gpu (paper ~19-25%)"),
            100.0 * (1.0 - pick(be, 1, true) / pick(be, 1, false)),
            "%",
        );
    }
    b.record("table1 caffe", pick("caffe", 1, true), "s/20it");
    b.record("table1 caffe_cudnn", pick("caffe_cudnn", 1, true), "s/20it");
    b.record(
        "factor best-vs-caffe_cudnn (paper 19.72/20.25=0.97)",
        pick("cudnn_r2", 2, true) / pick("caffe_cudnn", 1, true),
        "x",
    );
    b.write_csv();
}
