// Serving latency + saturation — the `tmg serve` perf record.
//
// Boots an in-process dynamic-batching server (2 replicas, native
// backend, 1 compute thread each) over a synthetic micro corpus, then:
//
//  1. closed loop: 8 connections firing as fast as answers come back —
//     best-case p50/p99 latency and peak throughput;
//  2. open loop: a fixed-arrival-rate sweep, doubling the offered rate
//     until the server falls behind (achieved < 90% of offered) —
//     `saturation_rps` is the last rate it kept up with.  Latency is
//     measured from the *scheduled* send time, so backlog shows up in
//     the percentiles (no coordinated omission).
//
// Emits target/bench_results/BENCH_serve.json with p50/p99,
// throughput, the sweep table, and the saturation point.

include!("harness.rs");

use std::sync::Arc;
use std::time::Duration;

use theano_mgpu::config::TrainConfig;
use theano_mgpu::params::ParamStore;
use theano_mgpu::serve::loadgen::{run_closed_loop, run_open_loop};
use theano_mgpu::serve::{ServeOpts, Server};

const REPLICAS: usize = 2;
const MAX_BATCH: usize = 8;
const DEADLINE_MS: f64 = 2.0;
const REQUESTS: u64 = 512;
const CONCURRENCY: usize = 8;

fn bench_corpus() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("target/bench_data/serve_micro");
    if !dir.join("meta.json").exists() {
        let spec = theano_mgpu::data::synth::SynthSpec {
            classes: 10,
            channels: 3,
            hw: 36,
            noise: 24.0,
            seed: 7,
        };
        theano_mgpu::data::synth::generate_dataset(&dir, &spec, 64, 16, 64).unwrap();
    }
    dir
}

fn main() {
    let mut b = Bench::new("serve_latency");

    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.compute_threads = 1;
    cfg.data.dir = bench_corpus();
    cfg.data.stored_hw = 36;

    // Latency doesn't care whether the weights are trained; a fresh
    // init serves identically-shaped work.
    let model = theano_mgpu::backend::resolve_model(&cfg).unwrap();
    let store = Arc::new(ParamStore::init(&model.params, 1));
    let opts = ServeOpts {
        replicas: REPLICAS,
        max_batch: MAX_BATCH,
        deadline: Duration::from_secs_f64(DEADLINE_MS / 1e3),
        topk: 5,
        port: 0,
        ..ServeOpts::default()
    };
    let server = Server::start(&cfg, store, opts).unwrap();
    let addr = server.addr().to_string();

    // --- closed loop ---
    let report = run_closed_loop(&addr, REQUESTS, CONCURRENCY, 42).unwrap();
    assert_eq!(report.errors, 0, "closed loop saw errors");
    b.record("closed-loop p50 latency", report.p50_ms, "ms");
    b.record("closed-loop p99 latency", report.p99_ms, "ms");
    b.record("closed-loop throughput", report.throughput_rps, "req/s");

    // --- open-loop saturation sweep ---
    let mut sweep_rows = Vec::new();
    let mut saturation_rps = 0.0f64;
    for rate in [100.0f64, 200.0, 400.0, 800.0, 1600.0] {
        let p = run_open_loop(&addr, rate, Duration::from_millis(1500), CONCURRENCY, 7).unwrap();
        b.record(&format!("open-loop @{rate:.0}rps achieved"), p.achieved_rps, "req/s");
        b.record(&format!("open-loop @{rate:.0}rps p99"), p.p99_ms, "ms");
        sweep_rows.push(format!(
            "{{\"offered_rps\": {:.1}, \"achieved_rps\": {:.1}, \"ok\": {}, \
             \"errors\": {}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}}}",
            p.offered_rps, p.achieved_rps, p.ok, p.errors, p.p50_ms, p.p99_ms
        ));
        let kept_up = p.achieved_rps >= 0.9 * rate && p.errors == 0;
        if kept_up {
            saturation_rps = rate;
        } else {
            // Saturated: offering more only grows the backlog.
            break;
        }
    }
    b.record("saturation rate", saturation_rps, "req/s");

    let snap = server.shutdown();
    b.record("server-side mean batch fill", snap.mean_fill, "req");
    b.record("server-side compute p50", snap.compute_p50_ms, "ms");
    b.write_csv();

    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("BENCH_serve.json");
    let json = format!(
        "{{\"bench\": \"serve_latency\", \"model\": \"{}\", \"replicas\": {REPLICAS}, \
         \"max_batch\": {MAX_BATCH}, \"deadline_ms\": {DEADLINE_MS}, \
         \"requests\": {REQUESTS}, \"concurrency\": {CONCURRENCY}, \
         \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"throughput_rps\": {:.1}, \
         \"server_mean_fill\": {:.2}, \"server_queue_p99_ms\": {:.3}, \
         \"server_compute_p99_ms\": {:.3}, \"saturation_rps\": {saturation_rps:.1}, \
         \"sweep\": [{}]}}\n",
        cfg.model,
        report.p50_ms,
        report.p99_ms,
        report.throughput_rps,
        snap.mean_fill,
        snap.queue_p99_ms,
        snap.compute_p99_ms,
        sweep_rows.join(", ")
    );
    let _ = std::fs::write(&path, json);
    println!("  -> {}", path.display());
}
