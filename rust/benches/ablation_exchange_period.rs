//! E6 — ablation: exchange period k and transport choice.
//!
//! The paper exchanges every step; this ablation shows the tradeoff it
//! bought: larger k amortizes the exchange cost (simulated at AlexNet
//! scale) but lets the replicas drift (measured, real micro-model
//! training on the native CPU backend).

include!("harness.rs");

use theano_mgpu::config::{ClusterConfig, DataConfig, TrainConfig, TransportKind};
use theano_mgpu::coordinator::trainer::train;
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::sim::pipeline::{simulate, PipelineParams};

fn main() {
    let mut b = Bench::new("ablation_exchange_period");

    // --- Simulated time saving at AlexNet scale ---
    for period in [1usize, 2, 4, 8] {
        let p = PipelineParams {
            workers: 2,
            compute_s: 1.0,
            load_s: 0.25,
            exchange_s: 0.25,
            period,
            parallel_loading: true,
            jitter: 0.0,
            seed: 6,
        };
        b.record(
            &format!("sim s/20it @period={period}"),
            simulate(&p, 200).mean_per20(),
            "s",
        );
    }

    // --- Real replica drift on the micro model (native backend) ---
    {
        let dir = std::env::temp_dir().join("tmg_bench_ablation");
        if !dir.join("meta.json").exists() {
            let spec = SynthSpec { classes: 10, hw: 36, seed: 11, ..Default::default() };
            generate_dataset(&dir, &spec, 640, 64, 320).unwrap();
        }
        for period in [1usize, 2, 4] {
            let mut cfg = TrainConfig::default();
            cfg.model = "alexnet-micro".into();
            cfg.backend = "native".into();
            cfg.batch_per_worker = 8;
            // 9 steps: not a multiple of any period > 1, so the final
            // state shows genuine inter-exchange drift.
            cfg.steps = 9;
            cfg.log_every = 0;
            cfg.schedule.base_lr = 0.02;
            cfg.exchange.period = period;
            cfg.cluster = ClusterConfig::pair_same_switch();
            cfg.data = DataConfig {
                dir: dir.clone(),
                train_examples: 640,
                val_examples: 64,
                shard_examples: 320,
                seed: 11,
                stored_hw: 36,
            };
            let s = train(&cfg).unwrap();
            b.record(
                &format!("real divergence @period={period}"),
                f64::from(s.final_divergence.unwrap_or(0.0)),
                "max|dw|",
            );
            b.record(
                &format!("real final loss @period={period}"),
                *s.losses.last().unwrap() as f64,
                "",
            );
        }
    }

    // --- Transport ablation at fixed period (simulated AlexNet) ---
    use theano_mgpu::comm::cost::CommCostModel;
    use theano_mgpu::sim::flops::alexnet;
    let model = CommCostModel::default();
    let bytes = alexnet().exchange_bytes() as usize;
    for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
        let p = PipelineParams {
            workers: 2,
            compute_s: 1.0,
            load_s: 0.25,
            exchange_s: model.exchange_round_time(kind, bytes),
            period: 1,
            parallel_loading: true,
            jitter: 0.0,
            seed: 6,
        };
        b.record(
            &format!("sim s/20it transport={}", kind.name()),
            simulate(&p, 200).mean_per20(),
            "s",
        );
    }
    b.write_csv();
}
