// Shared bench harness (no criterion in the offline crate set).
//
// Each bench binary `include!`s this file and uses [`Bench`] to time
// named cases with warmup + median-of-runs, printing a uniform table
// and optionally appending CSV rows under `target/bench_results/`.

use std::path::PathBuf;

use theano_mgpu::util::timer::{measure_runs, median};

pub struct Bench {
    name: &'static str,
    rows: Vec<(String, f64, String)>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("== bench: {name} ==");
        Bench { name, rows: Vec::new() }
    }

    /// Time `f` with `warmup` + `runs`, record the median under `label`.
    pub fn case(&mut self, label: &str, warmup: usize, runs: usize, mut f: impl FnMut()) -> f64 {
        let times = measure_runs(warmup, runs, &mut f);
        let med = median(&times);
        println!(
            "  {label:<44} {:>12}  (min {:>10}, n={runs})",
            theano_mgpu::util::fmt::secs(med),
            theano_mgpu::util::fmt::secs(times[0]),
        );
        self.rows.push((label.to_string(), med, String::new()));
        med
    }

    /// Record a pre-computed metric (e.g. a simulated table cell).
    pub fn record(&mut self, label: &str, value: f64, unit: &str) {
        println!("  {label:<44} {value:>12.4} {unit}");
        self.rows.push((label.to_string(), value, unit.to_string()));
    }

    /// Append results to target/bench_results/<name>.csv.
    pub fn write_csv(&self) {
        let dir = PathBuf::from("target/bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{}.csv", self.name));
        let mut body = String::from("label,value,unit\n");
        for (label, v, unit) in &self.rows {
            body.push_str(&format!("{label},{v},{unit}\n"));
        }
        let _ = std::fs::write(&path, body);
        println!("  -> {}", path.display());
    }
}

/// True when the AOT artifacts are present (some benches need them).
pub fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}
