// Shared bench harness (no criterion in the offline crate set).
//
// Each bench binary `include!`s this file and uses [`Bench`] to time
// named cases with warmup + median-of-runs, printing a uniform table
// and optionally appending CSV rows under `target/bench_results/`.

use std::path::PathBuf;

use theano_mgpu::util::timer::{measure_runs, median};

pub struct Bench {
    name: &'static str,
    rows: Vec<(String, f64, String)>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("== bench: {name} ==");
        Bench { name, rows: Vec::new() }
    }

    /// Time `f` with `warmup` + `runs`, record the median under `label`.
    pub fn case(&mut self, label: &str, warmup: usize, runs: usize, mut f: impl FnMut()) -> f64 {
        let times = measure_runs(warmup, runs, &mut f);
        let med = median(&times);
        println!(
            "  {label:<44} {:>12}  (min {:>10}, n={runs})",
            theano_mgpu::util::fmt::secs(med),
            theano_mgpu::util::fmt::secs(times[0]),
        );
        self.rows.push((label.to_string(), med, String::new()));
        med
    }

    /// Record a pre-computed metric (e.g. a simulated table cell).
    pub fn record(&mut self, label: &str, value: f64, unit: &str) {
        println!("  {label:<44} {value:>12.4} {unit}");
        self.rows.push((label.to_string(), value, unit.to_string()));
    }

    /// Append results to target/bench_results/<name>.csv.
    pub fn write_csv(&self) {
        let dir = PathBuf::from("target/bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{}.csv", self.name));
        let mut body = String::from("label,value,unit\n");
        for (label, v, unit) in &self.rows {
            body.push_str(&format!("{label},{v},{unit}\n"));
        }
        let _ = std::fs::write(&path, body);
        println!("  -> {}", path.display());
    }
}

/// True when the AOT artifacts are present (some benches need them).
#[allow(dead_code)]
pub fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Single-tensor store of `elements` f32 params (+ zero momenta) —
/// the standard payload for comm-layer benches.
#[allow(dead_code)]
pub fn bench_store(elements: usize, seed: u64) -> theano_mgpu::params::ParamStore {
    let specs = vec![theano_mgpu::runtime::artifact::ParamManifestSpec {
        name: "w".into(),
        shape: theano_mgpu::tensor::Shape::of(&[elements]),
        init: "normal".into(),
        std: 0.1,
        bias_value: 0.0,
    }];
    theano_mgpu::params::ParamStore::init(&specs, seed)
}

/// Run `rounds` ring all-reduce rounds across `n` threads over `kind`
/// links and return the per-round per-phase stats averaged over ranks
/// (the shared measurement core of the E4/E5 collective benches).
#[allow(dead_code)]
pub fn measure_ring(
    n: usize,
    kind: theano_mgpu::config::TransportKind,
    elements: usize,
    rounds: usize,
) -> theano_mgpu::comm::CollectiveStats {
    use theano_mgpu::comm::collective::{ring_fabric, Collective};
    let joins: Vec<_> = ring_fabric(&vec![kind; n])
        .into_iter()
        .map(|mut node| {
            std::thread::spawn(move || {
                let mut store = bench_store(elements, node.rank as u64 + 1);
                for _ in 0..rounds {
                    node.all_reduce_average(&mut store, true).unwrap();
                }
                node.stats()
            })
        })
        .collect();
    let stats: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let scale = (stats.len() * rounds) as f64;
    let mut out = theano_mgpu::comm::CollectiveStats {
        rounds: rounds as u64,
        bytes_per_round: stats[0].bytes_per_round,
        ..Default::default()
    };
    for s in &stats {
        out.flatten_seconds += s.flatten_seconds / scale;
        out.transfer_seconds += s.transfer_seconds / scale;
        out.average_seconds += s.average_seconds / scale;
    }
    out
}
