//! E4 — **Fig 2** behaviour: exchange-and-average latency.
//!
//! Measures real exchange rounds between two threads across the three
//! transports and a sweep of payload sizes (up to AlexNet-scale), and
//! prints the cost-model predictions for the same points.  The paper's
//! §4.3 claim under test: P2P < host-staged < serialized, with the
//! serialized (multiprocessing) path paying an encode/decode tax.
//!
//! Also measures the N-worker collective (chunked ring all-reduce over
//! the same transports) for N in {2, 3, 4}, reporting the per-phase
//! flatten/transfer/average breakdown the 2-GPU table reports.

include!("harness.rs");

use theano_mgpu::comm::cost::CommCostModel;
use theano_mgpu::comm::exchange::ExchangePort;
use theano_mgpu::comm::link::transport_pair;
use theano_mgpu::config::TransportKind;

/// One timed round: both sides exchange; returns port for stats.
fn run_rounds(kind: TransportKind, elements: usize, rounds: usize) -> (f64, f64) {
    let (ea, eb) = transport_pair(kind);
    let mut sa = bench_store(elements, 1);
    let mut sb = bench_store(elements, 2);
    let h = std::thread::spawn(move || {
        let mut port = ExchangePort::new(eb);
        for _ in 0..rounds {
            port.exchange(&mut sb, true).unwrap();
        }
    });
    let mut port = ExchangePort::new(ea);
    let t = theano_mgpu::util::Timer::start();
    for _ in 0..rounds {
        port.exchange(&mut sa, true).unwrap();
    }
    let total = t.elapsed_secs();
    h.join().unwrap();
    (total / rounds as f64, port.stats.average_seconds / rounds as f64)
}

fn main() {
    let mut b = Bench::new("fig2_exchange");
    let model = CommCostModel::default();

    // Payload sweep: 256 KiB .. 64 MiB of params(+momenta flattened x2).
    for &elements in &[32_768usize, 262_144, 2_097_152, 8_388_608] {
        let bytes = elements * 2 * 4; // params + momenta
        for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
            let rounds = if elements > 1_000_000 { 3 } else { 10 };
            let (per_round, avg_s) = run_rounds(kind, elements, rounds);
            b.record(
                &format!("real {} {:>8} KiB/round", kind.name(), bytes / 1024),
                per_round,
                "s",
            );
            let _ = avg_s;
            b.record(
                &format!("model {} {:>7} KiB/round", kind.name(), bytes / 1024),
                model.exchange_round_time(kind, bytes),
                "s",
            );
        }
    }

    // Ordering check at AlexNet-class payload.
    let (p2p, _) = run_rounds(TransportKind::P2p, 8_388_608, 3);
    let (host, _) = run_rounds(TransportKind::HostStaged, 8_388_608, 3);
    let (ser, _) = run_rounds(TransportKind::Serialized, 8_388_608, 3);
    b.record("ordering host/p2p (>1 expected)", host / p2p, "x");
    b.record("ordering serialized/p2p (>1 expected, §4.3)", ser / p2p, "x");

    // --- N-worker ring collective: per-phase stats for any N ---
    let elements = 2_097_152usize; // 16 MiB params(+momenta) per replica
    for &n in &[2usize, 3, 4] {
        for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
            let phases = measure_ring(n, kind, elements, 5);
            b.record(
                &format!("ring n={n} {} flatten/round", kind.name()),
                phases.flatten_seconds,
                "s",
            );
            b.record(
                &format!("ring n={n} {} transfer/round", kind.name()),
                phases.transfer_seconds,
                "s",
            );
            b.record(
                &format!("ring n={n} {} average/round", kind.name()),
                phases.average_seconds,
                "s",
            );
        }
    }
    b.write_csv();
}
