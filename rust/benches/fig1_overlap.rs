//! E3 — **Fig 1** behaviour: the parallel-loading pipeline.
//!
//! Two measurements:
//! 1. *Real*: SerialLoader vs ParallelLoader over a generated shard set
//!    with a synthetic compute phase, reporting per-batch wall time and
//!    trainer stall — the actual double-buffer implementation.
//! 2. *Simulated*: overlap-efficiency sweep across load/compute ratios
//!    (the regime map the paper's Fig-1 design targets).

include!("harness.rs");

use theano_mgpu::data::loader::{BatchSource, LoaderCfg, ParallelLoader, SerialLoader};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::sim::pipeline::{simulate, PipelineParams};

fn main() {
    let mut b = Bench::new("fig1_overlap");

    // --- Real pipeline ---
    let dir = std::env::temp_dir().join("tmg_bench_fig1");
    if !dir.join("meta.json").exists() {
        let spec = SynthSpec { classes: 16, hw: 72, seed: 4, ..Default::default() };
        generate_dataset(&dir, &spec, 2048, 128, 512).unwrap();
    }
    let cfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 64,
        crop_hw: 64,
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: true,
        verify_shards: false,
    };
    let compute = std::time::Duration::from_millis(8);

    let mut serial = SerialLoader::new(&cfg).unwrap();
    let t_serial = b.case("real serial: load+compute per step", 2, 12, || {
        let _ = serial.next_batch().unwrap();
        std::thread::sleep(compute);
    });

    let mut parallel = ParallelLoader::new(&cfg).unwrap();
    let t_par = b.case("real parallel: max(load,compute) per step", 2, 12, || {
        let _ = parallel.next_batch().unwrap();
        std::thread::sleep(compute);
    });
    let st = parallel.stats();
    b.record("real parallel: producer load/batch", st.load_seconds / st.batches as f64, "s");
    b.record("real parallel: trainer stall/batch", st.stall_seconds / st.batches as f64, "s");
    b.record("real loading saving (paper ~19-25%)", 100.0 * (1.0 - t_par / t_serial), "%");

    // --- Simulated regime sweep ---
    for ratio in [0.1, 0.25, 0.5, 0.75, 1.0, 1.5] {
        let base = PipelineParams {
            workers: 1,
            compute_s: 1.0,
            load_s: ratio,
            exchange_s: 0.0,
            period: 1,
            parallel_loading: true,
            jitter: 0.0,
            seed: 3,
        };
        let par = simulate(&base, 200);
        let ser = simulate(&PipelineParams { parallel_loading: false, ..base }, 200);
        b.record(
            &format!("sim saving @load/compute={ratio}"),
            100.0 * (1.0 - par.mean_per20() / ser.mean_per20()),
            "%",
        );
        b.record(
            &format!("sim overlap efficiency @{ratio}"),
            par.overlap_efficiency,
            "",
        );
    }
    b.write_csv();
}
