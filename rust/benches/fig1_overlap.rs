//! E3 — **Fig 1** behaviour: overlap of hideable work with compute.
//!
//! Three measurements:
//! 1. *Real loading*: SerialLoader vs ParallelLoader over a generated
//!    shard set with a synthetic compute phase, reporting per-batch
//!    wall time and trainer stall — the actual double-buffer
//!    implementation.
//! 2. *Real exchange*: streamed bucketed gradient exchange
//!    (`--overlap`) vs the same bucketed exchange run
//!    compute-then-exchange (`--overlap serial`) on real alexnet-micro
//!    training at N in {2, 4}.  Emits `BENCH_overlap.json` with the
//!    exposed-comm headline.
//! 3. *Simulated*: overlap-efficiency sweep across load/compute ratios
//!    (the regime map the paper's Fig-1 design targets).

include!("harness.rs");

use std::path::Path;

use theano_mgpu::config::{ClusterConfig, DataConfig, OverlapMode, TrainConfig};
use theano_mgpu::coordinator::trainer::{train, TrainSummary};
use theano_mgpu::data::loader::{BatchSource, LoaderCfg, ParallelLoader, SerialLoader};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::sim::pipeline::{simulate, PipelineParams};

/// Dataset cache keyed by the full generation recipe.  The old scheme
/// reused one fixed temp dir whenever `meta.json` existed, so editing
/// the spec here silently benchmarked stale data; encoding the spec
/// fingerprint in the directory name makes a spec change a cache miss.
fn cached_dataset(base: &str, spec: &SynthSpec, train: usize, val: usize, shard: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "{base}_c{}ch{}hw{}n{}s{}_{train}x{val}x{shard}",
        spec.classes, spec.channels, spec.hw, spec.noise, spec.seed
    ));
    if !dir.join("meta.json").exists() {
        generate_dataset(&dir, spec, train, val, shard).unwrap();
    }
    dir
}

/// Real 2-/4-worker alexnet-micro training with bucketed gradient
/// exchange, streamed or compute-then-exchange.
fn overlap_cfg(data_dir: &Path, workers: usize, mode: OverlapMode, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.name = format!("bench-overlap-{workers}");
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.dropout = 0.0;
    cfg.batch_per_worker = 8;
    cfg.steps = steps;
    cfg.log_every = 0;
    cfg.seed = 11;
    cfg.compute_threads = 1;
    cfg.cluster = ClusterConfig { workers, switch_of_worker: vec![0; workers] };
    cfg.exchange.period = 1;
    cfg.exchange.overlap = mode;
    // Smaller buckets than the training default: more buckets in
    // flight means a finer-grained picture of what streaming hides.
    cfg.exchange.bucket_elems = 8192;
    cfg.data = DataConfig {
        dir: data_dir.to_path_buf(),
        train_examples: 640,
        val_examples: 0,
        shard_examples: 320,
        seed: 42,
        stored_hw: 36,
    };
    cfg
}

fn run_overlap(data_dir: &Path, workers: usize, mode: OverlapMode, steps: usize) -> TrainSummary {
    train(&overlap_cfg(data_dir, workers, mode, steps)).unwrap()
}

fn main() {
    let mut b = Bench::new("fig1_overlap");

    // --- Real pipeline ---
    let spec = SynthSpec { classes: 16, hw: 72, seed: 4, ..Default::default() };
    let dir = cached_dataset("tmg_bench_fig1", &spec, 2048, 128, 512);
    let cfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 64,
        crop_hw: 64,
        worker: 0,
        workers: 1,
        seed: 1,
        train_augment: true,
        verify_shards: false,
    };
    let compute = std::time::Duration::from_millis(8);

    let mut serial = SerialLoader::new(&cfg).unwrap();
    let t_serial = b.case("real serial: load+compute per step", 2, 12, || {
        let _ = serial.next_batch().unwrap();
        std::thread::sleep(compute);
    });

    let mut parallel = ParallelLoader::new(&cfg).unwrap();
    let t_par = b.case("real parallel: max(load,compute) per step", 2, 12, || {
        let _ = parallel.next_batch().unwrap();
        std::thread::sleep(compute);
    });
    let st = parallel.stats();
    b.record("real parallel: producer load/batch", st.load_seconds / st.batches as f64, "s");
    b.record("real parallel: trainer stall/batch", st.stall_seconds / st.batches as f64, "s");
    b.record("real loading saving (paper ~19-25%)", 100.0 * (1.0 - t_par / t_serial), "%");

    // --- Real exchange overlap: streamed vs compute-then-exchange ---
    let train_spec = SynthSpec { classes: 10, hw: 36, seed: 42, ..Default::default() };
    let train_dir = cached_dataset("tmg_bench_overlap", &train_spec, 640, 64, 320);
    let steps = 10;
    let mut json = String::from("{\n  \"bench\": \"fig1_overlap\",\n");
    for workers in [2usize, 4] {
        let ser = run_overlap(&train_dir, workers, OverlapMode::Serial, steps);
        let stm = run_overlap(&train_dir, workers, OverlapMode::Stream, steps);
        let ser_step = ser.wall_seconds / steps as f64;
        let stm_step = stm.wall_seconds / steps as f64;
        let total = stm.collective.overlapped_seconds + stm.collective.exposed_seconds;
        let efficiency = if total > 0.0 { stm.collective.overlapped_seconds / total } else { 0.0 };
        b.record(
            &format!("N={workers} serial exchange exposed"),
            ser.collective.exposed_seconds,
            "s",
        );
        b.record(
            &format!("N={workers} stream exchange exposed"),
            stm.collective.exposed_seconds,
            "s",
        );
        b.record(
            &format!("N={workers} stream exchange overlapped"),
            stm.collective.overlapped_seconds,
            "s",
        );
        b.record(&format!("N={workers} overlap efficiency"), efficiency, "");
        b.record(&format!("N={workers} serial step time"), ser_step, "s");
        b.record(&format!("N={workers} stream step time"), stm_step, "s");
        json.push_str(&format!(
            "  \"world_{workers}\": {{\n    \"steps\": {steps},\n    \
             \"serial_exposed_comm_s\": {:.6},\n    \
             \"stream_exposed_comm_s\": {:.6},\n    \
             \"stream_overlapped_comm_s\": {:.6},\n    \
             \"overlap_efficiency\": {:.4},\n    \
             \"serial_step_s\": {:.6},\n    \
             \"stream_step_s\": {:.6}\n  }},\n",
            ser.collective.exposed_seconds,
            stm.collective.exposed_seconds,
            stm.collective.overlapped_seconds,
            efficiency,
            ser_step,
            stm_step,
        ));
    }
    json.push_str("  \"headline\": \"stream_exposed_comm_s vs serial_exposed_comm_s: \
                   comm seconds left on the critical path with and without overlap\"\n}\n");
    let out = PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&out);
    let json_path = out.join("BENCH_overlap.json");
    std::fs::write(&json_path, json).unwrap();
    println!("  -> {}", json_path.display());

    // --- Simulated regime sweep ---
    for ratio in [0.1, 0.25, 0.5, 0.75, 1.0, 1.5] {
        let base = PipelineParams {
            workers: 1,
            compute_s: 1.0,
            load_s: ratio,
            exchange_s: 0.0,
            period: 1,
            parallel_loading: true,
            jitter: 0.0,
            seed: 3,
        };
        let par = simulate(&base, 200);
        let ser = simulate(&PipelineParams { parallel_loading: false, ..base }, 200);
        b.record(
            &format!("sim saving @load/compute={ratio}"),
            100.0 * (1.0 - par.mean_per20() / ser.mean_per20()),
            "%",
        );
        b.record(
            &format!("sim overlap efficiency @{ratio}"),
            par.overlap_efficiency,
            "",
        );
    }
    b.write_csv();
}
