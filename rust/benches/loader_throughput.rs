//! E7 — data-pipeline stage throughput (the Fig-1 substrate).
//!
//! Breaks the loading path into its stages and reports images/second:
//! synthetic generation (dataset build), shard disk read, preprocess
//! (mean-subtract + crop + flip), and the assembled serial/parallel
//! loaders.

include!("harness.rs");

use theano_mgpu::data::loader::{BatchSource, LoaderCfg, ParallelLoader, SerialLoader};
use theano_mgpu::data::preprocess::{preprocess_into, Augment, MeanImage};
use theano_mgpu::data::shard::ShardedDataset;
use theano_mgpu::data::synth::{generate_dataset, generate_example, SynthSpec};
use theano_mgpu::util::Pcg32;

fn main() {
    let mut b = Bench::new("loader_throughput");
    let dir = std::env::temp_dir().join("tmg_bench_loader");
    let spec = SynthSpec { classes: 16, hw: 72, seed: 21, ..Default::default() };

    // Stage 0: generation (includes shard writing + mean image).
    if !dir.join("meta.json").exists() {
        let t = b.case("generate 1024+128 examples (72px)", 0, 1, || {
            let _ = std::fs::remove_dir_all(&dir);
            generate_dataset(&dir, &spec, 1024, 128, 512).unwrap();
        });
        b.record("generation rate", 1152.0 / t, "img/s");
    }

    // Stage 1: pure example synthesis.
    let t = b.case("synthesize 64 examples (no I/O)", 1, 5, || {
        for i in 0..64u64 {
            std::hint::black_box(generate_example(&spec, (i % 16) as usize, i));
        }
    });
    b.record("synthesis rate", 64.0 / t, "img/s");

    // Stage 2: shard point reads.
    let mut ds = ShardedDataset::open(&dir, "train", true).unwrap();
    let mut buf = Vec::new();
    let mut rng = Pcg32::seeded(3);
    let t = b.case("read 256 random records", 1, 5, || {
        for _ in 0..256 {
            let i = rng.below(1024) as usize;
            ds.read_into(i, &mut buf).unwrap();
        }
    });
    b.record("disk read rate", 256.0 / t, "img/s");

    // Stage 3: preprocessing.
    let mean = MeanImage::load(&dir.join("mean.f32"), 3, 72).unwrap();
    ds.read_into(0, &mut buf).unwrap();
    let mut out = vec![0f32; 3 * 64 * 64];
    let mut prng = Pcg32::seeded(9);
    let t = b.case("preprocess 256 images (72->64 crop+flip)", 1, 5, || {
        for _ in 0..256 {
            let aug = Augment::random(&mut prng, 72, 64);
            preprocess_into(&buf, &mean, 72, 64, aug, &mut out).unwrap();
        }
    });
    b.record("preprocess rate", 256.0 / t, "img/s");

    // Stage 4: assembled loaders.
    let cfg = LoaderCfg {
        data_dir: &dir,
        split: "train",
        batch: 64,
        crop_hw: 64,
        worker: 0,
        workers: 1,
        seed: 5,
        train_augment: true,
        verify_shards: false,
    };
    let mut serial = SerialLoader::new(&cfg).unwrap();
    let t = b.case("serial loader, 4 batches of 64", 1, 5, || {
        for _ in 0..4 {
            std::hint::black_box(serial.next_batch().unwrap());
        }
    });
    b.record("serial loader rate", 256.0 / t, "img/s");

    let mut parallel = ParallelLoader::new(&cfg).unwrap();
    let t = b.case("parallel loader, 4 batches of 64 (consumer)", 1, 5, || {
        for _ in 0..4 {
            std::hint::black_box(parallel.next_batch().unwrap());
        }
    });
    b.record("parallel loader rate (consumer-side)", 256.0 / t, "img/s");
    b.write_csv();
}
