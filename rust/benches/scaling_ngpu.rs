//! E5 — the N-GPU scaling study (paper §4.2/§4.4 future work).

include!("harness.rs");

use theano_mgpu::sim::calibrate::{CalibratedCosts, Calibration};
use theano_mgpu::sim::scaling::{render, scaling_study};

fn main() {
    let mut b = Bench::new("scaling_ngpu");
    let costs = if artifacts_present() {
        let scratch = std::env::temp_dir().join("tmg_bench_calib");
        Calibration::measure(std::path::Path::new("artifacts"), &scratch, 3)
            .unwrap_or_else(|_| CalibratedCosts::canned())
    } else {
        CalibratedCosts::canned()
    };
    let rows = scaling_study(&costs, 100).unwrap();
    println!("\n{}", render(&rows));
    for r in &rows {
        b.record(
            &format!("speedup n={} {} {}", r.workers, r.topology, r.algorithm),
            r.speedup,
            "x",
        );
        b.record(
            &format!("exchange n={} {} {}", r.workers, r.topology, r.algorithm),
            r.exchange_s,
            "s",
        );
    }
    b.write_csv();
}
