//! E5 — the N-GPU scaling study (paper §4.2/§4.4 future work).
//!
//! Simulated speedups from the calibrated cost model, plus *measured*
//! per-phase rounds of the real ring collective at each N (the same
//! code path `train` uses for `workers > 2`).

include!("harness.rs");

use theano_mgpu::config::TransportKind;
use theano_mgpu::sim::calibrate::{CalibratedCosts, Calibration};
use theano_mgpu::sim::scaling::{render, scaling_study};

fn main() {
    let mut b = Bench::new("scaling_ngpu");
    let costs = if artifacts_present() {
        let scratch = std::env::temp_dir().join("tmg_bench_calib");
        Calibration::measure(std::path::Path::new("artifacts"), &scratch, 3)
            .unwrap_or_else(|_| CalibratedCosts::canned())
    } else {
        CalibratedCosts::canned()
    };
    let rows = scaling_study(&costs, 100).unwrap();
    println!("\n{}", render(&rows));
    for r in &rows {
        b.record(
            &format!("speedup n={} {} {}", r.workers, r.topology, r.algorithm),
            r.speedup,
            "x",
        );
        b.record(
            &format!("exchange n={} {} {}", r.workers, r.topology, r.algorithm),
            r.exchange_s,
            "s",
        );
    }

    // --- Measured ring collective rounds (real comm layer, per phase) ---
    let elements = 1_048_576usize;
    for &n in &[2usize, 3, 4, 8] {
        let phases = measure_ring(n, TransportKind::P2p, elements, 4);
        b.record(&format!("measured ring n={n} flatten/round"), phases.flatten_seconds, "s");
        b.record(&format!("measured ring n={n} transfer/round"), phases.transfer_seconds, "s");
        b.record(&format!("measured ring n={n} average/round"), phases.average_seconds, "s");
        b.record(&format!("measured ring n={n} total/round"), phases.total_seconds(), "s");
    }
    b.write_csv();
}
