// GEMM microkernel throughput on the real AlexNet shapes.
//
// Times the packed register-blocked kernels (serial and tile-parallel
// at 1/2/4 threads) against the pre-packing scalar kernels on every
// GEMM the full 227×227 AlexNet step actually runs — the conv1–conv5
// im2col products (per example) and the FC1–FC3 products (per batch) —
// plus two ReLU-sparse cases that keep the zero-skip-vs-vectorization
// decision honest (the scalar kernels skip zero multipliers, the packed
// kernels deliberately do not; see gemm.rs).
//
// The packed kernels run the dispatched SIMD microkernel (AVX2/NEON,
// logged in the JSON header as "isa"); every case is additionally timed
// through the portable fallback kernel in the same run, so the
// `simd_vs_autovec` ratio isolates what the explicit intrinsics buy
// over whatever the auto-vectorizer produced.
//
// Emits `target/bench_results/BENCH_gemm.json`: GFLOP/s per case per
// configuration, with packed-vs-scalar and simd-vs-autovec ratios.  CI
// runs this alongside the native-step bench and uploads both, so the
// before/after of the packed rewrite (and the zero-skip measurement,
// re-examined per-ISA) is recorded on every push.

include!("harness.rs");

use theano_mgpu::backend::native::gemm::{
    matmul_nn_ws, matmul_nn_ws_with, matmul_nt_ws, matmul_nt_ws_with, matmul_tn_ws,
    matmul_tn_ws_with, par_matmul_nn, par_matmul_nt, par_matmul_tn, scalar, PackBuf,
};
use theano_mgpu::backend::native::model::{NetPlan, PlanOp};
use theano_mgpu::backend::native::pool::ComputePool;
use theano_mgpu::backend::native::simd::{active_isa, Isa, MicroKernel};
use theano_mgpu::sim::flops::alexnet;
use theano_mgpu::util::Pcg32;

/// Batch size the FC products are shaped for (conv products are
/// per-example, exactly as the step runs them).
const BATCH: usize = 16;

#[derive(Clone, Copy)]
enum Layout {
    Nn,
    Nt,
    /// `A` is stored `[k, m]` (`Aᵀ·B`); the sparse dW case uses it.
    Tn,
}

struct Case {
    name: String,
    layout: Layout,
    m: usize,
    k: usize,
    n: usize,
    /// Fraction of zeros injected into A (post-ReLU sparsity stand-in).
    a_zeros: f32,
}

/// Every GEMM shape of the full AlexNet forward pass, taken from the
/// same compiled plan the native backend executes.
fn alexnet_cases() -> Vec<Case> {
    let plan = NetPlan::from_arch(&alexnet());
    let mut cases = Vec::new();
    let (mut n_conv, mut n_fc) = (0, 0);
    for op in &plan.ops {
        match op {
            PlanOp::ConvRelu { shape, .. } => {
                n_conv += 1;
                cases.push(Case {
                    name: format!("conv{n_conv}"),
                    layout: Layout::Nn,
                    m: shape.cout,
                    k: shape.cin * shape.k * shape.k,
                    n: shape.out_hw * shape.out_hw,
                    a_zeros: 0.0,
                });
            }
            PlanOp::FcRelu { shape, .. } | PlanOp::FcOut { shape, .. } => {
                n_fc += 1;
                cases.push(Case {
                    name: format!("fc{n_fc}"),
                    layout: Layout::Nt,
                    m: BATCH,
                    k: shape.din,
                    n: shape.dout,
                    a_zeros: 0.0,
                });
            }
            PlanOp::Pool { .. } => {}
        }
    }
    cases
}

/// The shapes where the old zero-skip actually fired in the step: both
/// scalar kernels skip on zeros of the A operand only, and the two step
/// GEMMs whose A operand is ReLU-sparse are FC dX (`nn`, A = dY) and
/// FC dW (`tn`, A = dY).  ~50% zeros stands in for post-ReLU sparsity.
fn sparse_cases() -> Vec<Case> {
    vec![
        // FC1 dX-shaped: dX = dY (sparse) · W.
        Case {
            name: "fc1-dx-sparse50".into(),
            layout: Layout::Nn,
            m: BATCH,
            k: 4096,
            n: 9216,
            a_zeros: 0.5,
        },
        // FC1 dW-shaped: dW += dYᵀ (sparse) · X.
        Case {
            name: "fc1-dw-sparse50".into(),
            layout: Layout::Tn,
            m: 4096,
            k: BATCH,
            n: 9216,
            a_zeros: 0.5,
        },
    ]
}

fn rand_vec(rng: &mut Pcg32, n: usize, zeros: f32) -> Vec<f32> {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v, 1.0);
    if zeros > 0.0 {
        for x in v.iter_mut() {
            if rng.next_f32() < zeros {
                *x = 0.0;
            }
        }
    }
    v
}

struct Measured {
    scalar_t1: f64,
    /// Portable-fallback-kernel throughput at 1 thread (the autovec
    /// baseline the explicit SIMD kernel is measured against).
    autovec_t1: f64,
    packed: Vec<(usize, f64)>, // (threads, gflops)
    ratio: f64,
    /// `packed t1 / autovec t1` — what the intrinsics buy.  Exactly 1.0
    /// when the dispatched ISA *is* the portable kernel.
    simd_ratio: f64,
}

fn gflops(case: &Case, med: f64) -> f64 {
    (2.0 * case.m as f64 * case.k as f64 * case.n as f64) / med / 1e9
}

fn run_case(b: &mut Bench, case: &Case, pools: &[(usize, ComputePool)]) -> Measured {
    let mut rng = Pcg32::seeded(17);
    let (m, k, n) = (case.m, case.k, case.n);
    let a = rand_vec(&mut rng, m * k, case.a_zeros);
    // nt's B is [n, k]; nn's is [k, n] — same element count.
    let bmat = rand_vec(&mut rng, k * n, 0.0);
    // C accumulates across iterations — the kernels' own contract.  It
    // is never zeroed inside the timed region: for large-C cases (fc1-dw
    // is a 151 MB C) a fill would add a full write pass to both sides
    // and compress the packed-vs-scalar ratio the record exists for.
    let mut c = vec![0.0f32; m * n];
    let shape = format!("{m}x{k}x{n}");
    let tag = if case.a_zeros > 0.0 { " (scalar skips zeros)" } else { "" };

    let med = b.case(&format!("{} {shape} scalar t1{tag}", case.name), 1, 3, || {
        match case.layout {
            Layout::Nn => scalar::matmul_nn(m, k, n, &a, &bmat, &mut c),
            Layout::Nt => scalar::matmul_nt(m, k, n, &a, &bmat, &mut c),
            Layout::Tn => scalar::matmul_tn(m, k, n, &a, &bmat, &mut c),
        }
    });
    let scalar_t1 = gflops(case, med);
    b.record(&format!("{} {shape} scalar t1 GFLOP/s", case.name), scalar_t1, "GF/s");

    let mut ws = PackBuf::default();
    let mut packed = Vec::new();
    let med = b.case(&format!("{} {shape} packed t1", case.name), 1, 3, || {
        match case.layout {
            Layout::Nn => matmul_nn_ws(m, k, n, &a, &bmat, &mut c, &mut ws),
            Layout::Nt => matmul_nt_ws(m, k, n, &a, &bmat, &mut c, &mut ws),
            Layout::Tn => matmul_tn_ws(m, k, n, &a, &bmat, &mut c, &mut ws),
        }
    });
    packed.push((1, gflops(case, med)));

    // Same packed pipeline through the portable fallback kernel — the
    // autovec baseline.  When the dispatched ISA already *is* the
    // portable kernel there is nothing to compare: reuse the packed t1
    // time so the ratio is exactly 1.0 instead of timing noise.
    let autovec_t1 = if active_isa() == Isa::Scalar {
        packed[0].1
    } else {
        let fallback = MicroKernel::for_isa(Isa::Scalar);
        let med = b.case(&format!("{} {shape} autovec t1", case.name), 1, 3, || {
            match case.layout {
                Layout::Nn => matmul_nn_ws_with(fallback, m, k, n, &a, &bmat, &mut c, &mut ws),
                Layout::Nt => matmul_nt_ws_with(fallback, m, k, n, &a, &bmat, &mut c, &mut ws),
                Layout::Tn => matmul_tn_ws_with(fallback, m, k, n, &a, &bmat, &mut c, &mut ws),
            }
        });
        gflops(case, med)
    };
    for (threads, pool) in pools {
        let med = b.case(&format!("{} {shape} packed t{threads}", case.name), 1, 3, || {
            match case.layout {
                Layout::Nn => par_matmul_nn(pool, m, k, n, &a, &bmat, &mut c, &mut ws),
                Layout::Nt => par_matmul_nt(pool, m, k, n, &a, &bmat, &mut c, &mut ws),
                Layout::Tn => par_matmul_tn(pool, m, k, n, &a, &bmat, &mut c, &mut ws),
            }
        });
        packed.push((*threads, gflops(case, med)));
    }
    for (t, gf) in &packed {
        b.record(&format!("{} {shape} packed t{t} GFLOP/s", case.name), *gf, "GF/s");
    }
    let ratio = packed[0].1 / scalar_t1;
    b.record(&format!("{} packed/scalar at t1", case.name), ratio, "x");
    let simd_ratio = packed[0].1 / autovec_t1;
    b.record(&format!("{} simd/autovec at t1", case.name), simd_ratio, "x");
    Measured { scalar_t1, autovec_t1, packed, ratio, simd_ratio }
}

fn case_json(case: &Case, r: &Measured) -> String {
    let layout = match case.layout {
        Layout::Nn => "nn",
        Layout::Nt => "nt",
        Layout::Tn => "tn",
    };
    let packed: Vec<String> =
        r.packed.iter().map(|(t, gf)| format!("\"t{t}\": {gf:.3}")).collect();
    format!(
        "{{\"name\": \"{}\", \"layout\": \"{layout}\", \"m\": {}, \"k\": {}, \"n\": {}, \
         \"a_zero_fraction\": {:.2}, \"gflops_scalar_t1\": {:.3}, \
         \"gflops_autovec_t1\": {:.3}, \"gflops_packed\": {{{}}}, \
         \"packed_vs_scalar_t1\": {:.3}, \"simd_vs_autovec\": {:.3}}}",
        case.name,
        case.m,
        case.k,
        case.n,
        case.a_zeros,
        r.scalar_t1,
        r.autovec_t1,
        packed.join(", "),
        r.ratio,
        r.simd_ratio
    )
}

fn main() {
    let mut b = Bench::new("gemm_kernels");
    let pools = vec![(2usize, ComputePool::new(2)), (4usize, ComputePool::new(4))];

    let cases = alexnet_cases();
    let mut rows = Vec::new();
    let mut fc1_ratio = 0.0;
    for case in &cases {
        let r = run_case(&mut b, case, &pools);
        if case.name == "fc1" {
            fc1_ratio = r.ratio;
        }
        rows.push(case_json(case, &r));
    }
    let mut sparse_rows = Vec::new();
    for case in &sparse_cases() {
        let r = run_case(&mut b, case, &pools);
        sparse_rows.push(case_json(case, &r));
    }

    b.write_csv();

    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("BENCH_gemm.json");
    let json = format!(
        "{{\"bench\": \"gemm_kernels\", \"model\": \"alexnet\", \"fc_batch\": {BATCH}, \
         \"isa\": \"{}\", \"threads\": [1, 2, 4], \"available_cores\": {}, \
         \"fc1_packed_vs_scalar_t1\": {fc1_ratio:.3}, \
         \"cases\": [{}], \"sparse_cases\": [{}]}}\n",
        active_isa(),
        theano_mgpu::util::available_cores(),
        rows.join(", "),
        sparse_rows.join(", ")
    );
    let _ = std::fs::write(&path, json);
    println!("  -> {}", path.display());
}
