//! Tiny `--key value` / `--flag` argument parser.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed flags/options plus positional arguments.
#[derive(Debug, Default)]
pub struct ArgMap {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl ArgMap {
    /// `--key value` pairs become options; a `--key` followed by
    /// another `--...` (or nothing) becomes a boolean flag.
    pub fn parse(argv: &[String]) -> Result<ArgMap> {
        let mut out = ArgMap::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err(Error::msg("bare `--` not supported"));
                }
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::msg(format!("--{key} wants an integer, got {v:?}"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::msg(format!("--{key} wants an integer, got {v:?}"))),
        }
    }

    pub fn required(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::msg(format!("missing required option --{key}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn options_flags_positionals() {
        let a = ArgMap::parse(&argv("table1 --steps 40 --real --csv out.csv")).unwrap();
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get("steps"), Some("40"));
        assert!(a.has_flag("real"));
        assert_eq!(a.str_or("csv", "x"), "out.csv");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 40);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn errors() {
        let a = ArgMap::parse(&argv("--steps forty")).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
        assert!(a.required("nope").is_err());
        assert!(ArgMap::parse(&argv("-- x")).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = ArgMap::parse(&argv("--verbose")).unwrap();
        assert!(a.has_flag("verbose"));
    }
}
