//! `tmg eval` — evaluate a checkpoint on the validation split.

use std::path::Path;

use crate::cli::args::ArgMap;
use crate::config::TrainConfig;
use crate::coordinator::eval::evaluate;
use crate::error::{Error, Result};
use crate::params::{load_checkpoint, ParamStore};
use crate::runtime::{Manifest, RuntimeClient};

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let cfg = TrainConfig::load(Path::new(a.required("config")?))?;
    let ckpt = Path::new(a.required("checkpoint")?);

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let model = manifest.model(&cfg.model)?;
    let spec = manifest
        .eval_artifact_for(&cfg.model)
        .ok_or_else(|| Error::msg(format!("no eval artifact for model {:?}", cfg.model)))?;

    let mut store = ParamStore::init(&model.params, cfg.seed);
    let step = load_checkpoint(ckpt, &mut store)?;

    let client = RuntimeClient::cpu()?;
    let exe = client.load_step(spec)?;
    let crop = model.image_hw;
    let result = evaluate(&cfg, &exe, &store, crop, a.usize_or("max-batches", 0)?)?;
    println!(
        "checkpoint @step {step}: top-1 error {:.2}%  top-5 error {:.2}%  loss {:.4}  ({} examples)",
        100.0 * result.top1_error(),
        100.0 * result.top5_error(),
        result.mean_loss,
        result.examples
    );
    Ok(0)
}
