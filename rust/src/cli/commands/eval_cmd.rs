//! `tmg eval` — evaluate a checkpoint on the validation split.
//!
//! Runs through whichever step backend the config (or `--backend`)
//! selects; with the native backend no config file is needed:
//! `tmg eval --checkpoint c.ckpt --model alexnet-micro --data-dir d`.

use std::path::Path;

use crate::cli::args::ArgMap;
use crate::config::TrainConfig;
use crate::coordinator::eval::evaluate;
use crate::error::{Error, Result};
use crate::params::{load_checkpoint, ParamStore};

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let mut cfg = match a.get("config") {
        Some(p) => TrainConfig::load(Path::new(p))?,
        None => TrainConfig::default(),
    };
    // One override surface shared with `tmg train` (train-only flags
    // are simply absent here).
    super::train_cmd::apply_overrides(&mut cfg, &a)?;
    super::train_cmd::sync_dataset_meta(&mut cfg)?;
    if let Some(v) = a.get("gemm-isa") {
        // Same mechanism as `tmg train`: resolved once at the first
        // kernel dispatch, inside the backend built below.
        std::env::set_var("TMG_GEMM_ISA", v);
    }
    let ckpt = Path::new(a.required("checkpoint")?);

    let mut backend = crate::backend::build_eval_backend(&cfg)?;
    if !backend.supports_eval() {
        return Err(Error::msg(format!(
            "backend {:?} has no eval path for model {:?} (no eval artifact?)",
            backend.name(),
            cfg.model
        )));
    }
    let model = backend.model().clone();
    let mut store = ParamStore::init(&model.params, cfg.seed);
    let step = load_checkpoint(ckpt, &mut store)?;

    let Some(result) = evaluate(&cfg, backend.as_mut(), &store, a.usize_or("max-batches", 0)?)?
    else {
        // Pre-fix this printed "top-1 error 100.00% (0 examples)" —
        // a fake rate.  No data is a usage error, not a measurement.
        return Err(Error::msg(format!(
            "nothing to evaluate: no val examples under {:?} (generate the corpus \
             with --val > 0, or point --data-dir at one that has a val split)",
            cfg.data.dir
        )));
    };
    println!(
        "checkpoint @step {step}: top-1 error {:.2}%  top-5 error {:.2}%  loss {:.4}  ({} examples)",
        100.0 * result.top1_error(),
        100.0 * result.top5_error(),
        result.mean_loss,
        result.examples
    );
    Ok(0)
}
