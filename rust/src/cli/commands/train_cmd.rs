//! `tmg train` — run a training job.
//!
//! A TOML config is optional: with `--backend native` (the default)
//! every knob has a workable default, so
//! `tmg train --model alexnet-micro --steps 40` trains out of the box
//! (the synthetic dataset is generated on first use).

use std::path::{Path, PathBuf};

use crate::cli::args::ArgMap;
use crate::config::{LoaderMode, TrainConfig, TransportKind};
use crate::coordinator::trainer::train;
use crate::error::Result;

/// Apply CLI overrides on top of the TOML config.
pub fn apply_overrides(cfg: &mut TrainConfig, a: &ArgMap) -> Result<()> {
    if let Some(v) = a.get("steps") {
        cfg.steps = v.parse().map_err(|_| crate::Error::msg("--steps wants int"))?;
    }
    if let Some(v) = a.get("workers") {
        let w: usize = v.parse().map_err(|_| crate::Error::msg("--workers wants int"))?;
        cfg.cluster.workers = w;
        // Keep the config file's PCIe topology when it still fits this
        // worker count (so the §4.4 fallback stays live); only a count
        // change forces the all-one-switch default.
        if cfg.cluster.switch_of_worker.len() != w {
            cfg.cluster.switch_of_worker = vec![0; w];
        }
    }
    if let Some(v) = a.get("switches") {
        // Per-worker PCIe switch ids, e.g. `--switches 0,0,1,1`; drives
        // the per-hop §4.4 transport fallback for any worker count.
        let switches = v
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .map_err(|_| {
                crate::Error::msg("--switches wants comma-separated switch ids, e.g. 0,0,1")
            })?;
        if a.get("workers").is_none() {
            cfg.cluster.workers = switches.len();
        }
        cfg.cluster.switch_of_worker = switches;
    }
    if let Some(v) = a.get("threads") {
        // Intra-op compute threads per worker; `auto` (the default)
        // gives each worker a disjoint share of the machine's cores.
        cfg.compute_threads = match v {
            "auto" => 0,
            _ => {
                let t: usize = v.parse().map_err(|_| {
                    crate::Error::msg("--threads wants a positive integer or `auto`")
                })?;
                if t == 0 {
                    return Err(crate::Error::msg(
                        "--threads must be >= 1 (use `auto` for the per-worker core share)",
                    ));
                }
                t
            }
        };
    }
    if let Some(v) = a.get("model") {
        cfg.model = v.to_string();
    }
    if let Some(v) = a.get("backend") {
        cfg.backend = v.to_string();
    }
    if let Some(v) = a.get("data-dir") {
        cfg.data.dir = PathBuf::from(v);
    }
    if let Some(v) = a.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(PathBuf::from(v));
    }
    if let Some(v) = a.get("checkpoint-every") {
        cfg.checkpoint_every =
            v.parse().map_err(|_| crate::Error::msg("--checkpoint-every wants int"))?;
    }
    if let Some(v) = a.get("checkpoint-keep") {
        cfg.checkpoint_keep =
            v.parse().map_err(|_| crate::Error::msg("--checkpoint-keep wants int"))?;
    }
    if let Some(v) = a.get("eval-every") {
        cfg.eval_every = v.parse().map_err(|_| crate::Error::msg("--eval-every wants int"))?;
    }
    if let Some(v) = a.get("resume") {
        cfg.resume = Some(crate::config::ResumeFrom::parse(v));
    } else if a.has_flag("resume") {
        // Bare `--resume` (no value) means `--resume auto`.
        cfg.resume = Some(crate::config::ResumeFrom::Auto);
    }
    if let Some(v) = a.get("lr") {
        cfg.schedule.base_lr = v.parse().map_err(|_| crate::Error::msg("--lr wants a float"))?;
    }
    if let Some(v) = a.get("dropout") {
        cfg.dropout = v.parse().map_err(|_| crate::Error::msg("--dropout wants a float"))?;
    }
    if let Some(v) = a.get("seed") {
        cfg.seed = v.parse().map_err(|_| crate::Error::msg("--seed wants int"))?;
    }
    if let Some(v) = a.get("loader") {
        cfg.loader_mode = LoaderMode::parse(v)?;
    }
    if let Some(v) = a.get("transport") {
        cfg.exchange.transport = TransportKind::parse(v)?;
    }
    if let Some(v) = a.get("period") {
        cfg.exchange.period = v.parse().map_err(|_| crate::Error::msg("--period wants int"))?;
    }
    if let Some(v) = a.get("overlap") {
        cfg.exchange.overlap = crate::config::OverlapMode::parse(v)?;
    } else if a.has_flag("overlap") {
        // Bare `--overlap` (no value) means streamed overlap.
        cfg.exchange.overlap = crate::config::OverlapMode::Stream;
    }
    if let Some(v) = a.get("bucket-elems") {
        cfg.exchange.bucket_elems =
            v.parse().map_err(|_| crate::Error::msg("--bucket-elems wants int"))?;
    }
    if let Some(v) = a.get("batch") {
        cfg.batch_per_worker =
            v.parse().map_err(|_| crate::Error::msg("--batch wants int"))?;
    }
    if let Some(v) = a.get("csv") {
        cfg.metrics_csv = Some(PathBuf::from(v));
    }
    // --- Distributed (multi-process) mode: `--peers` (or a config
    // --- [distributed] section) makes this process ONE rank of a TCP
    // --- ring instead of spawning every worker as a thread. ---
    let wants_distributed =
        a.has_flag("distributed") || a.get("peers").is_some() || a.get("rank").is_some();
    if wants_distributed || cfg.distributed.is_some() {
        let mut d = cfg.distributed.clone().unwrap_or_default();
        if let Some(v) = a.get("peers") {
            d.peers = v.split(',').map(|s| s.trim().to_string()).collect();
        }
        if let Some(v) = a.get("rank") {
            d.rank = v.parse().map_err(|_| crate::Error::msg("--rank wants int"))?;
        }
        if let Some(v) = a.get("connect-timeout-ms") {
            d.connect_timeout_ms =
                v.parse().map_err(|_| crate::Error::msg("--connect-timeout-ms wants int"))?;
        }
        if let Some(v) = a.get("io-timeout-ms") {
            d.io_timeout_ms =
                v.parse().map_err(|_| crate::Error::msg("--io-timeout-ms wants int"))?;
        }
        if d.peers.is_empty() {
            return Err(crate::Error::msg(
                "--distributed needs --peers HOST:PORT,... (one listen address per rank, \
                 in rank order) or a [distributed] config section",
            ));
        }
        // One rank per worker: `--peers` implies the worker count
        // unless the user pinned it (validate() cross-checks either way).
        if a.get("workers").is_none()
            && a.get("switches").is_none()
            && cfg.cluster.workers != d.peers.len()
        {
            cfg.cluster.workers = d.peers.len();
            cfg.cluster.switch_of_worker = vec![0; d.peers.len()];
        }
        cfg.distributed = Some(d);
    }
    cfg.validate()
}

/// Reconcile the config's dataset sizes with what is actually on disk
/// (meta.json is authoritative once the corpus exists).
pub fn sync_dataset_meta(cfg: &mut TrainConfig) -> Result<()> {
    let meta_path = cfg.data.dir.join("meta.json");
    if let Ok(src) = std::fs::read_to_string(&meta_path) {
        let meta = crate::data::synth::DatasetMeta::from_json(&src)?;
        cfg.data.train_examples = meta.train_examples;
        cfg.data.val_examples = meta.val_examples;
        cfg.data.stored_hw = meta.hw;
    }
    Ok(())
}

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let mut cfg = match a.get("config") {
        Some(p) => TrainConfig::load(Path::new(p))?,
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, &a)?;
    if let Some(v) = a.get("gemm-isa") {
        // One mechanism with the TMG_GEMM_ISA env var: the override is
        // resolved (and logged) once, at the first kernel dispatch —
        // which happens after this point, inside the backends.
        std::env::set_var("TMG_GEMM_ISA", v);
    }

    // Auto-generate the dataset if missing (classes follow the model).
    if !cfg.data.dir.join("meta.json").exists() {
        log::info!("dataset missing; generating into {:?}", cfg.data.dir);
        let model = crate::backend::resolve_model(&cfg)?;
        let spec = crate::data::synth::SynthSpec {
            classes: model.num_classes,
            channels: 3,
            hw: cfg.data.stored_hw,
            noise: 24.0,
            seed: cfg.data.seed,
        };
        crate::data::synth::generate_dataset(
            &cfg.data.dir,
            &spec,
            cfg.data.train_examples,
            cfg.data.val_examples,
            cfg.data.shard_examples,
        )?;
    }
    sync_dataset_meta(&mut cfg)?;

    // The worker x thread core-budget check (thread_budget_warning)
    // runs inside train(), which every entry point shares.
    let summary = train(&cfg)?;
    if let Some(from) = summary.resumed_from {
        println!("resumed from checkpoint at step {from}");
    }
    // Report the steps *this invocation* executed; wall time covers
    // exactly those (a resumed run did not re-train the restored ones;
    // saturating: an already-complete `--resume auto` executes none).
    let executed = summary.steps.saturating_sub(summary.resumed_from.unwrap_or(0));
    println!(
        "trained {executed} steps (through step {}) on {} worker(s) in {:.1}s  ({:.2} s/20it)",
        summary.steps, summary.workers, summary.wall_seconds, summary.secs_per_20_iters
    );
    println!("gemm microkernel: {}", summary.gemm_isa);
    if let Some(last) = summary.losses.last() {
        let first = summary.losses.first().copied().unwrap_or(*last);
        println!("loss: {first:.4} -> {last:.4}");
    }
    if let Some(d) = summary.final_divergence {
        println!("replica divergence after final exchange: {d:.3e}");
    }
    if summary.exchange_rounds > 0 {
        println!(
            "collective: {} rounds, {:.3}s flatten / {:.3}s transfer / {:.3}s average per worker",
            summary.exchange_rounds,
            summary.collective.flatten_seconds,
            summary.collective.transfer_seconds,
            summary.collective.average_seconds
        );
    }
    if summary.collective.bucket_rounds > 0 {
        println!(
            "exchange overlap: {:.3}s overlapped, {:.3}s exposed ({} buckets over {} rounds)",
            summary.collective.overlapped_seconds,
            summary.collective.exposed_seconds,
            summary.collective.bucket_rounds,
            summary.exchange_rounds
        );
    }
    for (w, st) in summary.loader.iter().enumerate() {
        println!(
            "worker {w} loader: {} batches, load {:.2}s, stall {:.2}s",
            st.batches, st.load_seconds, st.stall_seconds
        );
    }
    for r in &summary.evals {
        println!(
            "step {:>5} validation: top-1 error {:.1}%  top-5 error {:.1}%  ({} examples)",
            r.step,
            100.0 * r.result.top1_error(),
            100.0 * r.result.top5_error(),
            r.result.examples
        );
    }
    if let Some(e) = summary.eval {
        println!(
            "validation: top-1 error {:.1}%  top-5 error {:.1}%  (loss {:.4}, {} examples)",
            100.0 * e.top1_error(),
            100.0 * e.top5_error(),
            e.mean_loss,
            e.examples
        );
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> ArgMap {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        ArgMap::parse(&argv).unwrap()
    }

    #[test]
    fn workers_override_resets_switches_only_on_count_change() {
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--workers 4 --transport serialized")).unwrap();
        assert_eq!(cfg.cluster.workers, 4);
        assert_eq!(cfg.cluster.switch_of_worker, vec![0; 4]);
        assert_eq!(cfg.exchange.transport, TransportKind::Serialized);
        // Same count: the config's topology (and its §4.4 fallback) is kept.
        let mut cfg = TrainConfig::default();
        cfg.cluster.switch_of_worker = vec![0, 1];
        apply_overrides(&mut cfg, &args("--workers 2")).unwrap();
        assert_eq!(cfg.cluster.switch_of_worker, vec![0, 1]);
    }

    #[test]
    fn switches_override_sets_topology_and_worker_count() {
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--switches 0,0,1")).unwrap();
        assert_eq!(cfg.cluster.workers, 3);
        assert_eq!(cfg.cluster.switch_of_worker, vec![0, 0, 1]);
    }

    #[test]
    fn conflicting_workers_and_switches_rejected() {
        let mut cfg = TrainConfig::default();
        let err = apply_overrides(&mut cfg, &args("--workers 2 --switches 0,0,1"));
        assert!(err.is_err(), "length mismatch must fail validation");
        let mut cfg = TrainConfig::default();
        assert!(apply_overrides(&mut cfg, &args("--switches 0,zebra")).is_err());
    }

    #[test]
    fn threads_override_validates() {
        // Integers >= 1 and `auto` parse; 0 and junk are rejected.
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--threads 4")).unwrap();
        assert_eq!(cfg.compute_threads, 4);
        apply_overrides(&mut cfg, &args("--threads auto")).unwrap();
        assert_eq!(cfg.compute_threads, 0);
        let err = apply_overrides(&mut cfg, &args("--threads 0")).unwrap_err();
        assert!(format!("{err}").contains(">= 1"), "{err}");
        assert!(apply_overrides(&mut cfg, &args("--threads many")).is_err());
        // Oversubscription is a warning (advisory), not an error: the
        // budget check fires exactly when workers * threads > cores.
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--workers 2 --threads 3")).unwrap();
        use crate::coordinator::trainer::thread_budget_warning_for;
        assert!(thread_budget_warning_for(&cfg, 4).is_some());
        assert!(thread_budget_warning_for(&cfg, 8).is_none());
    }

    #[test]
    fn lifecycle_overrides_parse() {
        use crate::config::ResumeFrom;
        let mut cfg = TrainConfig::default();
        apply_overrides(
            &mut cfg,
            &args("--checkpoint-every 50 --checkpoint-keep 3 --eval-every 25 --resume auto"),
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 50);
        assert_eq!(cfg.checkpoint_keep, 3);
        assert_eq!(cfg.eval_every, 25);
        assert_eq!(cfg.resume, Some(ResumeFrom::Auto));
        // An explicit path resumes from that file; bare --resume = auto.
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--resume ckpts/run_step8.w0.ckpt")).unwrap();
        assert_eq!(cfg.resume, Some(ResumeFrom::Path(PathBuf::from("ckpts/run_step8.w0.ckpt"))));
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--steps 8 --resume")).unwrap();
        assert_eq!(cfg.resume, Some(ResumeFrom::Auto));
        assert!(apply_overrides(&mut cfg, &args("--checkpoint-every soon")).is_err());
    }

    #[test]
    fn overlap_overrides_parse_and_validate() {
        use crate::config::OverlapMode;
        // Bare `--overlap` = streamed; valued forms pick the mode.
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--overlap")).unwrap();
        assert_eq!(cfg.exchange.overlap, OverlapMode::Stream);
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--overlap serial --bucket-elems 4096")).unwrap();
        assert_eq!(cfg.exchange.overlap, OverlapMode::Serial);
        assert_eq!(cfg.exchange.bucket_elems, 4096);
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--overlap off")).unwrap();
        assert_eq!(cfg.exchange.overlap, OverlapMode::Off);
        // Gradient exchange is only defined at period 1.
        let mut cfg = TrainConfig::default();
        let err = apply_overrides(&mut cfg, &args("--overlap --period 4")).unwrap_err();
        assert!(format!("{err}").contains("period"), "{err}");
        let mut cfg = TrainConfig::default();
        assert!(apply_overrides(&mut cfg, &args("--overlap sideways")).is_err());
    }

    #[test]
    fn distributed_overrides_parse() {
        // `--peers` implies distributed mode and the worker count.
        let mut cfg = TrainConfig::default();
        apply_overrides(
            &mut cfg,
            &args(
                "--rank 1 --peers 127.0.0.1:7301,127.0.0.1:7302 \
                 --connect-timeout-ms 500 --io-timeout-ms 800",
            ),
        )
        .unwrap();
        let d = cfg.distributed.as_ref().expect("peers enable distributed mode");
        assert_eq!(d.rank, 1);
        assert_eq!(d.peers, vec!["127.0.0.1:7301", "127.0.0.1:7302"]);
        assert_eq!(d.connect_timeout_ms, 500);
        assert_eq!(d.io_timeout_ms, 800);
        assert_eq!(cfg.cluster.workers, 2, "one rank per worker");
        // Bare `--distributed` without a peer list is a config error,
        // not a silent single-process run.
        let mut cfg = TrainConfig::default();
        let err = apply_overrides(&mut cfg, &args("--steps 4 --distributed")).unwrap_err();
        assert!(format!("{err}").contains("--peers"), "{err}");
        // Rank outside the peer list is rejected by validation.
        let mut cfg = TrainConfig::default();
        assert!(apply_overrides(
            &mut cfg,
            &args("--rank 5 --peers 127.0.0.1:7301,127.0.0.1:7302"),
        )
        .is_err());
    }

    #[test]
    fn model_backend_and_path_overrides() {
        let mut cfg = TrainConfig::default();
        apply_overrides(
            &mut cfg,
            &args(
                "--model alexnet-micro --backend native --data-dir /tmp/d \
                 --checkpoint-dir /tmp/c --lr 0.05 --dropout 0.0 --seed 9",
            ),
        )
        .unwrap();
        assert_eq!(cfg.model, "alexnet-micro");
        assert_eq!(cfg.backend, "native");
        assert_eq!(cfg.data.dir, PathBuf::from("/tmp/d"));
        assert_eq!(cfg.checkpoint_dir, Some(PathBuf::from("/tmp/c")));
        assert!((cfg.schedule.base_lr - 0.05).abs() < 1e-6);
        assert_eq!(cfg.dropout, 0.0);
        assert_eq!(cfg.seed, 9);
        assert!(apply_overrides(&mut cfg, &args("--dropout 2.0")).is_err());
    }
}
