//! `tmg train` — run a training job.

use std::path::{Path, PathBuf};

use crate::cli::args::ArgMap;
use crate::config::{LoaderMode, TrainConfig, TransportKind};
use crate::coordinator::trainer::train;
use crate::error::Result;

/// Apply CLI overrides on top of the TOML config.
pub fn apply_overrides(cfg: &mut TrainConfig, a: &ArgMap) -> Result<()> {
    if let Some(v) = a.get("steps") {
        cfg.steps = v.parse().map_err(|_| crate::Error::msg("--steps wants int"))?;
    }
    if let Some(v) = a.get("workers") {
        let w: usize = v.parse().map_err(|_| crate::Error::msg("--workers wants int"))?;
        cfg.cluster.workers = w;
        // Keep the config file's PCIe topology when it still fits this
        // worker count (so the §4.4 fallback stays live); only a count
        // change forces the all-one-switch default.
        if cfg.cluster.switch_of_worker.len() != w {
            cfg.cluster.switch_of_worker = vec![0; w];
        }
    }
    if let Some(v) = a.get("switches") {
        // Per-worker PCIe switch ids, e.g. `--switches 0,0,1,1`; drives
        // the per-hop §4.4 transport fallback for any worker count.
        let switches = v
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<std::result::Result<Vec<_>, _>>()
            .map_err(|_| {
                crate::Error::msg("--switches wants comma-separated switch ids, e.g. 0,0,1")
            })?;
        if a.get("workers").is_none() {
            cfg.cluster.workers = switches.len();
        }
        cfg.cluster.switch_of_worker = switches;
    }
    if let Some(v) = a.get("backend") {
        cfg.backend = v.to_string();
    }
    if let Some(v) = a.get("loader") {
        cfg.loader_mode = LoaderMode::parse(v)?;
    }
    if let Some(v) = a.get("transport") {
        cfg.exchange.transport = TransportKind::parse(v)?;
    }
    if let Some(v) = a.get("period") {
        cfg.exchange.period = v.parse().map_err(|_| crate::Error::msg("--period wants int"))?;
    }
    if let Some(v) = a.get("batch") {
        cfg.batch_per_worker =
            v.parse().map_err(|_| crate::Error::msg("--batch wants int"))?;
    }
    if let Some(v) = a.get("csv") {
        cfg.metrics_csv = Some(PathBuf::from(v));
    }
    cfg.validate()
}

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let mut cfg = TrainConfig::load(Path::new(a.required("config")?))?;
    apply_overrides(&mut cfg, &a)?;

    // Auto-generate the dataset if missing (classes follow the model).
    if !cfg.data.dir.join("meta.json").exists() {
        log::info!("dataset missing; generating into {:?}", cfg.data.dir);
        let manifest = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
        let classes = manifest.model(&cfg.model)?.num_classes;
        let spec = crate::data::synth::SynthSpec {
            classes,
            channels: 3,
            hw: cfg.data.stored_hw,
            noise: 24.0,
            seed: cfg.data.seed,
        };
        crate::data::synth::generate_dataset(
            &cfg.data.dir,
            &spec,
            cfg.data.train_examples,
            cfg.data.val_examples,
            cfg.data.shard_examples,
        )?;
    }

    let summary = train(&cfg)?;
    println!(
        "trained {} steps on {} worker(s) in {:.1}s  ({:.2} s/20it)",
        summary.steps, summary.workers, summary.wall_seconds, summary.secs_per_20_iters
    );
    if let Some(last) = summary.losses.last() {
        let first = summary.losses.first().copied().unwrap_or(*last);
        println!("loss: {first:.4} -> {last:.4}");
    }
    if let Some(d) = summary.final_divergence {
        println!("replica divergence after final exchange: {d:.3e}");
    }
    if summary.exchange_rounds > 0 {
        println!(
            "collective: {} rounds, {:.3}s flatten / {:.3}s transfer / {:.3}s average per worker",
            summary.exchange_rounds,
            summary.collective.flatten_seconds,
            summary.collective.transfer_seconds,
            summary.collective.average_seconds
        );
    }
    for (w, st) in summary.loader.iter().enumerate() {
        println!(
            "worker {w} loader: {} batches, load {:.2}s, stall {:.2}s",
            st.batches, st.load_seconds, st.stall_seconds
        );
    }
    if let Some(e) = summary.eval {
        println!(
            "validation: top-1 error {:.1}%  top-5 error {:.1}%  (loss {:.4}, {} examples)",
            100.0 * e.top1_error(),
            100.0 * e.top5_error(),
            e.mean_loss,
            e.examples
        );
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> ArgMap {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        ArgMap::parse(&argv).unwrap()
    }

    #[test]
    fn workers_override_resets_switches_only_on_count_change() {
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--workers 4 --transport serialized")).unwrap();
        assert_eq!(cfg.cluster.workers, 4);
        assert_eq!(cfg.cluster.switch_of_worker, vec![0; 4]);
        assert_eq!(cfg.exchange.transport, TransportKind::Serialized);
        // Same count: the config's topology (and its §4.4 fallback) is kept.
        let mut cfg = TrainConfig::default();
        cfg.cluster.switch_of_worker = vec![0, 1];
        apply_overrides(&mut cfg, &args("--workers 2")).unwrap();
        assert_eq!(cfg.cluster.switch_of_worker, vec![0, 1]);
    }

    #[test]
    fn switches_override_sets_topology_and_worker_count() {
        let mut cfg = TrainConfig::default();
        apply_overrides(&mut cfg, &args("--switches 0,0,1")).unwrap();
        assert_eq!(cfg.cluster.workers, 3);
        assert_eq!(cfg.cluster.switch_of_worker, vec![0, 0, 1]);
    }

    #[test]
    fn conflicting_workers_and_switches_rejected() {
        let mut cfg = TrainConfig::default();
        let err = apply_overrides(&mut cfg, &args("--workers 2 --switches 0,0,1"));
        assert!(err.is_err(), "length mismatch must fail validation");
        let mut cfg = TrainConfig::default();
        assert!(apply_overrides(&mut cfg, &args("--switches 0,zebra")).is_err());
    }
}
