//! `tmg simulate` — regenerate the paper's tables from the calibrated
//! simulator.
//!
//! - `table1`: the headline Table 1 (E1)
//! - `scaling`: the N-GPU study (E5)
//! - `overlap`: Fig-1 overlap-efficiency sweep (E3)

use std::path::PathBuf;

use crate::cli::args::ArgMap;
use crate::error::{Error, Result};
use crate::metrics::CsvWriter;
use crate::sim::calibrate::{CalibratedCosts, Calibration};
use crate::sim::pipeline::{simulate, PipelineParams};
use crate::sim::scaling::{render as render_scaling, scaling_study};
use crate::sim::table1::{render, table1, Table1Options};

fn costs(a: &ArgMap) -> Result<CalibratedCosts> {
    if a.has_flag("real") {
        let artifacts = PathBuf::from(a.str_or("artifacts", "artifacts"));
        let scratch = std::env::temp_dir().join("tmg_calibrate_data");
        Calibration::measure(&artifacts, &scratch, a.usize_or("runs", 5)?)
    } else {
        Ok(CalibratedCosts::canned())
    }
}

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let which = a
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| Error::msg("simulate wants table1|scaling|overlap"))?;
    match which {
        "table1" => {
            let mut opts = Table1Options::with_costs(costs(&a)?);
            opts.steps = a.usize_or("steps", 100)?;
            let cells = table1(&opts)?;
            print!("{}", render(&cells));
            if let Some(csv) = a.get("csv") {
                let mut w = CsvWriter::create(
                    std::path::Path::new(csv),
                    &["backend", "gpus", "parallel_loading", "per20_s"],
                )?;
                for c in &cells {
                    w.row(&[
                        c.backend.clone(),
                        c.gpus.to_string(),
                        c.parallel_loading.to_string(),
                        format!("{:.4}", c.per20_s),
                    ])?;
                }
                w.flush()?;
            }
            Ok(0)
        }
        "scaling" => {
            let rows = scaling_study(&costs(&a)?, a.usize_or("steps", 60)?)?;
            print!("{}", render_scaling(&rows));
            Ok(0)
        }
        "overlap" => {
            // Fig-1 sweep: hidden fraction vs load/compute ratio.
            println!("load/compute  serial_s/20it  parallel_s/20it  saving  overlap_eff");
            for ratio in [0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
                let base = PipelineParams {
                    workers: 1,
                    compute_s: 1.0,
                    load_s: ratio,
                    exchange_s: 0.0,
                    period: 1,
                    parallel_loading: true,
                    jitter: 0.0,
                    seed: 3,
                };
                let par = simulate(&base, a.usize_or("steps", 100)?);
                let ser = simulate(
                    &PipelineParams { parallel_loading: false, ..base },
                    a.usize_or("steps", 100)?,
                );
                println!(
                    "{:>11.2}  {:>13.2}  {:>15.2}  {:>5.1}%  {:>10.2}",
                    ratio,
                    ser.mean_per20(),
                    par.mean_per20(),
                    100.0 * (1.0 - par.mean_per20() / ser.mean_per20()),
                    par.overlap_efficiency
                );
            }
            Ok(0)
        }
        other => Err(Error::msg(format!("unknown simulation {other:?}"))),
    }
}
