//! `tmg calibrate` — measure this machine's costs.

use std::path::PathBuf;

use crate::cli::args::ArgMap;
use crate::error::Result;
use crate::sim::calibrate::Calibration;

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let artifacts = PathBuf::from(a.str_or("artifacts", "artifacts"));
    let runs = a.usize_or("runs", 5)?;
    let scratch = std::env::temp_dir().join("tmg_calibrate_data");

    let costs = Calibration::measure(&artifacts, &scratch, runs)?;
    println!("calibrated costs on this machine:");
    for (backend, secs) in &costs.backend_step_s {
        println!("  step[{backend:<9}] = {}", crate::util::fmt::secs(*secs));
    }
    println!(
        "  loader          = {} / image (stored {}px)",
        crate::util::fmt::secs(costs.load_s_per_image),
        costs.load_hw
    );
    println!(
        "  host memcpy     = {:.2} GB/s",
        costs.host_copy_bytes_per_s / 1e9
    );
    Ok(0)
}
