//! `tmg inspect` — list artifacts and their ABIs, or print a model's
//! per-layer table (`--model NAME`).

use std::path::PathBuf;

use crate::cli::args::ArgMap;
use crate::error::{Error, Result};
use crate::runtime::Manifest;
use crate::sim::flops::{arch_by_name, known_arch_names, ArchDesc};
use crate::util::fmt;

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    if let Some(name) = a.get("model") {
        let arch = arch_by_name(name).ok_or_else(|| {
            Error::msg(format!(
                "model {:?} is not a known architecture (known models: {})",
                name,
                known_arch_names().join(", ")
            ))
        })?;
        print_model_table(&arch);
        return Ok(0);
    }
    let dir = PathBuf::from(a.str_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir)?;

    println!("models:");
    for model in &m.models {
        let elems = model.total_param_elements();
        println!(
            "  {:<15} {}x{}x{}  {} classes  {} tensors, {} params ({})",
            model.name,
            model.in_channels,
            model.image_hw,
            model.image_hw,
            model.num_classes,
            model.param_count(),
            fmt::count(elems as u64),
            fmt::bytes(elems * 4)
        );
    }
    println!("artifacts:");
    for art in &m.artifacts {
        let in_bytes: usize = art.inputs.iter().map(|i| i.byte_size()).sum();
        println!(
            "  {:<38} kind={:<5?} batch={:<3} inputs={} ({}) outputs={}",
            art.name,
            art.kind,
            art.batch_size,
            art.inputs.len(),
            fmt::bytes(in_bytes),
            art.outputs.len()
        );
    }
    Ok(0)
}

/// Per-layer breakdown of an architecture.  The table's totals are
/// asserted equal to the analytic `ArchDesc` counts — any drift between
/// the two walks is a bug, not a rounding difference.
fn print_model_table(arch: &ArchDesc) {
    println!(
        "{}: {}x{}x{} input, {} classes",
        arch.name, arch.in_channels, arch.image_hw, arch.image_hw, arch.num_classes
    );
    println!(
        "  {:<10} {:<14} {:>12} {:>14} {:>7}  {}",
        "layer", "output", "params", "fwd MACs", "groups", "lrn"
    );
    let rows = arch.layer_rows();
    for r in &rows {
        let out = if r.out_hw > 0 {
            format!("{}x{}x{}", r.out_ch, r.out_hw, r.out_hw)
        } else {
            format!("{}", r.out_ch)
        };
        let lrn = match r.lrn {
            Some(l) => format!("r={} k={} a={} b={}", l.radius, l.bias, l.alpha, l.beta),
            None => "-".to_string(),
        };
        println!(
            "  {:<10} {:<14} {:>12} {:>14} {:>7}  {}",
            r.name, out, r.params, r.fwd_macs, r.groups, lrn
        );
    }
    let params: u64 = rows.iter().map(|r| r.params).sum();
    let macs: u64 = rows.iter().map(|r| r.fwd_macs).sum();
    assert_eq!(params, arch.param_elements(), "layer table drifted from param_elements()");
    assert_eq!(macs, arch.forward_macs(), "layer table drifted from forward_macs()");
    println!(
        "  {:<10} {:<14} {:>12} {:>14}   ({} params, {} fwd MACs/example)",
        "total",
        "",
        params,
        macs,
        fmt::count(params),
        fmt::count(macs)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_mode_prints_and_reconciles() {
        // The runtime assertions inside print_model_table are the
        // contract; run them for every known arch.
        for name in known_arch_names() {
            let args: Vec<String> = vec!["--model".into(), (*name).into()];
            assert_eq!(run(&args).unwrap(), 0);
        }
    }

    #[test]
    fn unknown_model_lists_known_names() {
        let args: Vec<String> = vec!["--model".into(), "resnet".into()];
        let err = run(&args).unwrap_err().to_string();
        assert!(err.contains("alexnet-tiny-faithful"), "{err}");
        assert!(err.contains("alexnet-micro"), "{err}");
    }
}
