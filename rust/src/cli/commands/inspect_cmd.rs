//! `tmg inspect` — list artifacts and their ABIs.

use std::path::PathBuf;

use crate::cli::args::ArgMap;
use crate::error::Result;
use crate::runtime::Manifest;
use crate::util::fmt;

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let dir = PathBuf::from(a.str_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir)?;

    println!("models:");
    for model in &m.models {
        let elems = model.total_param_elements();
        println!(
            "  {:<15} {}x{}x{}  {} classes  {} tensors, {} params ({})",
            model.name,
            model.in_channels,
            model.image_hw,
            model.image_hw,
            model.num_classes,
            model.param_count(),
            fmt::count(elems as u64),
            fmt::bytes(elems * 4)
        );
    }
    println!("artifacts:");
    for art in &m.artifacts {
        let in_bytes: usize = art.inputs.iter().map(|i| i.byte_size()).sum();
        println!(
            "  {:<38} kind={:<5?} batch={:<3} inputs={} ({}) outputs={}",
            art.name,
            art.kind,
            art.batch_size,
            art.inputs.len(),
            fmt::bytes(in_bytes),
            art.outputs.len()
        );
    }
    Ok(0)
}
