//! `tmg serve` — the dynamic-batching inference server (and its
//! scripted client).
//!
//! Server mode loads a checkpoint once into an immutable shared
//! `ParamStore` and answers `classify` requests over the line protocol
//! (see the [`crate::serve`] module docs):
//!
//! ```text
//! tmg serve --checkpoint ckpt/default_step8.ckpt --data-dir data \
//!           --model alexnet-micro --replicas 2 --max-batch 8 \
//!           --deadline-ms 5 --port 7070
//! ```
//!
//! Client mode (`--client HOST:PORT`) drives a running server with the
//! closed-loop generator and prints latency percentiles — the scripted
//! side of the CI smoke job:
//!
//! ```text
//! tmg serve --client 127.0.0.1:7070 --requests 64 --concurrency 8
//! ```

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::cli::args::ArgMap;
use crate::config::TrainConfig;
use crate::error::{Error, Result};
use crate::params::{load_checkpoint, ParamStore};
use crate::serve::loadgen::run_closed_loop;
use crate::serve::{ServeOpts, Server};

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    if let Some(addr) = a.get("client") {
        return run_client(addr, &a);
    }
    run_server(&a)
}

fn run_client(addr: &str, a: &ArgMap) -> Result<i32> {
    let requests = a.u64_or("requests", 64)?;
    let concurrency = a.usize_or("concurrency", 4)?;
    let seed = a.u64_or("seed", 1)?;
    let report = run_closed_loop(addr, requests, concurrency, seed)?;
    println!(
        "client: sent={} ok={} errors={} wall_s={:.3} throughput_rps={:.1} \
         p50_ms={:.3} p99_ms={:.3}",
        report.sent,
        report.ok,
        report.errors,
        report.wall_secs,
        report.throughput_rps,
        report.p50_ms,
        report.p99_ms
    );
    Ok(if report.errors > 0 { 1 } else { 0 })
}

fn run_server(a: &ArgMap) -> Result<i32> {
    let mut cfg = match a.get("config") {
        Some(p) => TrainConfig::load(Path::new(p))?,
        None => TrainConfig::default(),
    };
    // Same override surface as train/eval (model, backend, data-dir,
    // threads, ... — serve-only flags handled below).
    super::train_cmd::apply_overrides(&mut cfg, a)?;
    super::train_cmd::sync_dataset_meta(&mut cfg)?;
    if let Some(v) = a.get("gemm-isa") {
        std::env::set_var("TMG_GEMM_ISA", v);
    }
    let opts = ServeOpts {
        replicas: a.usize_or("replicas", 1)?.max(1),
        max_batch: a.usize_or("max-batch", 8)?.max(1),
        deadline: Duration::from_secs_f64(
            a.str_or("deadline-ms", "5")
                .parse::<f64>()
                .map_err(|_| Error::msg("--deadline-ms wants a number"))?
                .max(0.0)
                / 1e3,
        ),
        topk: a.usize_or("topk", 5)?.max(1),
        port: a
            .str_or("port", "7070")
            .parse::<u16>()
            .map_err(|_| Error::msg("--port wants a u16"))?,
        idle_timeout: Duration::from_secs(a.u64_or("idle-timeout-secs", 60)?.max(1)),
    };
    // `--threads auto` divides the machine's cores across replicas the
    // same way training divides them across workers.
    cfg.cluster.workers = opts.replicas;
    if cfg.cluster.switch_of_worker.len() != opts.replicas {
        cfg.cluster.switch_of_worker = vec![0; opts.replicas];
    }

    let ckpt = Path::new(a.required("checkpoint")?);
    let model = crate::backend::resolve_model(&cfg)?;
    let mut store = ParamStore::init(&model.params, cfg.seed);
    let step = load_checkpoint(ckpt, &mut store)?;
    log::info!("serve: checkpoint {ckpt:?} @step {step} loaded ({} params)", store.params.len());
    let store = Arc::new(store);

    // 0 = run until killed; N = answer N requests, drain, exit — the
    // self-terminating mode CI and scripts use.
    let max_requests = a.u64_or("max-requests", 0)?;
    let server = Server::start(&cfg, store, opts)?;
    println!("serving on {}", server.addr());
    if max_requests == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    while server.served() < max_requests {
        std::thread::sleep(Duration::from_millis(100));
    }
    let snap = server.shutdown();
    println!("serve drained: {}", snap.line(0));
    Ok(if snap.errors > 0 { 1 } else { 0 })
}
