//! Subcommand implementations.

pub mod calibrate_cmd;
pub mod eval_cmd;
pub mod gen_data;
pub mod inspect_cmd;
pub mod serve_cmd;
pub mod simulate_cmd;
pub mod train_cmd;
