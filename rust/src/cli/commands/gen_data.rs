//! `tmg gen-data` — write the synthetic corpus.

use std::path::PathBuf;

use crate::cli::args::ArgMap;
use crate::data::synth::{generate_dataset, SynthSpec};
use crate::error::Result;
use crate::util::Timer;

pub fn run(argv: &[String]) -> Result<i32> {
    let a = ArgMap::parse(argv)?;
    let dir = PathBuf::from(a.required("dir")?);
    let spec = SynthSpec {
        classes: a.usize_or("classes", 100)?,
        channels: 3,
        hw: a.usize_or("hw", 72)?,
        noise: 24.0,
        seed: a.u64_or("seed", 1234)?,
    };
    let train = a.usize_or("train", 8192)?;
    let val = a.usize_or("val", 1024)?;
    let shard = a.usize_or("shard", 1024)?;

    let t = Timer::start();
    let meta = generate_dataset(&dir, &spec, train, val, shard)?;
    println!(
        "generated {} train + {} val examples ({} classes, {}x{}x{}) in {:.1}s -> {}",
        meta.train_examples,
        meta.val_examples,
        meta.classes,
        meta.channels,
        meta.hw,
        meta.hw,
        t.elapsed_secs(),
        dir.display()
    );
    Ok(0)
}
