//! The `tmg` command line.
//!
//! Subcommands:
//!
//! - `gen-data`  — generate the synthetic corpus shards
//! - `train`     — run a training job from a TOML config (+ overrides)
//! - `eval`      — evaluate a checkpoint on the validation split
//! - `serve`     — dynamic-batching inference server over a checkpoint
//! - `calibrate` — measure step/loader/memcpy costs on this machine
//! - `simulate`  — regenerate Table 1 / the scaling study
//! - `inspect`   — list artifacts, models and their ABI
//!
//! (Hand-rolled parsing: the offline crate set has no clap.)

pub mod args;
pub mod commands;

use crate::error::{Error, Result};

/// Simple stderr logger honouring TMG_LOG (error|warn|info|debug).
struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init_logging() {
    let level = match std::env::var("TMG_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        _ => log::LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

const USAGE: &str = "\
tmg — Theano-multi-GPU reproduction (rust + jax + pallas)

USAGE:
  tmg gen-data  --dir DIR [--classes N] [--train N] [--val N]
                [--shard N] [--hw N] [--seed N]
  tmg train     [--config FILE] [--model M] [--backend native|xla|TAG]
                [--steps N] [--batch N] [--workers N] [--switches 0,0,1]
                [--threads N|auto] [--loader parallel|serial]
                [--transport K] [--period N] [--lr F] [--dropout F]
                [--seed N] [--data-dir DIR] [--checkpoint-dir DIR]
                [--checkpoint-every N] [--checkpoint-keep N]
                [--eval-every N] [--resume auto|PATH] [--csv FILE]
                [--gemm-isa avx2|neon|scalar|auto]
                [--distributed --rank R --peers HOST:PORT,...]
                [--connect-timeout-ms N] [--io-timeout-ms N]
  tmg eval      --checkpoint FILE [--config FILE] [--model M]
                [--backend B] [--data-dir DIR] [--batch N]
                [--threads N|auto] [--max-batches N]
                [--gemm-isa avx2|neon|scalar|auto]
  tmg serve     --checkpoint FILE [--config FILE] [--model M]
                [--backend B] [--data-dir DIR] [--threads N|auto]
                [--replicas N] [--max-batch N] [--deadline-ms F]
                [--port P] [--topk K] [--max-requests N]
                [--idle-timeout-secs N]
                [--gemm-isa avx2|neon|scalar|auto]
  tmg serve     --client HOST:PORT [--requests N] [--concurrency C]
                [--seed N]
  tmg calibrate [--artifacts DIR] [--runs N]
  tmg simulate  table1|scaling|overlap [--real] [--steps N] [--csv FILE]
  tmg inspect   [--artifacts DIR] [--model NAME]
  tmg help

The default backend is `native`: a pure-Rust CPU implementation of the
full AlexNet train/eval step — no AOT artifacts required.  Artifact
backend tags (e.g. `refconv`) run through the XLA runtime instead and
fall back to native when the artifacts are unavailable.

Models: `alexnet` (the paper's net, faithful: 2-group convolutions on
conv2/4/5 and LRN after conv1/conv2), `alexnet-tiny` and
`alexnet-micro` (fast ungrouped CPU-scale variants), and
`alexnet-tiny-faithful` (tiny geometry with the faithful structure).
`tmg inspect --model NAME` prints a per-layer table (output shape,
params, forward MACs, groups, LRN) with reconciled totals.

`tmg serve` loads a checkpoint once into an immutable shared store and
answers `classify` requests over a TCP line protocol with dynamically
formed batches: a request queue flushes to one of `--replicas` eval
replicas when `--max-batch` requests wait or the oldest has waited
`--deadline-ms`.  `--max-requests N` answers N requests, drains, and
exits (the CI smoke mode); the client mode fires concurrent requests
and prints p50/p99 latency.

Lifecycle: `--checkpoint-every N` snapshots each replica every N steps
(atomic v2 files carrying the resume state), `--eval-every N` runs
mid-training validation, and `--resume auto` (or a checkpoint PATH)
restarts a killed run bit-exactly from the newest valid snapshot.

Distributed: `--peers HOST:PORT,...` (one listen address per rank, in
rank order) runs this process as rank `--rank R` of a multi-process
TCP ring — same collective, same bits as the in-process run.  Ranks
rendezvous with bounded retry (`--connect-timeout-ms`), every socket
carries an I/O deadline (`--io-timeout-ms`) so a dead peer is a loud
timeout instead of a hang, and after a crash restarting every rank
with `--resume auto` (shared --checkpoint-dir) reassembles the run
bit-exactly.  See README \"Distributed training\".

The native GEMM picks an explicit SIMD microkernel (avx2/neon/scalar)
at startup via runtime detection; `--gemm-isa` (or the TMG_GEMM_ISA
env var) overrides it, unknown/unavailable values fall back to scalar
with a warning, and the dispatched ISA is logged and reported.
TMG_LOG=error|warn|info|debug sets log verbosity (stderr).
";

/// Entry point used by main.rs; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    init_logging();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "gen-data" => commands::gen_data::run(rest),
        "train" => commands::train_cmd::run(rest),
        "eval" => commands::eval_cmd::run(rest),
        "serve" => commands::serve_cmd::run(rest),
        "calibrate" => commands::calibrate_cmd::run(rest),
        "simulate" => commands::simulate_cmd::run(rest),
        "inspect" => commands::inspect_cmd::run(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => Err(Error::msg(format!("unknown command {other:?}; see `tmg help`"))),
    }
}
