//! CRC-32 (IEEE 802.3 polynomial) — shard file integrity checksums.
//!
//! Table-driven implementation; the table is built at compile time
//! (no `once_cell` in the offline crate set).

const TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

/// Incremental CRC-32 hasher (shard writer streams chunks through it).
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(&data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 1024];
        let before = crc32(&data);
        data[512] ^= 0x01;
        assert_ne!(before, crc32(&data));
    }
}
