//! PCG32 — deterministic, seedable, stream-splittable PRNG.
//!
//! Used everywhere randomness is needed on the Rust side: parameter
//! init, synthetic data generation, crop/flip augmentation, property
//! tests.  Determinism matters twice in this reproduction: (a) the two
//! model replicas must start *identical* (paper §2.2 "initialized
//! identically"), and (b) experiments must be re-runnable bit-for-bit.

/// Melissa O'Neill's PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a (seed, stream) pair; different streams are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-argument constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to give each worker,
    /// shard or tensor its own stream from one experiment seed).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let seed = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::new(seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of uniformity.
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias.
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Bernoulli(p) draw.
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped to
    /// keep the generator stateless beyond PCG itself).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-12 {
                let u2 = self.next_f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(0, std^2) draws.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.next_normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be (nearly) disjoint, got {same} collisions");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_range() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 50_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let v = r.next_normal() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg32::seeded(11);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
