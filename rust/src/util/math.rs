//! Numeric helpers shared by params, metrics and the simulator.

/// Ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `m`.
pub fn ceil_to(a: usize, m: usize) -> usize {
    ceil_div(a, m) * m
}

/// Product of a shape vector (element count).
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Max |a - b| over two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// L2 norm of a slice (f64 accumulation).
pub fn l2_norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Mean of a slice (0.0 on empty).
pub fn mean(a: &[f64]) -> f64 {
    if a.is_empty() {
        0.0
    } else {
        a.iter().sum::<f64>() / a.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(a: &[f64]) -> f64 {
    if a.len() < 2 {
        return 0.0;
    }
    let m = mean(a);
    (a.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (a.len() - 1) as f64).sqrt()
}

/// Index of the maximum element (first wins ties; 0 on empty).
pub fn argmax(a: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in a.iter().enumerate() {
        if v > a[best] {
            best = i;
        }
    }
    best
}

/// Relative error between two scalars, floored so near-zero pairs
/// compare absolutely (the gradient-check metric).
pub fn rel_err(a: f32, b: f32) -> f32 {
    (a - b).abs() / a.abs().max(b.abs()).max(1.0)
}

/// Row-major transpose: `x[rows×cols]` → `[cols×rows]`.  Shared by the
/// GEMM test suites to build the nt/tn operand layouts.
pub fn transpose(rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * cols);
    let mut t = vec![0.0; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = x[r * cols + c];
        }
    }
    t
}

/// Allclose with both relative and absolute tolerance (numpy-style).
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_helpers() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_to(10, 8), 16);
        assert_eq!(ceil_to(16, 8), 16);
    }

    #[test]
    fn numel_products() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(numel(&[]), 1);
    }

    #[test]
    fn diff_and_norm() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_first_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn rel_err_floors_small_magnitudes() {
        assert!((rel_err(2.0, 1.0) - 0.5).abs() < 1e-6);
        // Near zero, the denominator floor makes this absolute.
        assert!(rel_err(1e-6, 0.0) < 1e-5);
    }

    #[test]
    fn transpose_round_trips() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let t = transpose(2, 3, &x);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(transpose(3, 2, &t), x);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn allclose_tolerance() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0], 1e-5, 1e-5));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-5));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1.0, 1.0));
    }
}
