//! Human-readable formatting for logs, bench tables and the CLI.

/// Format a byte count with binary units ("3.2 MiB").
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds adaptively ("183 µs", "12.3 ms", "4.56 s").
pub fn secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Format a count with thousands separators ("1,234,567").
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Fixed-width left-padded cell for ASCII tables.
pub fn cell(s: &str, width: usize) -> String {
    format!("{s:>width$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_ranges() {
        assert_eq!(secs(0.5), "500.00 ms");
        assert_eq!(secs(2.0), "2.00 s");
        assert!(secs(1e-5).ends_with("µs"));
        assert!(secs(1e-8).ends_with("ns"));
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(1), "1");
        assert_eq!(count(1234), "1,234");
        assert_eq!(count(1234567), "1,234,567");
    }

    #[test]
    fn cell_pads() {
        assert_eq!(cell("x", 4), "   x");
    }
}
