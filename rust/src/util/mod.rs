//! Small self-contained substrates: deterministic PRNG, a minimal JSON
//! parser (for `artifacts/manifest.json`), CRC32 (shard integrity),
//! wall-clock timers and human formatting.
//!
//! The offline crate set has no `serde`/`rand`/`humantime`, so these
//! are implemented in-repo and unit-tested here.

pub mod crc32;
pub mod fmt;
pub mod json;
pub mod math;
pub mod prng;
pub mod timer;

pub use crc32::crc32;
pub use json::Json;
pub use prng::Pcg32;
pub use timer::Timer;

/// Cores available to this process (1 when the query fails) — the one
/// place the `available_parallelism` fallback policy lives.
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
