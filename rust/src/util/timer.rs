//! Wall-clock timing helpers used by the coordinator, the calibration
//! pass and the bench harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Measure the wall time of a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Median-of-runs measurement used by the bench harness: `warmup` silent
/// iterations, then `runs` timed ones; returns per-run seconds sorted
/// ascending (caller picks median / min / mean).
pub fn measure_runs(warmup: usize, runs: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Timer::start();
        f();
        out.push(t.elapsed_secs());
    }
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out
}

/// Median of an ascending-sorted sample (0.0 on empty input).
pub fn median(sorted: &[f64]) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn measure_runs_counts() {
        let mut calls = 0;
        let v = measure_runs(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(v.len(), 5);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn median_cases() {
        assert_eq!(median(&[]), 0.0);
        assert_eq!(median(&[3.0]), 3.0);
        assert_eq!(median(&[1.0, 3.0]), 2.0);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }
}
