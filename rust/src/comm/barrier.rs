//! Timed step barrier.
//!
//! The paper synchronizes replicas at every minibatch boundary (the
//! exchange itself is blocking); the coordinator additionally uses an
//! explicit barrier at startup and around evals.  This wrapper adds
//! per-handle wait-time accounting so the benches can report
//! synchronization overhead.

use std::sync::{Arc, Barrier};

/// Shared barrier; clone handles across worker threads.
#[derive(Clone)]
pub struct TimedBarrier {
    inner: Arc<Barrier>,
}

/// Per-thread accounting handle.
pub struct BarrierHandle {
    inner: Arc<Barrier>,
    pub waits: u64,
    pub wait_seconds: f64,
}

impl TimedBarrier {
    pub fn new(n: usize) -> Self {
        TimedBarrier { inner: Arc::new(Barrier::new(n)) }
    }

    pub fn handle(&self) -> BarrierHandle {
        BarrierHandle { inner: self.inner.clone(), waits: 0, wait_seconds: 0.0 }
    }
}

impl BarrierHandle {
    /// Wait; returns true on the leader thread of this round.
    pub fn wait(&mut self) -> bool {
        let t = crate::util::Timer::start();
        let res = self.inner.wait();
        self.wait_seconds += t.elapsed_secs();
        self.waits += 1;
        res.is_leader()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc as StdArc;

    #[test]
    fn barrier_synchronizes_phases() {
        let n = 4;
        let barrier = TimedBarrier::new(n);
        let counter = StdArc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..n {
            let mut h = barrier.handle();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                h.wait();
                // After the barrier every increment must be visible.
                assert_eq!(c.load(Ordering::SeqCst), 4);
                h.wait();
                (h.waits, h.wait_seconds)
            }));
        }
        let mut leader_count = 0;
        for h in handles {
            let (waits, secs) = h.join().unwrap();
            assert_eq!(waits, 2);
            assert!(secs >= 0.0);
            leader_count += 0; // leader flag checked implicitly by wait()
        }
        let _ = leader_count;
    }

    #[test]
    fn exactly_one_leader_per_round() {
        let n = 3;
        let barrier = TimedBarrier::new(n);
        let leaders = StdArc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..n {
            let mut h = barrier.handle();
            let l = leaders.clone();
            joins.push(std::thread::spawn(move || {
                if h.wait() {
                    l.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::SeqCst), 1);
    }
}
