//! Chunked ring all-reduce (average) — the N-GPU extension.
//!
//! The paper's pairwise exchange does not scale past 2 GPUs (§4.4,
//! "situations involved with more GPUs are discussed in Krizhevsky
//! (2014)"); this module implements the standard bandwidth-optimal
//! ring from that reference: N-1 reduce-scatter rounds + N-1
//! all-gather rounds over equal chunks, then divide by N.  Used by the
//! E5 scaling study and available to the coordinator for `workers > 2`.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::error::{Error, Result};
use crate::params::average::{accumulate, scale_in_place};

/// One rank's handle: a sender to the next rank and receiver from the
/// previous rank.
pub struct RingNode {
    pub rank: usize,
    pub n: usize,
    tx_next: Sender<(u64, usize, Vec<f32>)>,
    rx_prev: Receiver<(u64, usize, Vec<f32>)>,
    round: u64,
    pub bytes_sent: u64,
}

/// Build a ring of N connected nodes.
pub fn ring(n: usize) -> Vec<RingNode> {
    assert!(n >= 2);
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // Node i sends to (i+1) % n, so it owns txs[(i+1)%n]'s sender side.
    let mut nodes: Vec<Option<RingNode>> = (0..n).map(|_| None).collect();
    let mut rx_iter: Vec<Option<Receiver<_>>> = rxs.into_iter().map(Some).collect();
    for i in 0..n {
        let tx_next = txs[(i + 1) % n].clone();
        let rx_prev = rx_iter[i].take().unwrap();
        nodes[i] = Some(RingNode {
            rank: i,
            n,
            tx_next,
            rx_prev,
            round: 0,
            bytes_sent: 0,
        });
    }
    nodes.into_iter().map(|n| n.unwrap()).collect()
}

/// Chunk boundaries: N nearly-equal spans covering `len`.
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut off = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((off, off + sz));
        off += sz;
    }
    out
}

impl RingNode {
    /// All-reduce `data` to the elementwise mean across ranks.
    /// Every rank must call this with identically-sized buffers.
    pub fn allreduce_average(&mut self, data: &mut [f32]) -> Result<()> {
        let n = self.n;
        let bounds = chunk_bounds(data.len(), n);
        self.round += 1;
        let tag = self.round;

        // Reduce-scatter: after n-1 steps, chunk (rank+1)%n holds the sum.
        for step in 0..n - 1 {
            let send_chunk = (self.rank + n - step) % n;
            let (s0, s1) = bounds[send_chunk];
            self.send(tag, send_chunk, data[s0..s1].to_vec())?;
            let (seq, idx, payload) = self.recv()?;
            self.check(seq, tag, idx, (self.rank + n - step - 1) % n)?;
            let (r0, r1) = bounds[idx];
            accumulate(&mut data[r0..r1], &payload);
        }
        // All-gather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_chunk = (self.rank + 1 + n - step) % n;
            let (s0, s1) = bounds[send_chunk];
            self.send(tag, send_chunk, data[s0..s1].to_vec())?;
            let (seq, idx, payload) = self.recv()?;
            self.check(seq, tag, idx, (self.rank + n - step) % n)?;
            let (r0, r1) = bounds[idx];
            data[r0..r1].copy_from_slice(&payload);
        }
        scale_in_place(data, 1.0 / n as f32);
        Ok(())
    }

    fn send(&mut self, seq: u64, idx: usize, payload: Vec<f32>) -> Result<()> {
        self.bytes_sent += (payload.len() * 4) as u64;
        self.tx_next
            .send((seq, idx, payload))
            .map_err(|_| Error::Protocol("ring neighbour dropped".into()))
    }

    fn recv(&mut self) -> Result<(u64, usize, Vec<f32>)> {
        self.rx_prev
            .recv()
            .map_err(|_| Error::Protocol("ring neighbour dropped".into()))
    }

    fn check(&self, seq: u64, tag: u64, idx: usize, expect_idx: usize) -> Result<()> {
        if seq != tag {
            return Err(Error::Protocol(format!(
                "ring round skew: got {seq}, expected {tag}"
            )));
        }
        if idx != expect_idx {
            return Err(Error::Protocol(format!(
                "ring chunk skew: got chunk {idx}, expected {expect_idx}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ring(n: usize, len: usize) -> Vec<Vec<f32>> {
        let nodes = ring(n);
        let mut joins = Vec::new();
        for (r, mut node) in nodes.into_iter().enumerate() {
            joins.push(std::thread::spawn(move || {
                // Rank r holds the constant vector r+1.
                let mut data = vec![(r + 1) as f32; len];
                node.allreduce_average(&mut data).unwrap();
                data
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn averages_across_ranks() {
        for n in [2, 3, 4, 8] {
            let out = run_ring(n, 37); // non-divisible length
            let want = (1..=n).sum::<usize>() as f32 / n as f32;
            for (r, d) in out.iter().enumerate() {
                assert_eq!(d.len(), 37);
                for &v in d {
                    assert!((v - want).abs() < 1e-5, "rank {r}: {v} vs {want}");
                }
            }
        }
    }

    #[test]
    fn ring_handles_tiny_buffers() {
        let out = run_ring(4, 3); // fewer elements than some chunks
        for d in out {
            for &v in &d {
                assert!((v - 2.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn chunk_bounds_cover() {
        let b = chunk_bounds(10, 3);
        assert_eq!(b, vec![(0, 4), (4, 7), (7, 10)]);
        let b = chunk_bounds(3, 4);
        assert_eq!(b.last().unwrap().1, 3);
    }

    #[test]
    fn bandwidth_counter_matches_theory() {
        // Ring moves 2*(n-1)/n of the buffer per rank.
        let n = 4;
        let len = 1024;
        let nodes = ring(n);
        let joins: Vec<_> = nodes
            .into_iter()
            .map(|mut node| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; len];
                    node.allreduce_average(&mut data).unwrap();
                    node.bytes_sent
                })
            })
            .collect();
        for j in joins {
            let sent = j.join().unwrap() as usize;
            let theory = 2 * (n - 1) * (len / n) * 4;
            assert_eq!(sent, theory);
        }
    }
}
