//! The Fig-2 exchange-and-average engine — the *pairwise* (N = 2)
//! protocol, served to the trainer as the fast path behind
//! [`crate::comm::collective::Collective`] (N-worker jobs use the ring
//! all-reduce in that module instead).
//!
//! Per round, on the two peers symmetrically:
//!
//! 1. the local step produced fresh params/momenta (caller did this);
//! 2. `flatten` + `send`, then `recv` the peer's state — the paper's
//!    cross-GPU copy into the dedicated "peer" shared variable;
//! 3. `average_with_flat` — both sides compute the same midpoint, so
//!    replicas re-synchronize exactly.
//!
//! Sequence numbers implement the paper's §4.3 synchronization
//! workaround: averaging against a stale round is detected, not
//! silently computed.

use std::time::Duration;

use crate::comm::link::{Endpoint, Transport};
use crate::error::Result;
use crate::params::ParamStore;
use crate::util::Timer;

/// Timing/traffic summary of one exchange round.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeStats {
    pub rounds: u64,
    pub bytes_per_round: usize,
    pub flatten_seconds: f64,
    pub transfer_seconds: f64,
    pub average_seconds: f64,
}

impl ExchangeStats {
    pub fn total_seconds(&self) -> f64 {
        self.flatten_seconds + self.transfer_seconds + self.average_seconds
    }
}

/// One worker's handle on the pairwise exchange.
pub struct ExchangePort {
    endpoint: Box<dyn Transport>,
    seq: u64,
    recv_buf: Vec<f32>,
    /// Outgoing staging buffer; ping-pongs with `recv_buf` so the P2P
    /// path performs zero allocations in steady state (§Perf).
    flat_buf: Vec<f32>,
    pub stats: ExchangeStats,
}

impl ExchangePort {
    pub fn new(endpoint: Endpoint) -> Self {
        Self::from_transport(Box::new(endpoint))
    }

    /// Wrap any transport (in-memory link or a socket to the peer).
    pub fn from_transport(endpoint: Box<dyn Transport>) -> Self {
        ExchangePort {
            endpoint,
            seq: 0,
            recv_buf: Vec::new(),
            flat_buf: Vec::new(),
            stats: ExchangeStats::default(),
        }
    }

    /// Bound every subsequent recv (and socket send) by `d`.
    pub fn set_deadline(&mut self, d: Option<Duration>) -> Result<()> {
        self.endpoint.set_deadline(d)
    }

    /// Round counter (must advance in lockstep on both sides).
    pub fn round(&self) -> u64 {
        self.seq
    }

    /// Execute one Fig-2 round on this worker's store.
    pub fn exchange(&mut self, store: &mut ParamStore, include_momentum: bool) -> Result<()> {
        let t0 = Timer::start();
        store.flatten_into(&mut self.flat_buf, include_momentum);
        let bytes = self.flat_buf.len() * 4;
        let t_flat = t0.elapsed_secs();

        let t1 = Timer::start();
        // P2P moves the staging buffer onto the wire (zero-copy); the
        // buffer received from the peer becomes next round's staging
        // buffer, so steady state allocates nothing.
        let outgoing = std::mem::take(&mut self.flat_buf);
        self.endpoint.send_vec(self.seq, outgoing)?;
        self.endpoint.recv(self.seq, &mut self.recv_buf)?;
        let t_xfer = t1.elapsed_secs();

        let t2 = Timer::start();
        store.average_with_flat(&self.recv_buf, include_momentum)?;
        let t_avg = t2.elapsed_secs();
        std::mem::swap(&mut self.flat_buf, &mut self.recv_buf);

        self.stats.rounds += 1;
        self.stats.bytes_per_round = bytes;
        self.stats.flatten_seconds += t_flat;
        self.stats.transfer_seconds += t_xfer;
        self.stats.average_seconds += t_avg;
        self.seq += 1;
        Ok(())
    }

    /// Pairwise all-reduce-average of a raw flat buffer — the bucketed
    /// gradient-exchange primitive.  Both sides send their slice, recv
    /// the peer's, and overwrite `data` with the elementwise midpoint
    /// `0.5 * (a + b)` (f32 addition is commutative, so both ranks
    /// compute identical bits).  Shares the round counter with
    /// [`Self::exchange`], so per-bucket skew is detected the same way.
    pub fn exchange_flat(&mut self, data: &mut [f32]) -> Result<()> {
        let t0 = Timer::start();
        self.flat_buf.clear();
        self.flat_buf.extend_from_slice(data);
        let bytes = self.flat_buf.len() * 4;
        let t_flat = t0.elapsed_secs();

        let t1 = Timer::start();
        let outgoing = std::mem::take(&mut self.flat_buf);
        self.endpoint.send_vec(self.seq, outgoing)?;
        self.endpoint.recv(self.seq, &mut self.recv_buf)?;
        let t_xfer = t1.elapsed_secs();

        if self.recv_buf.len() != data.len() {
            return Err(crate::error::Error::Protocol(format!(
                "pair bucket: received {} values, expected {}",
                self.recv_buf.len(),
                data.len()
            )));
        }
        let t2 = Timer::start();
        for (a, &b) in data.iter_mut().zip(&self.recv_buf) {
            *a = 0.5 * (*a + b);
        }
        let t_avg = t2.elapsed_secs();
        std::mem::swap(&mut self.flat_buf, &mut self.recv_buf);

        self.stats.rounds += 1;
        self.stats.bytes_per_round = bytes;
        self.stats.flatten_seconds += t_flat;
        self.stats.transfer_seconds += t_xfer;
        self.stats.average_seconds += t_avg;
        self.seq += 1;
        Ok(())
    }

    /// Link-layer counters.
    pub fn link_stats(&self) -> crate::comm::link::LinkStats {
        self.endpoint.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::link::transport_pair;
    use crate::config::TransportKind;
    use crate::runtime::artifact::ParamManifestSpec;
    use crate::tensor::Shape;

    fn specs() -> Vec<ParamManifestSpec> {
        vec![ParamManifestSpec {
            name: "w".into(),
            shape: Shape::of(&[64, 32]),
            init: "normal".into(),
            std: 0.1,
            bias_value: 0.0,
        }]
    }

    /// Drive both sides of an exchange from two threads.
    fn run_symmetric(kind: TransportKind, rounds: usize, include_momentum: bool) -> (ParamStore, ParamStore) {
        let (ea, eb) = transport_pair(kind);
        let mut store_a = ParamStore::init(&specs(), 1);
        let mut store_b = ParamStore::init(&specs(), 1);
        // Desynchronize the replicas as local steps would.
        for v in store_a.params[0].as_mut_slice() {
            *v += 0.5;
        }
        for v in store_b.momenta[0].as_mut_slice() {
            *v -= 0.25;
        }
        let hb = std::thread::spawn(move || {
            let mut port = ExchangePort::new(eb);
            for _ in 0..rounds {
                port.exchange(&mut store_b, include_momentum).unwrap();
            }
            store_b
        });
        let mut port = ExchangePort::new(ea);
        for _ in 0..rounds {
            port.exchange(&mut store_a, include_momentum).unwrap();
        }
        assert_eq!(port.round(), rounds as u64);
        (store_a, hb.join().unwrap())
    }

    #[test]
    fn replicas_converge_after_one_round() {
        for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
            let (a, b) = run_symmetric(kind, 1, true);
            assert!(
                a.max_divergence(&b) < 1e-7,
                "replicas disagree after exchange over {kind:?}"
            );
        }
    }

    #[test]
    fn average_is_midpoint() {
        let (ea, eb) = transport_pair(TransportKind::P2p);
        let mut a = ParamStore::init(&specs(), 1);
        let mut b = ParamStore::init(&specs(), 1);
        for v in a.params[0].as_mut_slice() {
            *v = 1.0;
        }
        for v in b.params[0].as_mut_slice() {
            *v = 3.0;
        }
        let hb = std::thread::spawn(move || {
            let mut port = ExchangePort::new(eb);
            port.exchange(&mut b, true).unwrap();
            b
        });
        let mut port = ExchangePort::new(ea);
        port.exchange(&mut a, true).unwrap();
        let b = hb.join().unwrap();
        assert!(a.params[0].as_slice().iter().all(|&v| (v - 2.0).abs() < 1e-7));
        assert!(b.params[0].as_slice().iter().all(|&v| (v - 2.0).abs() < 1e-7));
    }

    #[test]
    fn momentum_excluded_when_configured() {
        let (a, b) = run_symmetric(TransportKind::P2p, 1, false);
        // Params converge, momenta still differ.
        let pdiff = crate::util::math::max_abs_diff(
            a.params[0].as_slice(),
            b.params[0].as_slice(),
        );
        let mdiff = crate::util::math::max_abs_diff(
            a.momenta[0].as_slice(),
            b.momenta[0].as_slice(),
        );
        assert!(pdiff < 1e-7);
        assert!(mdiff > 0.1);
    }

    #[test]
    fn stats_accumulate() {
        let (a, _b) = run_symmetric(TransportKind::Serialized, 3, true);
        let _ = a;
        // run_symmetric asserts protocol success; stats sanity below on
        // a fresh pair (the port from run_symmetric is consumed).
        let (ea, eb) = transport_pair(TransportKind::P2p);
        let mut sa = ParamStore::init(&specs(), 1);
        let mut sb = ParamStore::init(&specs(), 1);
        let hb = std::thread::spawn(move || {
            let mut port = ExchangePort::new(eb);
            port.exchange(&mut sb, true).unwrap();
        });
        let mut port = ExchangePort::new(ea);
        port.exchange(&mut sa, true).unwrap();
        hb.join().unwrap();
        assert_eq!(port.stats.rounds, 1);
        assert_eq!(port.stats.bytes_per_round, 64 * 32 * 2 * 4);
        assert!(port.stats.total_seconds() > 0.0);
    }
}
