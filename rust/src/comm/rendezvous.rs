//! Distributed rendezvous: assemble the ring collective across OS
//! processes over TCP.
//!
//! Every rank is given the same ordered peer list (`peers[i]` = the
//! listen address of rank `i`).  Rendezvous builds the two directed
//! ring links of this rank — `rank -> rank+1` (outbound connect) and
//! `rank-1 -> rank` (inbound accept) — with a handshake that fails
//! *loudly* instead of hanging or silently mis-pairing:
//!
//! 1. bind the local listener (before connecting out, so a peer's
//!    early connect lands in the backlog instead of being refused);
//! 2. connect to the next rank with bounded retry + exponential
//!    backoff, and immediately send the local [`Hello`];
//! 3. accept the previous rank's connection under a deadline, read its
//!    `Hello`, validate every field (version, ring position, world
//!    size, config fingerprint, resume step), and reply with the local
//!    `Hello` as the acknowledgement;
//! 4. read the next rank's acknowledgement on the outbound link and
//!    validate it the same way.
//!
//! Because every rank sends its `Hello` *before* blocking on accept,
//! and the acknowledgement is produced by the peer's accept phase, the
//! schedule has no circular wait for any N.  Any mismatch is an
//! [`Error::Protocol`] naming the offending field; any absent peer is
//! an [`Error::Timeout`] naming the rank and the exhausted budget.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::comm::collective::RingCollective;
use crate::comm::link::{TcpEndpoint, Transport};
use crate::error::{Error, Result};

/// Bumped whenever the frame or handshake layout changes; peers with a
/// different version refuse to pair.
pub const PROTOCOL_VERSION: u32 = 1;

/// Resume step value meaning "fresh run, no checkpoint".
pub const FRESH_RUN: u64 = u64::MAX;

const MAGIC: [u8; 4] = *b"TMGD";
const HELLO_BYTES: usize = 32;

/// The handshake payload every rank presents on both of its links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub version: u32,
    pub rank: u32,
    pub world: u32,
    /// `TrainConfig::resume_fingerprint()` — ranks running drifted
    /// configs must not form a ring.
    pub fingerprint: u64,
    /// Step the run resumes from ([`FRESH_RUN`] = from scratch); ranks
    /// that resolved different checkpoint sets must not form a ring.
    pub resume_step: u64,
}

fn encode_hello(h: &Hello) -> [u8; HELLO_BYTES] {
    let mut buf = [0u8; HELLO_BYTES];
    buf[0..4].copy_from_slice(&MAGIC);
    buf[4..8].copy_from_slice(&h.version.to_le_bytes());
    buf[8..12].copy_from_slice(&h.rank.to_le_bytes());
    buf[12..16].copy_from_slice(&h.world.to_le_bytes());
    buf[16..24].copy_from_slice(&h.fingerprint.to_le_bytes());
    buf[24..32].copy_from_slice(&h.resume_step.to_le_bytes());
    buf
}

fn decode_hello(buf: &[u8; HELLO_BYTES]) -> Result<Hello> {
    if buf[0..4] != MAGIC {
        return Err(Error::Protocol(
            "handshake: bad magic — the peer is not a tmg distributed worker".into(),
        ));
    }
    Ok(Hello {
        version: u32::from_le_bytes(buf[4..8].try_into().unwrap()),
        rank: u32::from_le_bytes(buf[8..12].try_into().unwrap()),
        world: u32::from_le_bytes(buf[12..16].try_into().unwrap()),
        fingerprint: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
        resume_step: u64::from_le_bytes(buf[24..32].try_into().unwrap()),
    })
}

fn fmt_step(step: u64) -> String {
    if step == FRESH_RUN {
        "<fresh run>".into()
    } else {
        format!("step {step}")
    }
}

/// Check a peer's `Hello` against ours and its expected ring position.
/// Every rejection names the mismatched field and both values.
pub fn validate_hello(peer: &Hello, expected_rank: u32, local: &Hello) -> Result<()> {
    if peer.version != local.version {
        return Err(Error::Protocol(format!(
            "handshake: protocol version skew: peer rank {} speaks v{}, \
             this build speaks v{}",
            peer.rank, peer.version, local.version
        )));
    }
    if peer.world != local.world {
        return Err(Error::Protocol(format!(
            "handshake: world-size mismatch: peer rank {} expects a \
             {}-rank ring, this run has {} ranks",
            peer.rank, peer.world, local.world
        )));
    }
    if peer.rank != expected_rank {
        return Err(Error::Protocol(format!(
            "handshake: ring position mismatch: this link expects rank \
             {expected_rank}, the peer claims rank {} — check the peer \
             list ordering",
            peer.rank
        )));
    }
    if peer.fingerprint != local.fingerprint {
        return Err(Error::Protocol(format!(
            "handshake: config fingerprint mismatch: peer rank {} has \
             {:#018x}, local is {:#018x} — resume-critical config \
             drifted between ranks",
            peer.rank, peer.fingerprint, local.fingerprint
        )));
    }
    if peer.resume_step != local.resume_step {
        return Err(Error::Protocol(format!(
            "handshake: resume-step mismatch: peer rank {} starts at {}, \
             this rank at {} — the ranks resolved different checkpoint \
             sets (share one checkpoint dir, or clean stale snapshots)",
            peer.rank,
            fmt_step(peer.resume_step),
            fmt_step(local.resume_step)
        )));
    }
    Ok(())
}

/// Connect to `addr` with exponential backoff until `budget` runs out.
fn connect_with_backoff(addr: &str, budget: Duration) -> Result<TcpStream> {
    let start = Instant::now();
    let mut delay = Duration::from_millis(25);
    let mut last_err = String::from("address did not resolve");
    loop {
        let remaining = budget.saturating_sub(start.elapsed());
        if remaining.is_zero() {
            return Err(Error::Timeout(format!(
                "rendezvous: could not connect to peer {addr} within \
                 {budget:?} (last error: {last_err}) — is that rank up?"
            )));
        }
        match addr.to_socket_addrs() {
            Ok(mut addrs) => match addrs.next() {
                Some(sock) => match TcpStream::connect_timeout(&sock, remaining) {
                    Ok(s) => return Ok(s),
                    Err(e) => last_err = e.to_string(),
                },
                None => {
                    return Err(Error::Config(format!(
                        "rendezvous: peer address {addr:?} resolves to nothing"
                    )))
                }
            },
            Err(e) => last_err = e.to_string(),
        }
        std::thread::sleep(delay.min(budget.saturating_sub(start.elapsed())));
        delay = (delay * 2).min(Duration::from_secs(1));
    }
}

/// Accept one connection under a deadline (std listeners have no
/// native accept timeout, so poll in non-blocking mode).
fn accept_within(listener: &TcpListener, budget: Duration, from_rank: usize) -> Result<TcpStream> {
    listener.set_nonblocking(true).map_err(Error::RawIo)?;
    let start = Instant::now();
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false).map_err(Error::RawIo)?;
                return Ok(stream);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if start.elapsed() >= budget {
                    return Err(Error::Timeout(format!(
                        "rendezvous: no connection from rank {from_rank} \
                         within {budget:?} — is that rank up?"
                    )));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(Error::RawIo(e)),
        }
    }
}

fn write_hello(stream: &mut TcpStream, hello: &Hello, what: &str) -> Result<()> {
    stream.write_all(&encode_hello(hello)).map_err(|e| match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            Error::Timeout(format!("handshake: sending {what} missed its deadline"))
        }
        _ => Error::RawIo(e),
    })
}

fn read_hello(stream: &mut TcpStream, what: &str) -> Result<Hello> {
    let mut buf = [0u8; HELLO_BYTES];
    stream.read_exact(&mut buf).map_err(|e| match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            Error::Timeout(format!("handshake: waiting for {what} missed its deadline"))
        }
        ErrorKind::UnexpectedEof => Error::Protocol(format!(
            "handshake: peer closed the connection before sending its {what} \
             (its own handshake validation probably failed — check its log)"
        )),
        _ => Error::RawIo(e),
    })?;
    decode_hello(&buf)
}

/// Everything rendezvous needs from the run configuration.
pub struct RendezvousCfg<'a> {
    /// This process's rank (index into `peers`).
    pub rank: usize,
    /// `peers[i]` = listen address (`host:port`) of rank `i`.
    pub peers: &'a [String],
    /// `TrainConfig::resume_fingerprint()` of the local config.
    pub fingerprint: u64,
    /// Resolved resume step, [`FRESH_RUN`] when starting from scratch.
    pub resume_step: u64,
    /// Budget for each of: outbound connect (with backoff), inbound
    /// accept, and each handshake read/write.
    pub connect_timeout: Duration,
    /// Steady-state per-message deadline installed on both links.
    pub io_timeout: Duration,
}

/// Run the rendezvous and return this rank's node of the TCP ring.
///
/// For a 2-rank world this is still a ring (two directed socket
/// links); the N = 2 ring schedule is bit-identical to the in-memory
/// pairwise exchange, so loopback-TCP runs reproduce in-memory runs
/// exactly.
pub fn ring_over_tcp(rc: &RendezvousCfg) -> Result<RingCollective> {
    let n = rc.peers.len();
    if n < 2 {
        return Err(Error::Config(format!(
            "rendezvous: a distributed ring needs at least 2 peers, got {n}"
        )));
    }
    if rc.rank >= n {
        return Err(Error::Config(format!(
            "rendezvous: rank {} out of range for a {n}-peer ring",
            rc.rank
        )));
    }
    let local = Hello {
        version: PROTOCOL_VERSION,
        rank: rc.rank as u32,
        world: n as u32,
        fingerprint: rc.fingerprint,
        resume_step: rc.resume_step,
    };
    let next = (rc.rank + 1) % n;
    let prev = (rc.rank + n - 1) % n;

    // 1. Bind first: a peer connecting before we accept parks in the
    //    listener backlog instead of being refused.
    let listen_addr = &rc.peers[rc.rank];
    let listener = TcpListener::bind(listen_addr).map_err(|e| {
        Error::Config(format!(
            "rendezvous: rank {} cannot listen on {listen_addr:?}: {e}",
            rc.rank
        ))
    })?;
    log::info!("rendezvous: rank {} listening on {listen_addr}", rc.rank);

    // 2. Outbound link to the next rank; announce ourselves at once so
    //    the peer's accept phase never waits on ours.
    let mut to_next = connect_with_backoff(&rc.peers[next], rc.connect_timeout)?;
    to_next.set_write_timeout(Some(rc.connect_timeout)).map_err(Error::RawIo)?;
    to_next.set_read_timeout(Some(rc.connect_timeout)).map_err(Error::RawIo)?;
    write_hello(&mut to_next, &local, "hello")?;

    // 3. Inbound link from the previous rank: validate, then ack.
    let mut from_prev = accept_within(&listener, rc.connect_timeout, prev)?;
    from_prev.set_read_timeout(Some(rc.connect_timeout)).map_err(Error::RawIo)?;
    from_prev.set_write_timeout(Some(rc.connect_timeout)).map_err(Error::RawIo)?;
    let prev_hello = read_hello(&mut from_prev, "hello")?;
    validate_hello(&prev_hello, prev as u32, &local)?;
    write_hello(&mut from_prev, &local, "acknowledgement")?;

    // 4. The next rank's accept phase acks our outbound hello.
    let next_hello = read_hello(&mut to_next, "acknowledgement")?;
    validate_hello(&next_hello, next as u32, &local)?;

    let mut to_next = TcpEndpoint::new(to_next)?;
    let mut from_prev = TcpEndpoint::new(from_prev)?;
    to_next.set_deadline(Some(rc.io_timeout))?;
    from_prev.set_deadline(Some(rc.io_timeout))?;
    log::info!(
        "rendezvous: rank {} of {n} joined the ring (next: {}, prev: {}, \
         io deadline {:?})",
        rc.rank,
        rc.peers[next],
        rc.peers[prev],
        rc.io_timeout
    );
    Ok(RingCollective::from_transports(rc.rank, n, Box::new(to_next), Box::new(from_prev)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::Collective;

    fn local(rank: u32, world: u32) -> Hello {
        Hello { version: PROTOCOL_VERSION, rank, world, fingerprint: 0xfeed, resume_step: FRESH_RUN }
    }

    #[test]
    fn hello_roundtrips() {
        let h = Hello {
            version: 3,
            rank: 7,
            world: 9,
            fingerprint: 0xdead_beef_cafe_f00d,
            resume_step: 42,
        };
        assert_eq!(decode_hello(&encode_hello(&h)).unwrap(), h);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = encode_hello(&local(0, 2));
        buf[0] = b'X';
        let err = decode_hello(&buf).unwrap_err();
        assert!(format!("{err}").contains("bad magic"), "{err}");
    }

    #[test]
    fn version_skew_rejected_with_named_field() {
        let me = local(0, 2);
        let mut peer = local(1, 2);
        peer.version += 1;
        let err = validate_hello(&peer, 1, &me).unwrap_err();
        let msg = format!("{err}");
        assert!(matches!(err, Error::Protocol(_)));
        assert!(msg.contains("protocol version skew"), "{msg}");
        assert!(msg.contains("v2") && msg.contains("v1"), "{msg}");
    }

    #[test]
    fn world_size_mismatch_rejected_with_named_field() {
        let me = local(0, 2);
        let peer = local(1, 3);
        let err = validate_hello(&peer, 1, &me).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("world-size mismatch"), "{msg}");
        assert!(msg.contains("3-rank") && msg.contains("2 ranks"), "{msg}");
    }

    #[test]
    fn ring_position_mismatch_rejected() {
        let me = local(0, 4);
        let peer = local(2, 4);
        let err = validate_hello(&peer, 3, &me).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("ring position mismatch"), "{msg}");
        assert!(msg.contains("expects rank 3"), "{msg}");
    }

    #[test]
    fn fingerprint_drift_rejected_with_both_values() {
        let me = local(0, 2);
        let mut peer = local(1, 2);
        peer.fingerprint = 0xbad;
        let err = validate_hello(&peer, 1, &me).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("config fingerprint mismatch"), "{msg}");
        assert!(msg.contains("0x0000000000000bad"), "{msg}");
        assert!(msg.contains("0x000000000000feed"), "{msg}");
    }

    #[test]
    fn resume_step_drift_rejected() {
        let mut me = local(0, 2);
        me.resume_step = 4;
        let mut peer = local(1, 2);
        peer.resume_step = 6;
        let err = validate_hello(&peer, 1, &me).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("resume-step mismatch"), "{msg}");
        assert!(msg.contains("step 6") && msg.contains("step 4"), "{msg}");
    }

    /// Reserve `n` distinct loopback ports (bind :0, record, release).
    fn free_addrs(n: usize) -> Vec<String> {
        let holds: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        holds.iter().map(|l| l.local_addr().unwrap().to_string()).collect()
    }

    fn rc(rank: usize, peers: &[String], fingerprint: u64) -> RendezvousCfg<'_> {
        RendezvousCfg {
            rank,
            peers,
            fingerprint,
            resume_step: FRESH_RUN,
            connect_timeout: Duration::from_secs(10),
            io_timeout: Duration::from_secs(10),
        }
    }

    #[test]
    fn two_rank_rendezvous_forms_a_working_ring() {
        let peers = free_addrs(2);
        let peers1 = peers.clone();
        let h = std::thread::spawn(move || {
            let mut node = ring_over_tcp(&rc(1, &peers1, 7)).unwrap();
            let mut data = vec![2.0f32; 11];
            node.all_reduce_flat(&mut data).unwrap();
            data
        });
        let mut node = ring_over_tcp(&rc(0, &peers, 7)).unwrap();
        assert_eq!(node.world_size(), 2);
        let mut data = vec![1.0f32; 11];
        node.all_reduce_flat(&mut data).unwrap();
        let peer_data = h.join().unwrap();
        assert!(data.iter().all(|&v| v == 1.5), "{data:?}");
        assert_eq!(data, peer_data);
    }

    #[test]
    fn three_rank_rendezvous_averages_exactly() {
        let peers = free_addrs(3);
        let mut joins = Vec::new();
        for rank in 0..3 {
            let peers = peers.clone();
            joins.push(std::thread::spawn(move || {
                let mut node = ring_over_tcp(&rc(rank, &peers, 9)).unwrap();
                let mut data = vec![(rank + 1) as f32; 10];
                node.all_reduce_flat(&mut data).unwrap();
                data
            }));
        }
        for j in joins {
            let data = j.join().unwrap();
            assert!(data.iter().all(|&v| v == 2.0), "{data:?}");
        }
    }

    #[test]
    fn fingerprint_drift_fails_both_ranks_loudly() {
        let peers = free_addrs(2);
        let peers1 = peers.clone();
        let h = std::thread::spawn(move || ring_over_tcp(&rc(1, &peers1, 0xaaaa)).map(|_| ()));
        let err = ring_over_tcp(&rc(0, &peers, 0xbbbb)).map(|_| ()).unwrap_err();
        assert!(
            format!("{err}").contains("config fingerprint mismatch"),
            "rank 0 error: {err}"
        );
        // Rank 1 must also reject — it validates the same hello fields
        // in its own accept phase; either it sees the drift itself or
        // the already-failed peer's closed socket. No partial ring.
        let peer = h.join().unwrap();
        assert!(peer.is_err(), "rank 1 formed half a ring from a drifted config");
    }

    /// A scripted impostor: accepts the victim's outbound link, then
    /// connects back presenting an arbitrary crafted hello.
    fn impostor(
        listen_on: TcpListener,
        target: String,
        crafted: [u8; HELLO_BYTES],
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let (mut inbound, _) = listen_on.accept().unwrap();
            let mut victim_hello = [0u8; HELLO_BYTES];
            inbound.read_exact(&mut victim_hello).unwrap();
            let mut outbound = connect_with_backoff(&target, Duration::from_secs(10)).unwrap();
            outbound.write_all(&crafted).unwrap();
            // Hold the sockets open until the victim has judged the
            // hello, so it never sees EOF instead of the bad field.
            std::thread::sleep(Duration::from_millis(300));
        })
    }

    #[test]
    fn wire_version_skew_rejected_no_hang_no_partial_ring() {
        let peers = free_addrs(2);
        // Re-bind rank 1's reserved address for the impostor.
        let fake_listener = TcpListener::bind(&peers[1]).unwrap();
        let mut crafted = local(1, 2);
        crafted.version = PROTOCOL_VERSION + 1;
        let h = impostor(fake_listener, peers[0].clone(), encode_hello(&crafted));
        let err = ring_over_tcp(&rc(0, &peers, 0xfeed)).map(|_| ()).unwrap_err();
        assert!(format!("{err}").contains("protocol version skew"), "{err}");
        h.join().unwrap();
    }

    #[test]
    fn wire_world_size_mismatch_rejected_no_hang() {
        let peers = free_addrs(2);
        let fake_listener = TcpListener::bind(&peers[1]).unwrap();
        // The impostor believes the ring has 3 ranks.
        let crafted = local(1, 3);
        let h = impostor(fake_listener, peers[0].clone(), encode_hello(&crafted));
        let err = ring_over_tcp(&rc(0, &peers, 0xfeed)).map(|_| ()).unwrap_err();
        assert!(format!("{err}").contains("world-size mismatch"), "{err}");
        h.join().unwrap();
    }

    #[test]
    fn absent_peer_times_out_within_budget() {
        let peers = free_addrs(2);
        let mut cfg = rc(0, &peers, 1);
        cfg.connect_timeout = Duration::from_millis(200);
        let start = Instant::now();
        let err = ring_over_tcp(&cfg).map(|_| ()).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "{err:?}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "backoff did not respect its budget: {:?}",
            start.elapsed()
        );
    }
}
