//! Bucketed gradient exchange, streamed concurrently with backward.
//!
//! Theano-MPI's framework-level lever on top of the paper's exchange:
//! once the per-replica kernels are fast, the next win is hiding the
//! collective behind the backward pass.  The flat parameter layout is
//! cut into fixed-size *buckets* whose boundaries derive only from the
//! layout (`total_elems`, `bucket_elems`) — never from timing or
//! thread count — and each bucket is all-reduced as soon as backward
//! has produced every gradient inside it.  Backward emits gradients in
//! reverse layout order (out.b, out.w, …, conv1.b, conv1.w), so the
//! ready region grows contiguously from the end of the layout and
//! buckets complete in fixed descending index order.
//!
//! Determinism: every rank pushes the same buckets in the same
//! descending order through the same collective schedule, so the
//! sequence-number stream, the summation order and therefore the
//! resulting bits are independent of comm timing.  [`StreamMode`]
//! (dedicated comm thread, reductions concurrent with the remaining
//! backward) and the serial mode (reduce everything at the join
//! barrier) are bit-identical by construction — the serial mode *is*
//! the non-overlapped baseline the benches compare against.
//!
//! Fault surface: the exchanger adds no timeouts of its own, but when
//! the wrapped collective's links carry an I/O deadline
//! (`Collective::set_io_deadline` — always set in distributed mode) a
//! dead or stalled peer turns into an [`Error::Timeout`] delivered at
//! the [`GradExchanger::join`] barrier, never a silent hang of the
//! step loop.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use crate::comm::collective::{Collective, CollectiveStats};
use crate::error::{Error, Result};
use crate::util::Timer;

/// Bucket boundaries: fixed-size spans `[b*B, min((b+1)*B, total))`
/// covering the flat gradient layout.  A pure function of the layout —
/// every rank derives identical bounds from its own config.
pub fn bucket_bounds(total_elems: usize, bucket_elems: usize) -> Vec<(usize, usize)> {
    assert!(bucket_elems > 0, "bucket_elems must be positive");
    if total_elems == 0 {
        return Vec::new();
    }
    let n = total_elems.div_ceil(bucket_elems);
    (0..n)
        .map(|b| (b * bucket_elems, ((b + 1) * bucket_elems).min(total_elems)))
        .collect()
}

/// One reduced bucket coming back from the comm thread.
struct BucketDone {
    bucket: usize,
    data: Vec<f32>,
    /// Wall time the comm thread spent reducing this bucket.
    busy_seconds: f64,
    round: CollectiveStats,
}

/// The dedicated comm thread's handle: buckets go out in fixed order,
/// averaged buckets come back as they complete.
struct StreamMode {
    to_comm: Sender<(usize, Vec<f32>)>,
    from_comm: Receiver<Result<BucketDone>>,
    handle: Option<JoinHandle<()>>,
}

enum Mode {
    /// Reductions run on a dedicated comm thread, concurrent with the
    /// rest of backward.
    Stream(StreamMode),
    /// Same buckets, same order, reduced inline at the join barrier —
    /// the measured compute-then-exchange baseline.
    Serial(Box<dyn Collective>),
}

/// One worker's handle on the bucketed gradient exchange.
///
/// Per step: backward drives [`GradExchanger::grad_ready`] with each
/// finished gradient (reverse layout order, contiguous from the end);
/// completed buckets are handed to the collective immediately.
/// [`GradExchanger::join`] is the pre-update barrier: it blocks until
/// every bucket of the round holds the group mean and returns the full
/// averaged gradient buffer for `apply_update`.
pub struct GradExchanger {
    bounds: Vec<(usize, usize)>,
    total_elems: usize,
    /// Flat gradient staging in layout order; averaged in place by the
    /// time `join` returns.
    stage: Vec<f32>,
    /// Readiness watermark: `stage[ready_from..]` holds final
    /// gradients.  Descends from `total_elems` to 0 each round.
    ready_from: usize,
    /// Next bucket to hand to the collective (descending; the round is
    /// fully pushed once it underflows to `None`).
    next_push: Option<usize>,
    /// Recycled bucket buffers (§Perf: steady state allocates nothing).
    free: Vec<Vec<f32>>,
    mode: Mode,
    stats: CollectiveStats,
}

impl GradExchanger {
    /// Wrap `collective` for a layout of `total_elems` gradients cut
    /// into `bucket_elems`-sized buckets.  `stream: true` spawns the
    /// dedicated comm thread which owns the collective for the run;
    /// `false` keeps reductions inline at the join barrier.
    pub fn new(
        collective: Box<dyn Collective>,
        total_elems: usize,
        bucket_elems: usize,
        stream: bool,
    ) -> Self {
        let bounds = bucket_bounds(total_elems, bucket_elems);
        let next_push = bounds.len().checked_sub(1);
        let mode = if stream {
            let (to_comm, rx) = channel::<(usize, Vec<f32>)>();
            let (tx_done, from_comm) = channel::<Result<BucketDone>>();
            let mut collective = collective;
            let handle = std::thread::Builder::new()
                .name("tmg-comm".into())
                .spawn(move || {
                    while let Ok((bucket, mut data)) = rx.recv() {
                        let t = Timer::start();
                        let res = collective.all_reduce_flat(&mut data);
                        let busy_seconds = t.elapsed_secs();
                        let msg = res.map(|round| BucketDone {
                            bucket,
                            data,
                            busy_seconds,
                            round,
                        });
                        let failed = msg.is_err();
                        if tx_done.send(msg).is_err() || failed {
                            // Receiver gone or the fabric broke: stop
                            // consuming; the worker sees the error (or
                            // a disconnect) at the join barrier.
                            return;
                        }
                    }
                })
                .expect("spawn comm thread");
            Mode::Stream(StreamMode { to_comm, from_comm, handle: Some(handle) })
        } else {
            Mode::Serial(collective)
        };
        GradExchanger {
            bounds,
            total_elems,
            stage: vec![0.0; total_elems],
            ready_from: total_elems,
            next_push,
            free: Vec::new(),
            mode,
            stats: CollectiveStats::default(),
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.bounds.len()
    }

    /// Accept one finished gradient at `offset` in the flat layout.
    /// Gradients must arrive contiguously from the end of the layout
    /// (backward order); any gap or reorder is a protocol error, since
    /// it would let a bucket ship with stale contents.
    pub fn grad_ready(&mut self, offset: usize, grad: &[f32]) -> Result<()> {
        if offset + grad.len() != self.ready_from {
            return Err(Error::Protocol(format!(
                "grad_ready out of order: got [{}, {}), ready watermark at {}",
                offset,
                offset + grad.len(),
                self.ready_from
            )));
        }
        self.stage[offset..self.ready_from].copy_from_slice(grad);
        self.ready_from = offset;
        self.push_ready_buckets()
    }

    /// Hand every fully-ready, not-yet-pushed bucket to the collective,
    /// in fixed descending index order.
    fn push_ready_buckets(&mut self) -> Result<()> {
        while let Some(b) = self.next_push {
            let (lo, hi) = self.bounds[b];
            if lo < self.ready_from {
                break;
            }
            match &mut self.mode {
                Mode::Stream(s) => {
                    let mut buf = self.free.pop().unwrap_or_default();
                    buf.clear();
                    buf.extend_from_slice(&self.stage[lo..hi]);
                    s.to_comm.send((b, buf)).map_err(|_| {
                        Error::Protocol("comm thread terminated before the round finished".into())
                    })?;
                }
                // Serial: nothing to do yet — the data sits in `stage`
                // until the join barrier reduces it in the same order.
                Mode::Serial(_) => {}
            }
            self.next_push = b.checked_sub(1);
        }
        Ok(())
    }

    /// The pre-update barrier: block until every bucket of the round
    /// holds the group mean, then return the averaged flat gradients.
    /// Resets the readiness watermark for the next round.
    pub fn join(&mut self) -> Result<&[f32]> {
        if self.ready_from != 0 || self.next_push.is_some() {
            return Err(Error::Protocol(format!(
                "join before the round is complete: watermark at {}, {} buckets unpushed",
                self.ready_from,
                self.next_push.map_or(0, |b| b + 1)
            )));
        }
        let n = self.bounds.len();
        match &mut self.mode {
            Mode::Stream(s) => {
                let mut remaining = n;
                // Buckets that finished while backward was still
                // running are pure overlap: their comm time was hidden.
                loop {
                    match s.from_comm.try_recv() {
                        Ok(done) => {
                            let done = done?;
                            let (lo, hi) = self.bounds[done.bucket];
                            self.stage[lo..hi].copy_from_slice(&done.data);
                            self.free.push(done.data);
                            self.stats.overlapped_seconds += done.busy_seconds;
                            self.stats.absorb(&done.round);
                            remaining -= 1;
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            return Err(Error::Protocol("comm thread terminated".into()))
                        }
                    }
                }
                // Whatever is still in flight is exposed: the step
                // waits for it here, wall-clock.
                let t = Timer::start();
                while remaining > 0 {
                    let done = s
                        .from_comm
                        .recv()
                        .map_err(|_| Error::Protocol("comm thread terminated".into()))??;
                    let (lo, hi) = self.bounds[done.bucket];
                    self.stage[lo..hi].copy_from_slice(&done.data);
                    self.free.push(done.data);
                    self.stats.absorb(&done.round);
                    remaining -= 1;
                }
                self.stats.exposed_seconds += t.elapsed_secs();
            }
            Mode::Serial(collective) => {
                let t = Timer::start();
                for b in (0..n).rev() {
                    let (lo, hi) = self.bounds[b];
                    let round = collective.all_reduce_flat(&mut self.stage[lo..hi])?;
                    self.stats.absorb(&round);
                }
                self.stats.exposed_seconds += t.elapsed_secs();
            }
        }
        self.stats.rounds += 1;
        self.ready_from = self.total_elems;
        self.next_push = n.checked_sub(1);
        Ok(&self.stage)
    }

    /// Cumulative stats across all rounds so far.
    pub fn stats(&self) -> CollectiveStats {
        self.stats
    }

    /// Shut down (joining the comm thread in stream mode) and return
    /// the cumulative stats.
    pub fn finish(self) -> Result<CollectiveStats> {
        let GradExchanger { mode, stats, .. } = self;
        if let Mode::Stream(StreamMode { to_comm, from_comm, handle }) = mode {
            // Closing the bucket channel is the shutdown signal.
            drop(to_comm);
            drop(from_comm);
            if let Some(h) = handle {
                h.join()
                    .map_err(|_| Error::Protocol("comm thread panicked".into()))?;
            }
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::{build_fabric, NoopCollective};
    use crate::config::TransportKind;

    #[test]
    fn bounds_tile_the_layout_exactly() {
        assert_eq!(bucket_bounds(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(bucket_bounds(8, 4), vec![(0, 4), (4, 8)]);
        assert_eq!(bucket_bounds(3, 100), vec![(0, 3)]);
        assert_eq!(bucket_bounds(0, 4), vec![]);
        let b = bucket_bounds(52_666, 32_768);
        assert_eq!(b.len(), 2);
        assert_eq!(b.last().unwrap().1, 52_666);
    }

    /// Drive one full round on a single rank: push gradients back to
    /// front, join, return the averaged buffer.
    fn one_round(ex: &mut GradExchanger, grads: &[f32], cuts: &[usize]) -> Vec<f32> {
        // `cuts` are layout offsets splitting `grads` into tensors;
        // emit them in reverse order, as backward would.
        let mut hi = grads.len();
        for &lo in cuts.iter().rev() {
            ex.grad_ready(lo, &grads[lo..hi]).unwrap();
            hi = lo;
        }
        ex.join().unwrap().to_vec()
    }

    #[test]
    fn noop_round_trips_the_gradients_unchanged() {
        let grads: Vec<f32> = (0..10).map(|i| i as f32).collect();
        for stream in [false, true] {
            let mut ex = GradExchanger::new(Box::new(NoopCollective::new()), 10, 4, stream);
            assert_eq!(ex.n_buckets(), 3);
            let out = one_round(&mut ex, &grads, &[0, 3, 7]);
            assert_eq!(out, grads);
            let stats = ex.finish().unwrap();
            assert_eq!(stats.rounds, 1);
        }
    }

    #[test]
    fn out_of_order_and_early_join_are_protocol_errors() {
        let mut ex = GradExchanger::new(Box::new(NoopCollective::new()), 10, 4, false);
        // First emission must end at the watermark (10).
        assert!(ex.grad_ready(0, &[0.0; 3]).is_err());
        ex.grad_ready(7, &[0.0; 3]).unwrap();
        // Join with 7 elements still missing must refuse.
        assert!(ex.join().is_err());
        // Skipping a span must refuse.
        assert!(ex.grad_ready(0, &[0.0; 3]).is_err());
    }

    /// Stream and serial modes over a real 2-rank fabric must agree
    /// bit-for-bit and produce the group mean.
    #[test]
    fn stream_and_serial_agree_bitwise_over_a_pair() {
        let total = 37;
        let run = |stream: bool| -> Vec<Vec<f32>> {
            let fabrics = build_fabric(2, &[TransportKind::P2p]);
            let mut joins = Vec::new();
            for (rank, fabric) in fabrics.into_iter().enumerate() {
                joins.push(std::thread::spawn(move || {
                    let mut ex = GradExchanger::new(fabric, total, 8, stream);
                    let grads: Vec<f32> =
                        (0..total).map(|i| (i as f32 + 1.0) * (rank as f32 + 1.0)).collect();
                    let out = one_round(&mut ex, &grads, &[0, 5, 20]);
                    let stats = ex.finish().unwrap();
                    assert_eq!(stats.bucket_rounds, 5);
                    out
                }));
            }
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        };
        let serial = run(false);
        let stream = run(true);
        // Mean of rank multipliers 1 and 2 is 1.5.
        for rank in 0..2 {
            for (i, &v) in serial[rank].iter().enumerate() {
                assert_eq!(v, (i as f32 + 1.0) * 1.5, "serial rank {rank} elem {i}");
            }
            assert_eq!(serial[rank], stream[rank], "rank {rank}");
        }
        assert_eq!(serial[0], serial[1]);
    }

    /// A stalled (alive but silent) peer behind a link deadline must
    /// surface as a Timeout at the join barrier — the streamed comm
    /// thread forwards the error instead of hanging the step loop.
    #[test]
    fn stalled_peer_with_deadline_times_out_at_join() {
        use crate::comm::collective::PairwiseCollective;
        use crate::comm::link::transport_pair;
        use std::time::Duration;

        let (a, stalled_peer) = transport_pair(TransportKind::P2p);
        let mut coll = PairwiseCollective::from_transport(Box::new(a));
        coll.set_io_deadline(Some(Duration::from_millis(30))).unwrap();
        let mut ex = GradExchanger::new(Box::new(coll), 8, 4, true);
        ex.grad_ready(0, &[1.0; 8]).unwrap();
        let err = ex.join().unwrap_err();
        assert!(
            matches!(err, Error::Timeout(_)),
            "expected Timeout from the join barrier, got: {err}"
        );
        // The peer endpoint stayed alive the whole time — this was a
        // stall, not a disconnect.
        drop(stalled_peer);
        ex.finish().unwrap();
    }

    #[test]
    fn multiple_rounds_reuse_buffers_and_count_rounds() {
        let fabrics = build_fabric(2, &[TransportKind::P2p]);
        let mut joins = Vec::new();
        for (rank, fabric) in fabrics.into_iter().enumerate() {
            joins.push(std::thread::spawn(move || {
                let mut ex = GradExchanger::new(fabric, 12, 5, true);
                for round in 0..3 {
                    let grads = vec![(rank + round) as f32; 12];
                    let _ = one_round(&mut ex, &grads, &[0, 6]);
                }
                ex.finish().unwrap()
            }));
        }
        for j in joins {
            let stats = j.join().unwrap();
            assert_eq!(stats.rounds, 3);
            assert_eq!(stats.bucket_rounds, 9);
        }
    }
}
