//! Paired transport endpoints.
//!
//! A message is `(seq, payload)`; `seq` lets the exchange protocol
//! detect skew (a worker averaging against a stale round — exactly the
//! hazard the paper hit with unsynchronized device-to-device copies,
//! §4.3).  Three implementations differ in *real* work performed:
//!
//! | kind        | copies                 | extra work        |
//! |-------------|------------------------|-------------------|
//! | P2p         | 1 (payload -> wire)    | —                 |
//! | HostStaged  | 2 (payload -> host staging -> wire) | —    |
//! | Serialized  | 2 + byte encode/decode | f32<->LE bytes    |

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::config::TransportKind;
use crate::error::{Error, Result};

/// Wire format: either raw f32 vectors or encoded bytes.
enum Wire {
    Raw(u64, Vec<f32>),
    Bytes(u64, Vec<u8>),
}

/// Per-endpoint traffic counters (E4 bench data).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes_sent: u64,
    /// Host-side copies performed on the send path (P2p=1, staged=2).
    pub send_copies: u64,
    /// Seconds spent encoding/decoding (Serialized only).
    pub codec_seconds: f64,
}

/// One side of a bidirectional link.
pub struct Endpoint {
    kind: TransportKind,
    tx: Sender<Wire>,
    rx: Receiver<Wire>,
    staging: Vec<f32>,
    pub stats: LinkStats,
}

/// Build a connected pair of endpoints of the given kind.
pub fn transport_pair(kind: TransportKind) -> (Endpoint, Endpoint) {
    let (tx_ab, rx_ab) = channel();
    let (tx_ba, rx_ba) = channel();
    (
        Endpoint { kind, tx: tx_ab, rx: rx_ba, staging: Vec::new(), stats: LinkStats::default() },
        Endpoint { kind, tx: tx_ba, rx: rx_ab, staging: Vec::new(), stats: LinkStats::default() },
    )
}

impl Endpoint {
    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    /// Send an owned payload tagged with `seq`.  On the P2P path the
    /// buffer is *moved* onto the wire — zero copies, the GPUDirect
    /// analog (§Perf: this is the exchange hot path; `send` below is
    /// the borrowing convenience wrapper).
    pub fn send_vec(&mut self, seq: u64, payload: Vec<f32>) -> Result<()> {
        self.stats.messages += 1;
        self.stats.bytes_sent += (payload.len() * 4) as u64;
        if self.kind == TransportKind::P2p {
            return self
                .tx
                .send(Wire::Raw(seq, payload))
                .map_err(|_| Error::Protocol("peer endpoint dropped".into()));
        }
        self.stats.messages -= 1;
        self.stats.bytes_sent -= (payload.len() * 4) as u64;
        self.send(seq, &payload)
    }

    /// Send `payload` tagged with `seq`.
    pub fn send(&mut self, seq: u64, payload: &[f32]) -> Result<()> {
        self.stats.messages += 1;
        self.stats.bytes_sent += (payload.len() * 4) as u64;
        let wire = match self.kind {
            TransportKind::P2p => {
                // GPUDirect analog: one copy, device to device.
                self.stats.send_copies += 1;
                Wire::Raw(seq, payload.to_vec())
            }
            TransportKind::HostStaged => {
                // d2h into the staging buffer, then h2d onto the wire.
                self.staging.clear();
                self.staging.extend_from_slice(payload);
                self.stats.send_copies += 2;
                Wire::Raw(seq, self.staging.clone())
            }
            TransportKind::Serialized => {
                // The multiprocessing path: pickle-style byte encode.
                let t = crate::util::Timer::start();
                let mut bytes = Vec::with_capacity(payload.len() * 4);
                for v in payload {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                self.stats.codec_seconds += t.elapsed_secs();
                self.stats.send_copies += 2;
                Wire::Bytes(seq, bytes)
            }
        };
        self.tx
            .send(wire)
            .map_err(|_| Error::Protocol("peer endpoint dropped".into()))
    }

    /// Receive the message for `expected_seq` into `out`.
    pub fn recv(&mut self, expected_seq: u64, out: &mut Vec<f32>) -> Result<()> {
        let wire = self
            .rx
            .recv()
            .map_err(|_| Error::Protocol("peer endpoint dropped".into()))?;
        let (seq, n) = match wire {
            Wire::Raw(seq, v) => {
                // Take ownership of the wire buffer — no copy.
                let n = v.len();
                *out = v;
                (seq, n)
            }
            Wire::Bytes(seq, bytes) => {
                if bytes.len() % 4 != 0 {
                    return Err(Error::Protocol("serialized payload not f32-aligned".into()));
                }
                let t = crate::util::Timer::start();
                out.clear();
                out.reserve(bytes.len() / 4);
                for c in bytes.chunks_exact(4) {
                    out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                self.stats.codec_seconds += t.elapsed_secs();
                (seq, bytes.len() / 4)
            }
        };
        if seq != expected_seq {
            return Err(Error::Protocol(format!(
                "exchange skew: received round {seq}, expected {expected_seq} \
                 (unsynchronized peer copy — the §4.3 hazard)"
            )));
        }
        let _ = n;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(kind: TransportKind) {
        let (mut a, mut b) = transport_pair(kind);
        let payload: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        a.send(0, &payload).unwrap();
        let mut out = Vec::new();
        b.recv(0, &mut out).unwrap();
        assert_eq!(out, payload);
        // Reverse direction.
        b.send(0, &payload).unwrap();
        a.recv(0, &mut out).unwrap();
        assert_eq!(out, payload);
    }

    #[test]
    fn all_kinds_roundtrip() {
        roundtrip(TransportKind::P2p);
        roundtrip(TransportKind::HostStaged);
        roundtrip(TransportKind::Serialized);
    }

    #[test]
    fn seq_skew_detected() {
        let (mut a, mut b) = transport_pair(TransportKind::P2p);
        a.send(3, &[1.0]).unwrap();
        let mut out = Vec::new();
        let err = b.recv(4, &mut out).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)));
    }

    #[test]
    fn stats_reflect_path_costs() {
        let payload = vec![1.0f32; 256];
        let (mut p, _pb) = transport_pair(TransportKind::P2p);
        p.send(0, &payload).unwrap();
        assert_eq!(p.stats.send_copies, 1);
        assert_eq!(p.stats.bytes_sent, 1024);
        assert_eq!(p.stats.codec_seconds, 0.0);

        let (mut h, _hb) = transport_pair(TransportKind::HostStaged);
        h.send(0, &payload).unwrap();
        assert_eq!(h.stats.send_copies, 2);

        let (mut s, mut sb) = transport_pair(TransportKind::Serialized);
        s.send(0, &payload).unwrap();
        let mut out = Vec::new();
        sb.recv(0, &mut out).unwrap();
        assert_eq!(s.stats.send_copies, 2);
        assert!(s.stats.codec_seconds >= 0.0);
    }

    #[test]
    fn dropped_peer_errors() {
        let (mut a, b) = transport_pair(TransportKind::P2p);
        drop(b);
        assert!(a.send(0, &[1.0]).is_err());
    }
}
