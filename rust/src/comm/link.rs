//! Paired transport endpoints: in-memory links and the TCP link.
//!
//! A message is `(seq, payload)`; `seq` lets the exchange protocol
//! detect skew (a worker averaging against a stale round — exactly the
//! hazard the paper hit with unsynchronized device-to-device copies,
//! §4.3).  The [`Transport`] trait is the send/recv contract every
//! collective is written against; three in-memory implementations
//! differ in *real* work performed:
//!
//! | kind        | copies                 | extra work        |
//! |-------------|------------------------|-------------------|
//! | P2p         | 1 (payload -> wire)    | —                 |
//! | HostStaged  | 2 (payload -> host staging -> wire) | —    |
//! | Serialized  | 2 + byte encode/decode | f32<->LE bytes    |
//!
//! [`TcpEndpoint`] carries the same contract across process (and
//! machine) boundaries: each message is one length-prefixed frame
//! (`seq: u64 LE, count: u32 LE, count * f32 LE`), and an optional
//! deadline turns a dead or stalled peer into `Error::Timeout` instead
//! of a hang.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::config::TransportKind;
use crate::error::{Error, Result};

/// Wire format: either raw f32 vectors or encoded bytes.
enum Wire {
    Raw(u64, Vec<f32>),
    Bytes(u64, Vec<u8>),
}

/// Per-endpoint traffic counters (E4 bench data).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes_sent: u64,
    /// Host-side copies performed on the send path (P2p=1, staged=2).
    pub send_copies: u64,
    /// Seconds spent encoding/decoding (Serialized + TCP).
    pub codec_seconds: f64,
}

/// The send/recv contract shared by in-memory and TCP links.  The
/// collectives (`ExchangePort`, `RingCollective`) are written against
/// this trait, so a ring can mix local channels and sockets.
pub trait Transport: Send {
    /// Send an owned payload tagged with `seq` (may move the buffer).
    fn send_vec(&mut self, seq: u64, payload: Vec<f32>) -> Result<()>;

    /// Send a borrowed payload tagged with `seq`.
    fn send(&mut self, seq: u64, payload: &[f32]) -> Result<()>;

    /// Receive the message for `expected_seq` into `out`.  A sequence
    /// mismatch is `Error::Protocol`; a missed deadline is
    /// `Error::Timeout`.
    fn recv(&mut self, expected_seq: u64, out: &mut Vec<f32>) -> Result<()>;

    /// Bound every subsequent recv (and, for sockets, send) by `d`.
    /// `None` restores blocking behaviour.
    fn set_deadline(&mut self, d: Option<Duration>) -> Result<()>;

    /// Traffic counters accumulated so far.
    fn stats(&self) -> LinkStats;
}

/// One side of a bidirectional in-memory link.
pub struct Endpoint {
    kind: TransportKind,
    tx: Sender<Wire>,
    rx: Receiver<Wire>,
    staging: Vec<f32>,
    deadline: Option<Duration>,
    pub stats: LinkStats,
}

/// Build a connected pair of endpoints of the given kind.
pub fn transport_pair(kind: TransportKind) -> (Endpoint, Endpoint) {
    let (tx_ab, rx_ab) = channel();
    let (tx_ba, rx_ba) = channel();
    (
        Endpoint {
            kind,
            tx: tx_ab,
            rx: rx_ba,
            staging: Vec::new(),
            deadline: None,
            stats: LinkStats::default(),
        },
        Endpoint {
            kind,
            tx: tx_ba,
            rx: rx_ab,
            staging: Vec::new(),
            deadline: None,
            stats: LinkStats::default(),
        },
    )
}

impl Endpoint {
    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    /// Bound every subsequent `recv` by `d` (None = block forever).
    pub fn set_deadline(&mut self, d: Option<Duration>) {
        self.deadline = d;
    }

    /// Send an owned payload tagged with `seq`.  On the P2P path the
    /// buffer is *moved* onto the wire — zero copies, the GPUDirect
    /// analog (§Perf: this is the exchange hot path; `send` below is
    /// the borrowing convenience wrapper).
    pub fn send_vec(&mut self, seq: u64, payload: Vec<f32>) -> Result<()> {
        self.stats.messages += 1;
        self.stats.bytes_sent += (payload.len() * 4) as u64;
        if self.kind == TransportKind::P2p {
            return self
                .tx
                .send(Wire::Raw(seq, payload))
                .map_err(|_| Error::Protocol("peer endpoint dropped".into()));
        }
        self.stats.messages -= 1;
        self.stats.bytes_sent -= (payload.len() * 4) as u64;
        self.send(seq, &payload)
    }

    /// Send `payload` tagged with `seq`.
    pub fn send(&mut self, seq: u64, payload: &[f32]) -> Result<()> {
        self.stats.messages += 1;
        self.stats.bytes_sent += (payload.len() * 4) as u64;
        let wire = match self.kind {
            TransportKind::P2p => {
                // GPUDirect analog: one copy, device to device.
                self.stats.send_copies += 1;
                Wire::Raw(seq, payload.to_vec())
            }
            TransportKind::HostStaged => {
                // d2h into the staging buffer, then h2d onto the wire.
                self.staging.clear();
                self.staging.extend_from_slice(payload);
                self.stats.send_copies += 2;
                Wire::Raw(seq, self.staging.clone())
            }
            TransportKind::Serialized => {
                // The multiprocessing path: pickle-style byte encode.
                let t = crate::util::Timer::start();
                let mut bytes = Vec::with_capacity(payload.len() * 4);
                for v in payload {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                self.stats.codec_seconds += t.elapsed_secs();
                self.stats.send_copies += 2;
                Wire::Bytes(seq, bytes)
            }
        };
        self.tx
            .send(wire)
            .map_err(|_| Error::Protocol("peer endpoint dropped".into()))
    }

    /// Receive the message for `expected_seq` into `out`.
    pub fn recv(&mut self, expected_seq: u64, out: &mut Vec<f32>) -> Result<()> {
        let wire = match self.deadline {
            None => self
                .rx
                .recv()
                .map_err(|_| Error::Protocol("peer endpoint dropped".into()))?,
            Some(d) => self.rx.recv_timeout(d).map_err(|e| match e {
                RecvTimeoutError::Timeout => Error::Timeout(format!(
                    "no message for round {expected_seq} within {d:?} \
                     (peer dead or stalled)"
                )),
                RecvTimeoutError::Disconnected => {
                    Error::Protocol("peer endpoint dropped".into())
                }
            })?,
        };
        let (seq, n) = match wire {
            Wire::Raw(seq, v) => {
                // Take ownership of the wire buffer — no copy.
                let n = v.len();
                *out = v;
                (seq, n)
            }
            Wire::Bytes(seq, bytes) => {
                if bytes.len() % 4 != 0 {
                    return Err(Error::Protocol("serialized payload not f32-aligned".into()));
                }
                let t = crate::util::Timer::start();
                out.clear();
                out.reserve(bytes.len() / 4);
                for c in bytes.chunks_exact(4) {
                    out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                self.stats.codec_seconds += t.elapsed_secs();
                (seq, bytes.len() / 4)
            }
        };
        if seq != expected_seq {
            return Err(Error::Protocol(format!(
                "exchange skew: received round {seq}, expected {expected_seq} \
                 (unsynchronized peer copy — the §4.3 hazard)"
            )));
        }
        let _ = n;
        Ok(())
    }
}

impl Transport for Endpoint {
    fn send_vec(&mut self, seq: u64, payload: Vec<f32>) -> Result<()> {
        Endpoint::send_vec(self, seq, payload)
    }

    fn send(&mut self, seq: u64, payload: &[f32]) -> Result<()> {
        Endpoint::send(self, seq, payload)
    }

    fn recv(&mut self, expected_seq: u64, out: &mut Vec<f32>) -> Result<()> {
        Endpoint::recv(self, expected_seq, out)
    }

    fn set_deadline(&mut self, d: Option<Duration>) -> Result<()> {
        Endpoint::set_deadline(self, d);
        Ok(())
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

/// Frame header: seq (u64 LE) + element count (u32 LE).
const TCP_HEADER_BYTES: usize = 12;

/// Sanity bound on a single frame (2^28 f32 = 1 GiB payload); anything
/// larger means a corrupt or hostile stream, not a gradient bucket.
const TCP_MAX_FRAME_ELEMS: u32 = 1 << 28;

/// One direction-pair of a socket link: the same `(seq, payload)`
/// contract as the in-memory endpoints, framed as
/// `seq: u64 LE, count: u32 LE, count * f32 LE` on a `TcpStream`.
pub struct TcpEndpoint {
    stream: TcpStream,
    wire_buf: Vec<u8>,
    pub stats: LinkStats,
}

impl TcpEndpoint {
    /// Wrap a connected stream.  `TCP_NODELAY` is set — exchange
    /// frames are latency-critical and self-contained, so Nagle
    /// batching only adds round latency.
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).map_err(Error::RawIo)?;
        Ok(TcpEndpoint { stream, wire_buf: Vec::new(), stats: LinkStats::default() })
    }

    fn peer_label(&self) -> String {
        match self.stream.peer_addr() {
            Ok(a) => a.to_string(),
            Err(_) => "<disconnected peer>".into(),
        }
    }

    /// Map a socket error to the collective error vocabulary: missed
    /// deadline -> Timeout, torn stream -> Protocol, rest -> RawIo.
    fn map_io(&self, what: &str, e: std::io::Error) -> Error {
        use std::io::ErrorKind;
        match e.kind() {
            ErrorKind::WouldBlock | ErrorKind::TimedOut => Error::Timeout(format!(
                "tcp {what} to/from {} missed its deadline (peer dead or stalled)",
                self.peer_label()
            )),
            ErrorKind::UnexpectedEof => Error::Protocol(format!(
                "peer {} closed the connection mid-{what}",
                self.peer_label()
            )),
            _ => Error::RawIo(e),
        }
    }
}

impl Transport for TcpEndpoint {
    fn send_vec(&mut self, seq: u64, payload: Vec<f32>) -> Result<()> {
        self.send(seq, &payload)
    }

    fn send(&mut self, seq: u64, payload: &[f32]) -> Result<()> {
        if payload.len() as u64 > TCP_MAX_FRAME_ELEMS as u64 {
            return Err(Error::Protocol(format!(
                "tcp frame of {} f32 exceeds the {} element bound",
                payload.len(),
                TCP_MAX_FRAME_ELEMS
            )));
        }
        self.stats.messages += 1;
        self.stats.bytes_sent += (payload.len() * 4) as u64;
        let t = crate::util::Timer::start();
        self.wire_buf.clear();
        self.wire_buf.reserve(TCP_HEADER_BYTES + payload.len() * 4);
        self.wire_buf.extend_from_slice(&seq.to_le_bytes());
        self.wire_buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        for v in payload {
            self.wire_buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stats.codec_seconds += t.elapsed_secs();
        // Encode into the wire buffer + the kernel copy on write.
        self.stats.send_copies += 2;
        if let Err(e) = self.stream.write_all(&self.wire_buf) {
            return Err(self.map_io("send", e));
        }
        Ok(())
    }

    fn recv(&mut self, expected_seq: u64, out: &mut Vec<f32>) -> Result<()> {
        let mut header = [0u8; TCP_HEADER_BYTES];
        if let Err(e) = self.stream.read_exact(&mut header) {
            return Err(self.map_io("recv", e));
        }
        let seq = u64::from_le_bytes(header[0..8].try_into().unwrap());
        let count = u32::from_le_bytes(header[8..12].try_into().unwrap());
        if count > TCP_MAX_FRAME_ELEMS {
            return Err(Error::Protocol(format!(
                "tcp frame header claims {count} f32 (bound {TCP_MAX_FRAME_ELEMS}); \
                 corrupt stream from {}",
                self.peer_label()
            )));
        }
        self.wire_buf.clear();
        self.wire_buf.resize(count as usize * 4, 0);
        if let Err(e) = self.stream.read_exact(&mut self.wire_buf) {
            return Err(self.map_io("recv", e));
        }
        let t = crate::util::Timer::start();
        out.clear();
        out.reserve(count as usize);
        for c in self.wire_buf.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        self.stats.codec_seconds += t.elapsed_secs();
        if seq != expected_seq {
            return Err(Error::Protocol(format!(
                "exchange skew: received round {seq}, expected {expected_seq} \
                 (unsynchronized peer copy — the §4.3 hazard)"
            )));
        }
        Ok(())
    }

    fn set_deadline(&mut self, d: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(d).map_err(Error::RawIo)?;
        self.stream.set_write_timeout(d).map_err(Error::RawIo)?;
        Ok(())
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn roundtrip(kind: TransportKind) {
        let (mut a, mut b) = transport_pair(kind);
        let payload: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        a.send(0, &payload).unwrap();
        let mut out = Vec::new();
        b.recv(0, &mut out).unwrap();
        assert_eq!(out, payload);
        // Reverse direction.
        b.send(0, &payload).unwrap();
        a.recv(0, &mut out).unwrap();
        assert_eq!(out, payload);
    }

    #[test]
    fn all_kinds_roundtrip() {
        roundtrip(TransportKind::P2p);
        roundtrip(TransportKind::HostStaged);
        roundtrip(TransportKind::Serialized);
    }

    #[test]
    fn seq_skew_detected() {
        let (mut a, mut b) = transport_pair(TransportKind::P2p);
        a.send(3, &[1.0]).unwrap();
        let mut out = Vec::new();
        let err = b.recv(4, &mut out).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)));
    }

    #[test]
    fn stats_reflect_path_costs() {
        let payload = vec![1.0f32; 256];
        let (mut p, _pb) = transport_pair(TransportKind::P2p);
        p.send(0, &payload).unwrap();
        assert_eq!(p.stats.send_copies, 1);
        assert_eq!(p.stats.bytes_sent, 1024);
        assert_eq!(p.stats.codec_seconds, 0.0);

        let (mut h, _hb) = transport_pair(TransportKind::HostStaged);
        h.send(0, &payload).unwrap();
        assert_eq!(h.stats.send_copies, 2);

        let (mut s, mut sb) = transport_pair(TransportKind::Serialized);
        s.send(0, &payload).unwrap();
        let mut out = Vec::new();
        sb.recv(0, &mut out).unwrap();
        assert_eq!(s.stats.send_copies, 2);
        assert!(s.stats.codec_seconds >= 0.0);
    }

    #[test]
    fn dropped_peer_errors() {
        let (mut a, b) = transport_pair(TransportKind::P2p);
        drop(b);
        assert!(a.send(0, &[1.0]).is_err());
    }

    #[test]
    fn in_memory_recv_deadline_times_out() {
        let (mut a, _b) = transport_pair(TransportKind::P2p);
        a.set_deadline(Some(Duration::from_millis(30)));
        let mut out = Vec::new();
        // Peer alive but silent: must surface as Timeout, not hang.
        let err = a.recv(7, &mut out).unwrap_err();
        match err {
            Error::Timeout(m) => assert!(m.contains("round 7"), "message: {m}"),
            other => panic!("expected Timeout, got {other:?}"),
        }
        // Clearing the deadline restores normal delivery.
        a.set_deadline(None);
    }

    /// A connected loopback TcpEndpoint pair (a = client, b = accepted).
    fn tcp_pair() -> (TcpEndpoint, TcpEndpoint) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (TcpEndpoint::new(client).unwrap(), TcpEndpoint::new(server).unwrap())
    }

    #[test]
    fn tcp_roundtrip_is_exact() {
        let (mut a, mut b) = tcp_pair();
        // Includes values that would be lossy under any non-bitwise
        // re-encode: the LE byte round-trip must be exact.
        let payload: Vec<f32> =
            vec![0.1, -0.0, f32::MIN_POSITIVE, 1.0e-38, 3.141_592_7, -12345.678];
        a.send(0, &payload).unwrap();
        let mut out = Vec::new();
        b.recv(0, &mut out).unwrap();
        assert_eq!(out.len(), payload.len());
        for (x, y) in out.iter().zip(payload.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        b.send_vec(1, payload.clone()).unwrap();
        a.recv(1, &mut out).unwrap();
        assert_eq!(out, payload);
        assert_eq!(a.stats.messages, 1);
        assert_eq!(a.stats.bytes_sent, (payload.len() * 4) as u64);
    }

    #[test]
    fn tcp_seq_skew_detected() {
        let (mut a, mut b) = tcp_pair();
        a.send(3, &[1.0]).unwrap();
        let mut out = Vec::new();
        let err = b.recv(4, &mut out).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "got {err:?}");
    }

    #[test]
    fn tcp_stalled_peer_times_out() {
        let (mut a, _b) = tcp_pair();
        a.set_deadline(Some(Duration::from_millis(30))).unwrap();
        let mut out = Vec::new();
        let err = a.recv(0, &mut out).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "got {err:?}");
    }

    #[test]
    fn tcp_closed_peer_is_protocol_error() {
        let (mut a, b) = tcp_pair();
        drop(b);
        let mut out = Vec::new();
        let err = a.recv(0, &mut out).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "got {err:?}");
    }

    #[test]
    fn tcp_rejects_oversized_frame_header() {
        let (mut a, mut b) = tcp_pair();
        // Hand-craft a header claiming an absurd element count.
        let mut frame = Vec::new();
        frame.extend_from_slice(&0u64.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        a.stream.write_all(&frame).unwrap();
        let mut out = Vec::new();
        let err = b.recv(0, &mut out).unwrap_err();
        match err {
            Error::Protocol(m) => assert!(m.contains("corrupt stream"), "message: {m}"),
            other => panic!("expected Protocol, got {other:?}"),
        }
    }
}
