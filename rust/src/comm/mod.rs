//! Inter-replica communication: the paper's §2.2/§4.3 machinery,
//! generalized from the 2-GPU special case to an N-worker collective
//! fabric.
//!
//! - [`link`]: paired endpoints with three copy paths — `P2p`
//!   (GPUDirect analog: one staged copy), `HostStaged` (bounce through
//!   host memory, the cross-switch fallback of §4.4) and `Serialized`
//!   (the `multiprocessing` pickle path of §4.3: encode + copy +
//!   decode).  The paths do genuinely different amounts of work, so
//!   the E4 bench measures real cost ratios.
//! - [`exchange`]: the Fig-2 engine — 3-step exchange-and-average of
//!   params (+ momenta) with sequence-number protocol checking (the
//!   paper's CUDA-context-sync workaround).  Pairwise only; reused by
//!   the collective layer as the N = 2 fast path.
//! - [`collective`]: the [`Collective`] trait the coordinator trains
//!   through for *any* N — no-op (N = 1), pairwise port (N = 2, byte-
//!   for-byte the paper's path) and a chunked ring all-reduce over the
//!   link transports (arbitrary N, per-hop §4.4 topology fallback).
//! - [`overlap`]: bucketed gradient exchange streamed from backward —
//!   fixed layout-derived buckets ring-reduced on a dedicated comm
//!   thread concurrently with the remaining backward pass, joined at a
//!   barrier before the update (Theano-MPI's comm/compute overlap).
//! - [`rendezvous`]: multi-process rings — bind/connect/handshake
//!   assembly of the same ring collective across OS processes over
//!   TCP, with bounded backoff and loud named-field rejection of
//!   drifted peers.
//! - [`barrier`]: timed step barrier.
//! - [`cost`]: analytic transfer-time model, calibrated by `sim`.

pub mod barrier;
pub mod collective;
pub mod cost;
pub mod exchange;
pub mod link;
pub mod overlap;
pub mod rendezvous;

pub use barrier::TimedBarrier;
pub use collective::{
    build_fabric, pair_fabric, ring_fabric, Collective, CollectiveStats, NoopCollective,
    PairwiseCollective, RingCollective,
};
pub use overlap::{bucket_bounds, GradExchanger};
pub use cost::{CommCostModel, LinkCost};
pub use exchange::{ExchangePort, ExchangeStats};
pub use link::{transport_pair, Endpoint, LinkStats, TcpEndpoint, Transport};
pub use rendezvous::{ring_over_tcp, Hello, RendezvousCfg, FRESH_RUN, PROTOCOL_VERSION};
