//! Inter-replica communication: the paper's §2.2/§4.3 machinery.
//!
//! - [`link`]: paired endpoints with three copy paths — `P2p`
//!   (GPUDirect analog: one staged copy), `HostStaged` (bounce through
//!   host memory, the cross-switch fallback of §4.4) and `Serialized`
//!   (the `multiprocessing` pickle path of §4.3: encode + copy +
//!   decode).  The paths do genuinely different amounts of work, so
//!   the E4 bench measures real cost ratios.
//! - [`exchange`]: the Fig-2 engine — 3-step exchange-and-average of
//!   params (+ momenta) with sequence-number protocol checking (the
//!   paper's CUDA-context-sync workaround).
//! - [`barrier`]: timed step barrier.
//! - [`ring`]: chunked ring all-reduce — the N-GPU extension the paper
//!   leaves as future work (§4.4), used by the E5 scaling study.
//! - [`cost`]: analytic transfer-time model, calibrated by `sim`.

pub mod barrier;
pub mod cost;
pub mod exchange;
pub mod link;
pub mod ring;

pub use barrier::TimedBarrier;
pub use cost::{CommCostModel, LinkCost};
pub use exchange::{ExchangePort, ExchangeStats};
pub use link::{transport_pair, Endpoint, LinkStats};
pub use ring::RingNode;
