//! Analytic transfer-cost model.
//!
//! Drives the discrete-event simulator's Table-1 regeneration: given a
//! transport kind and payload size, predict the copy time on the
//! paper's testbed-class hardware.  Defaults are PCIe-gen3-era figures
//! (Titan Black / 2014): GPUDirect P2P under one switch sustains close
//! to the x16 link, host-staged pays two hops at lower efficiency, and
//! the multiprocessing path adds a serialize/deserialize stage at
//! memory-bandwidth-bound pickle speeds.  `sim::calibrate` can rescale
//! all rates from measured copies on the current machine.

use crate::config::TransportKind;

/// One link: fixed latency + linear byte cost.
#[derive(Clone, Copy, Debug)]
pub struct LinkCost {
    pub latency_s: f64,
    pub bytes_per_s: f64,
}

impl LinkCost {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// Full communication cost model.
#[derive(Clone, Copy, Debug)]
pub struct CommCostModel {
    /// Same-switch GPUDirect peer copy.
    pub p2p: LinkCost,
    /// Device->host or host->device copy (one hop).
    pub host_hop: LinkCost,
    /// Byte encode/decode rate for the serialized path.
    pub codec_bytes_per_s: f64,
}

impl Default for CommCostModel {
    fn default() -> Self {
        CommCostModel {
            // ~10.5 GB/s effective PCIe3 x16 P2P, 10 µs setup.
            p2p: LinkCost { latency_s: 10e-6, bytes_per_s: 10.5e9 },
            // ~6 GB/s effective pinned-memory hop, 15 µs setup.
            host_hop: LinkCost { latency_s: 15e-6, bytes_per_s: 6.0e9 },
            // ~1.8 GB/s pickle-ish encode.
            codec_bytes_per_s: 1.8e9,
        }
    }
}

impl CommCostModel {
    /// One-way transfer time of `bytes` over `kind`.
    pub fn transfer_time(&self, kind: TransportKind, bytes: usize) -> f64 {
        match kind {
            TransportKind::P2p => self.p2p.transfer_time(bytes),
            // d2h + h2d.
            TransportKind::HostStaged => 2.0 * self.host_hop.transfer_time(bytes),
            // encode + d2h + h2d + decode.
            TransportKind::Serialized => {
                2.0 * self.host_hop.transfer_time(bytes)
                    + 2.0 * bytes as f64 / self.codec_bytes_per_s
            }
        }
    }

    /// Fig-2 round time: both directions overlap on independent links,
    /// so the round is one transfer + the (memory-bound) average pass.
    pub fn exchange_round_time(&self, kind: TransportKind, bytes: usize) -> f64 {
        // Average pass: read peer + read/write local at ~8 GB/s.
        let avg = bytes as f64 / 8.0e9;
        self.transfer_time(kind, bytes) + avg
    }

    /// Uniform scale of all bandwidths (calibration hook).
    pub fn scaled(&self, factor: f64) -> CommCostModel {
        CommCostModel {
            p2p: LinkCost {
                latency_s: self.p2p.latency_s,
                bytes_per_s: self.p2p.bytes_per_s * factor,
            },
            host_hop: LinkCost {
                latency_s: self.host_hop.latency_s,
                bytes_per_s: self.host_hop.bytes_per_s * factor,
            },
            codec_bytes_per_s: self.codec_bytes_per_s * factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_the_paper() {
        // §4.3/§4.4: P2P < host-staged < serialized for any real payload.
        let m = CommCostModel::default();
        let bytes = 245 * 1024 * 1024; // AlexNet params+momenta fp32
        let p2p = m.transfer_time(TransportKind::P2p, bytes);
        let host = m.transfer_time(TransportKind::HostStaged, bytes);
        let ser = m.transfer_time(TransportKind::Serialized, bytes);
        assert!(p2p < host && host < ser, "{p2p} {host} {ser}");
    }

    #[test]
    fn latency_dominates_small_payloads() {
        let m = CommCostModel::default();
        let t = m.transfer_time(TransportKind::P2p, 64);
        assert!(t < 2.0 * m.p2p.latency_s);
    }

    #[test]
    fn linear_in_bytes() {
        let m = CommCostModel::default();
        let t1 = m.transfer_time(TransportKind::P2p, 1 << 20);
        let t2 = m.transfer_time(TransportKind::P2p, 2 << 20);
        let marginal = t2 - t1;
        assert!((marginal - (1 << 20) as f64 / m.p2p.bytes_per_s).abs() < 1e-9);
    }

    #[test]
    fn scaling_rescales() {
        let m = CommCostModel::default().scaled(2.0);
        assert!((m.p2p.bytes_per_s - 21.0e9).abs() < 1e6);
    }
}
