//! The N-worker collective fabric.
//!
//! The paper's headline result is a *pairwise* exchange between exactly
//! two GPUs (Fig 2); Theano-MPI — its direct successor — generalizes
//! the same exchange-and-average protocol to N workers over a proper
//! collective layer.  This module is that generalization: a single
//! [`Collective`] trait with three implementations, all driving the
//! same [`ParamStore`] staging path:
//!
//! - [`NoopCollective`] — N = 1, nothing to synchronize;
//! - [`PairwiseCollective`] — the N = 2 fast path, wrapping
//!   [`ExchangePort`] so the paper's whole-buffer zero-copy exchange is
//!   preserved byte-for-byte;
//! - [`RingCollective`] — arbitrary N: a chunked ring all-reduce
//!   (reduce-scatter + all-gather, Krizhevsky 2014) over the existing
//!   [`comm::link`](crate::comm::link) transports, reusing the same
//!   ping-pong staging-buffer discipline as `ExchangePort` so the P2P
//!   path performs zero steady-state allocations.
//!
//! **Topology rule (§4.4, N-worker form).**  Each ring hop `i -> i+1`
//! resolves its transport independently: a P2P request is downgraded to
//! host-staged on hops whose endpoints sit on different PCIe switches,
//! while same-switch hops keep the fast path.  The trainer computes the
//! per-hop kinds via `effective_hop_transports` and hands them to
//! [`build_fabric`].
//!
//! All three implementations report per-phase timing through
//! [`CollectiveStats`] (flatten / transfer / average — the Fig-2
//! decomposition), which flows into `WorkerOutcome`/`TrainSummary` and
//! the E4/E5 benches for any N.
//!
//! Protocol safety: every message carries a sequence number checked by
//! [`Endpoint::recv`]; a worker averaging against a stale round (the
//! paper's §4.3 hazard) is detected, not silently computed.

use std::time::Duration;

use crate::comm::exchange::{ExchangePort, ExchangeStats};
use crate::comm::link::{transport_pair, Endpoint, Transport};
use crate::config::TransportKind;
use crate::error::{Error, Result};
use crate::params::average::{accumulate, scale_in_place};
use crate::params::ParamStore;
use crate::util::Timer;

/// Per-phase timing/traffic summary of collective rounds.
///
/// Field meanings follow Fig 2: `flatten` covers staging between the
/// store and the wire buffer (both directions), `transfer` covers time
/// on the links, `average` covers the arithmetic (accumulate / copy /
/// scale).  A value returned from one `all_reduce_average` call is the
/// delta of that round; `Collective::stats` returns the running total.
#[derive(Clone, Copy, Debug, Default)]
pub struct CollectiveStats {
    pub rounds: u64,
    pub bytes_per_round: usize,
    pub flatten_seconds: f64,
    pub transfer_seconds: f64,
    pub average_seconds: f64,
    /// Bucketed-exchange messages completed (one per bucket per round;
    /// zero on the monolithic path).
    pub bucket_rounds: u64,
    /// Of the bucketed-exchange comm time, the share that ran
    /// concurrently with backward compute (hidden from the step).
    pub overlapped_seconds: f64,
    /// Comm time the step actually waited for at the pre-update
    /// barrier — the exposed cost the overlap is meant to shrink.
    pub exposed_seconds: f64,
}

impl CollectiveStats {
    pub fn total_seconds(&self) -> f64 {
        self.flatten_seconds + self.transfer_seconds + self.average_seconds
    }

    pub(crate) fn absorb(&mut self, round: &CollectiveStats) {
        self.rounds += round.rounds;
        self.bytes_per_round = round.bytes_per_round;
        self.flatten_seconds += round.flatten_seconds;
        self.transfer_seconds += round.transfer_seconds;
        self.average_seconds += round.average_seconds;
        self.bucket_rounds += round.bucket_rounds;
        self.overlapped_seconds += round.overlapped_seconds;
        self.exposed_seconds += round.exposed_seconds;
    }
}

impl From<ExchangeStats> for CollectiveStats {
    fn from(e: ExchangeStats) -> Self {
        CollectiveStats {
            rounds: e.rounds,
            bytes_per_round: e.bytes_per_round,
            flatten_seconds: e.flatten_seconds,
            transfer_seconds: e.transfer_seconds,
            average_seconds: e.average_seconds,
            ..Default::default()
        }
    }
}

/// One worker's handle on the group-wide exchange-and-average.
///
/// Every participant must call `all_reduce_average` once per round with
/// the same `include_momentum`; after the call returns on all ranks,
/// every replica holds the elementwise mean of the group's state.
pub trait Collective: Send {
    /// Execute one synchronization round on this worker's store;
    /// returns the round's per-phase timing.
    fn all_reduce_average(
        &mut self,
        store: &mut ParamStore,
        include_momentum: bool,
    ) -> Result<CollectiveStats>;

    /// All-reduce-average a raw flat buffer in place — one bucket of
    /// the streamed gradient exchange.  Every rank must call this with
    /// the same buffer length, in the same order relative to its other
    /// collective calls; the per-message sequence check makes skew a
    /// [`Error::Protocol`], never a silent mix-up.  After the call,
    /// `data` holds the elementwise group mean, bit-identical on every
    /// rank.
    fn all_reduce_flat(&mut self, data: &mut [f32]) -> Result<CollectiveStats>;

    /// Cumulative stats across all rounds so far.
    fn stats(&self) -> CollectiveStats;

    /// Number of participants in the group.
    fn world_size(&self) -> usize;

    /// Bound every subsequent link recv (and socket send) by `d`, so a
    /// dead peer surfaces as [`Error::Timeout`] instead of a hang.
    /// `None` restores blocking behaviour.  No-op for N = 1.
    fn set_io_deadline(&mut self, _d: Option<Duration>) -> Result<()> {
        Ok(())
    }

    /// Rounds completed (lockstep across the group).
    fn rounds(&self) -> u64 {
        self.stats().rounds
    }
}

/// N = 1: no peers, every round is a free no-op (no state to track —
/// stats stay at zero by construction).
#[derive(Debug, Default)]
pub struct NoopCollective;

impl NoopCollective {
    pub fn new() -> Self {
        NoopCollective
    }
}

impl Collective for NoopCollective {
    fn all_reduce_average(
        &mut self,
        _store: &mut ParamStore,
        _include_momentum: bool,
    ) -> Result<CollectiveStats> {
        Ok(CollectiveStats::default())
    }

    fn all_reduce_flat(&mut self, _data: &mut [f32]) -> Result<CollectiveStats> {
        Ok(CollectiveStats::default())
    }

    fn stats(&self) -> CollectiveStats {
        CollectiveStats::default()
    }

    fn world_size(&self) -> usize {
        1
    }
}

/// N = 2 fast path: the paper's Fig-2 whole-buffer exchange, preserved
/// byte-for-byte (one send, one recv, midpoint average in place).
pub struct PairwiseCollective {
    port: ExchangePort,
}

impl PairwiseCollective {
    pub fn new(endpoint: Endpoint) -> Self {
        PairwiseCollective { port: ExchangePort::new(endpoint) }
    }

    /// Fast path over any transport (e.g. a socket to the peer rank).
    pub fn from_transport(link: Box<dyn Transport>) -> Self {
        PairwiseCollective { port: ExchangePort::from_transport(link) }
    }

    /// Link-layer counters of the underlying endpoint.
    pub fn link_stats(&self) -> crate::comm::link::LinkStats {
        self.port.link_stats()
    }
}

impl Collective for PairwiseCollective {
    fn all_reduce_average(
        &mut self,
        store: &mut ParamStore,
        include_momentum: bool,
    ) -> Result<CollectiveStats> {
        let before = self.port.stats;
        self.port.exchange(store, include_momentum)?;
        let after = self.port.stats;
        Ok(CollectiveStats {
            rounds: 1,
            bytes_per_round: after.bytes_per_round,
            flatten_seconds: after.flatten_seconds - before.flatten_seconds,
            transfer_seconds: after.transfer_seconds - before.transfer_seconds,
            average_seconds: after.average_seconds - before.average_seconds,
            ..Default::default()
        })
    }

    fn all_reduce_flat(&mut self, data: &mut [f32]) -> Result<CollectiveStats> {
        let before = self.port.stats;
        self.port.exchange_flat(data)?;
        let after = self.port.stats;
        Ok(CollectiveStats {
            bytes_per_round: after.bytes_per_round,
            flatten_seconds: after.flatten_seconds - before.flatten_seconds,
            transfer_seconds: after.transfer_seconds - before.transfer_seconds,
            average_seconds: after.average_seconds - before.average_seconds,
            bucket_rounds: 1,
            ..Default::default()
        })
    }

    fn stats(&self) -> CollectiveStats {
        self.port.stats.into()
    }

    fn world_size(&self) -> usize {
        2
    }

    fn set_io_deadline(&mut self, d: Option<Duration>) -> Result<()> {
        self.port.set_deadline(d)
    }
}

/// Arbitrary N: chunked ring all-reduce over link transports.
///
/// N-1 reduce-scatter steps followed by N-1 all-gather steps over
/// nearly-equal chunks, then divide by N.  For N = 2 the arithmetic is
/// identical to the pairwise midpoint (`0.5 * (a + b)` in the same
/// f32 expression order), so results match the fast path exactly.
pub struct RingCollective {
    pub rank: usize,
    n: usize,
    to_next: Box<dyn Transport>,
    from_prev: Box<dyn Transport>,
    /// Message counter; advances once per hop message so skew anywhere
    /// in the 2(N-1)-step schedule is detected by `Endpoint::recv`.
    seq: u64,
    flat_buf: Vec<f32>,
    /// Outgoing chunk staging; ping-pongs with `chunk_in` (the buffer
    /// received from the previous rank becomes the next send's staging
    /// buffer), so the P2P path allocates nothing in steady state.
    chunk_out: Vec<f32>,
    chunk_in: Vec<f32>,
    stats: CollectiveStats,
}

/// Chunk boundaries: N nearly-equal spans covering `len` (the first
/// `len % n` chunks take one extra element; chunks may be empty when
/// `len < n`).
fn chunk_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut off = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((off, off + sz));
        off += sz;
    }
    out
}

impl RingCollective {
    /// Assemble one ring node from its two directed links — how the
    /// distributed rendezvous builds a node whose links are sockets.
    pub fn from_transports(
        rank: usize,
        n: usize,
        to_next: Box<dyn Transport>,
        from_prev: Box<dyn Transport>,
    ) -> Self {
        assert!(n >= 2, "a ring needs at least 2 nodes");
        assert!(rank < n, "rank {rank} out of range for a {n}-node ring");
        RingCollective {
            rank,
            n,
            to_next,
            from_prev,
            seq: 0,
            flat_buf: Vec::new(),
            chunk_out: Vec::new(),
            chunk_in: Vec::new(),
            stats: CollectiveStats::default(),
        }
    }

    fn send_recv_chunk(&mut self, lo: usize, hi: usize) -> Result<()> {
        let mut out = std::mem::take(&mut self.chunk_out);
        out.clear();
        out.extend_from_slice(&self.flat_buf[lo..hi]);
        self.to_next.send_vec(self.seq, out)?;
        self.from_prev.recv(self.seq, &mut self.chunk_in)?;
        self.seq += 1;
        Ok(())
    }

    fn check_chunk(&self, want: usize, phase: &str) -> Result<()> {
        if self.chunk_in.len() != want {
            return Err(Error::Protocol(format!(
                "ring {phase}: rank {} received {} values, expected {want}",
                self.rank,
                self.chunk_in.len()
            )));
        }
        Ok(())
    }

    /// Ring all-reduce-average of `self.flat_buf` in place: N-1
    /// reduce-scatter steps, N-1 all-gather steps, then divide by N.
    /// Shared by the monolithic store round and the per-bucket flat
    /// round, so both run the *same* schedule, summation order and
    /// sequence-number stream.  Returns (transfer, average) seconds.
    fn reduce_flat_in_place(&mut self) -> Result<(f64, f64)> {
        let n = self.n;
        let bounds = chunk_bounds(self.flat_buf.len(), n);
        let mut transfer_seconds = 0.0;
        let mut average_seconds = 0.0;

        // Reduce-scatter: after N-1 steps chunk (rank+1)%n holds the sum.
        for step in 0..n - 1 {
            let send_chunk = (self.rank + n - step) % n;
            let recv_chunk = (self.rank + n - step - 1) % n;
            let (s0, s1) = bounds[send_chunk];
            let t = Timer::start();
            self.send_recv_chunk(s0, s1)?;
            transfer_seconds += t.elapsed_secs();
            let (r0, r1) = bounds[recv_chunk];
            self.check_chunk(r1 - r0, "reduce-scatter")?;
            let t = Timer::start();
            accumulate(&mut self.flat_buf[r0..r1], &self.chunk_in);
            average_seconds += t.elapsed_secs();
            std::mem::swap(&mut self.chunk_out, &mut self.chunk_in);
        }
        // All-gather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_chunk = (self.rank + 1 + n - step) % n;
            let recv_chunk = (self.rank + n - step) % n;
            let (s0, s1) = bounds[send_chunk];
            let t = Timer::start();
            self.send_recv_chunk(s0, s1)?;
            transfer_seconds += t.elapsed_secs();
            let (r0, r1) = bounds[recv_chunk];
            self.check_chunk(r1 - r0, "all-gather")?;
            let t = Timer::start();
            self.flat_buf[r0..r1].copy_from_slice(&self.chunk_in);
            average_seconds += t.elapsed_secs();
            std::mem::swap(&mut self.chunk_out, &mut self.chunk_in);
        }

        let t = Timer::start();
        scale_in_place(&mut self.flat_buf, 1.0 / n as f32);
        average_seconds += t.elapsed_secs();
        Ok((transfer_seconds, average_seconds))
    }
}

impl Collective for RingCollective {
    fn all_reduce_average(
        &mut self,
        store: &mut ParamStore,
        include_momentum: bool,
    ) -> Result<CollectiveStats> {
        let t = Timer::start();
        store.flatten_into(&mut self.flat_buf, include_momentum);
        let mut flatten_seconds = t.elapsed_secs();
        let bytes = self.flat_buf.len() * 4;

        let (transfer_seconds, average_seconds) = self.reduce_flat_in_place()?;

        let t = Timer::start();
        store.unflatten_from(&self.flat_buf, include_momentum)?;
        flatten_seconds += t.elapsed_secs();

        let round = CollectiveStats {
            rounds: 1,
            bytes_per_round: bytes,
            flatten_seconds,
            transfer_seconds,
            average_seconds,
            ..Default::default()
        };
        self.stats.absorb(&round);
        Ok(round)
    }

    fn all_reduce_flat(&mut self, data: &mut [f32]) -> Result<CollectiveStats> {
        let t = Timer::start();
        self.flat_buf.clear();
        self.flat_buf.extend_from_slice(data);
        let mut flatten_seconds = t.elapsed_secs();
        let bytes = self.flat_buf.len() * 4;

        let (transfer_seconds, average_seconds) = self.reduce_flat_in_place()?;

        let t = Timer::start();
        data.copy_from_slice(&self.flat_buf);
        flatten_seconds += t.elapsed_secs();

        let round = CollectiveStats {
            bytes_per_round: bytes,
            flatten_seconds,
            transfer_seconds,
            average_seconds,
            bucket_rounds: 1,
            ..Default::default()
        };
        self.stats.absorb(&round);
        Ok(round)
    }

    fn stats(&self) -> CollectiveStats {
        self.stats
    }

    fn world_size(&self) -> usize {
        self.n
    }

    fn set_io_deadline(&mut self, d: Option<Duration>) -> Result<()> {
        self.to_next.set_deadline(d)?;
        self.from_prev.set_deadline(d)
    }
}

/// Connected pair of N = 2 fast-path collectives over one link.
pub fn pair_fabric(kind: TransportKind) -> (PairwiseCollective, PairwiseCollective) {
    let (a, b) = transport_pair(kind);
    (PairwiseCollective::new(a), PairwiseCollective::new(b))
}

/// Build a ring of `hops.len()` connected nodes; `hops[i]` is the
/// transport of the directed link `i -> (i+1) % n` (per-hop §4.4
/// downgrades supported — hops may mix kinds).
pub fn ring_fabric(hops: &[TransportKind]) -> Vec<RingCollective> {
    let n = hops.len();
    assert!(n >= 2, "a ring needs at least 2 nodes");
    let mut send_sides: Vec<Option<Endpoint>> = Vec::with_capacity(n);
    let mut recv_sides: Vec<Option<Endpoint>> = Vec::with_capacity(n);
    for &kind in hops {
        let (a, b) = transport_pair(kind);
        send_sides.push(Some(a));
        recv_sides.push(Some(b));
    }
    (0..n)
        .map(|i| {
            RingCollective::from_transports(
                i,
                n,
                Box::new(send_sides[i].take().unwrap()),
                Box::new(recv_sides[(i + n - 1) % n].take().unwrap()),
            )
        })
        .collect()
}

/// Build one collective handle per worker for the given hop transports
/// (`hops[i]` = transport of ring hop `i -> (i+1) % workers`; ignored
/// for N = 1, only `hops[0]` is used for the N = 2 fast path).
pub fn build_fabric(workers: usize, hops: &[TransportKind]) -> Vec<Box<dyn Collective>> {
    match workers {
        0 | 1 => vec![Box::new(NoopCollective::new()) as Box<dyn Collective>],
        2 => {
            assert!(!hops.is_empty(), "need the pair's hop transport");
            let (a, b) = pair_fabric(hops[0]);
            vec![Box::new(a) as Box<dyn Collective>, Box::new(b) as Box<dyn Collective>]
        }
        n => {
            assert_eq!(hops.len(), n, "need one hop transport per ring link");
            ring_fabric(hops)
                .into_iter()
                .map(|node| Box::new(node) as Box<dyn Collective>)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamManifestSpec;
    use crate::tensor::Shape;

    fn specs() -> Vec<ParamManifestSpec> {
        vec![
            ParamManifestSpec {
                name: "w".into(),
                shape: Shape::of(&[16, 4]),
                init: "normal".into(),
                std: 0.1,
                bias_value: 0.0,
            },
            ParamManifestSpec {
                name: "b".into(),
                shape: Shape::of(&[5]),
                init: "zeros".into(),
                std: 0.0,
                bias_value: 0.0,
            },
        ]
    }

    /// A store whose params are the constant `rank + 1` and momenta the
    /// constant `-(rank + 1)` — the group mean is exactly computable.
    fn rank_store(rank: usize) -> ParamStore {
        let mut s = ParamStore::init(&specs(), 0);
        for t in s.params.iter_mut() {
            t.as_mut_slice().fill((rank + 1) as f32);
        }
        for t in s.momenta.iter_mut() {
            t.as_mut_slice().fill(-((rank + 1) as f32));
        }
        s
    }

    fn run_group(
        mut fabrics: Vec<Box<dyn Collective>>,
        rounds: usize,
        include_momentum: bool,
    ) -> Vec<ParamStore> {
        let n = fabrics.len();
        let mut joins = Vec::with_capacity(n);
        for (rank, mut fabric) in fabrics.drain(..).enumerate() {
            joins.push(std::thread::spawn(move || {
                let mut store = rank_store(rank);
                for _ in 0..rounds {
                    fabric.all_reduce_average(&mut store, include_momentum).unwrap();
                }
                assert_eq!(fabric.rounds(), if n > 1 { rounds as u64 } else { 0 });
                store
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    }

    #[test]
    fn all_world_sizes_converge_to_the_exact_mean_on_all_transports() {
        for kind in [TransportKind::P2p, TransportKind::HostStaged, TransportKind::Serialized] {
            for n in [1usize, 2, 3, 4] {
                let fabrics = build_fabric(n, &vec![kind; n.max(1)]);
                assert!(fabrics.iter().all(|f| f.world_size() == n.max(1)));
                let stores = run_group(fabrics, 1, true);
                // Mean of params 1..=n is (n+1)/2; momenta are its negative.
                let want = (1..=n).sum::<usize>() as f32 / n as f32;
                for (rank, s) in stores.iter().enumerate() {
                    for t in &s.params {
                        for &v in t.as_slice() {
                            assert!(
                                (v - want).abs() < 1e-5,
                                "{kind:?} n={n} rank {rank}: param {v} vs {want}"
                            );
                        }
                    }
                    for t in &s.momenta {
                        for &v in t.as_slice() {
                            assert!((v + want).abs() < 1e-5, "{kind:?} n={n} rank {rank}");
                        }
                    }
                }
                // Every replica is bit-identical after the round.
                for s in &stores[1..] {
                    assert_eq!(stores[0].max_divergence(s), 0.0);
                }
            }
        }
    }

    #[test]
    fn momentum_exclusion_respected_for_all_n() {
        for n in [2usize, 3, 4] {
            let fabrics = build_fabric(n, &vec![TransportKind::P2p; n]);
            let stores = run_group(fabrics, 1, false);
            let want = (1..=n).sum::<usize>() as f32 / n as f32;
            for (rank, s) in stores.iter().enumerate() {
                // Params averaged...
                for t in &s.params {
                    assert!(t.as_slice().iter().all(|v| (v - want).abs() < 1e-5));
                }
                // ...momenta untouched (still the per-rank constant).
                let local = -((rank + 1) as f32);
                for t in &s.momenta {
                    assert!(t.as_slice().iter().all(|&v| v == local), "n={n} rank {rank}");
                }
            }
        }
    }

    #[test]
    fn ring_n2_matches_pairwise_bit_for_bit() {
        let pair = build_fabric(2, &[TransportKind::P2p]);
        let ring = ring_fabric(&[TransportKind::P2p; 2])
            .into_iter()
            .map(|n| Box::new(n) as Box<dyn Collective>)
            .collect::<Vec<_>>();
        let via_pair = run_group(pair, 3, true);
        let via_ring = run_group(ring, 3, true);
        for (a, b) in via_pair.iter().zip(&via_ring) {
            assert_eq!(a.max_divergence(b), 0.0, "N=2 ring must equal the pairwise path");
        }
    }

    #[test]
    fn per_phase_stats_accumulate() {
        let fabrics = build_fabric(3, &[TransportKind::Serialized; 3]);
        let mut joins = Vec::new();
        for (rank, mut fabric) in fabrics.into_iter().enumerate() {
            joins.push(std::thread::spawn(move || {
                let mut store = rank_store(rank);
                let round = fabric.all_reduce_average(&mut store, true).unwrap();
                assert_eq!(round.rounds, 1);
                fabric.all_reduce_average(&mut store, true).unwrap();
                fabric.stats()
            }));
        }
        for j in joins {
            let stats = j.join().unwrap();
            assert_eq!(stats.rounds, 2);
            // params (16*4 + 5) + momenta, f32.
            assert_eq!(stats.bytes_per_round, (16 * 4 + 5) * 2 * 4);
            assert!(stats.total_seconds() > 0.0);
            assert!(stats.transfer_seconds > 0.0);
        }
    }

    #[test]
    fn sequence_number_mismatch_detected() {
        let mut nodes = ring_fabric(&[TransportKind::P2p; 2]);
        let mut b = nodes.pop().unwrap();
        let mut a = nodes.pop().unwrap();
        // Inject a rogue message tagged with a stale round: rank 1's
        // first recv expects seq 0 and must reject 99 (§4.3 hazard).
        a.to_next.send_vec(99, vec![1.0, 2.0]).unwrap();
        let h = std::thread::spawn(move || {
            let mut store = rank_store(1);
            b.all_reduce_average(&mut store, true)
        });
        let err = h.join().unwrap().unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        drop(a);
    }

    #[test]
    fn chunk_bounds_cover_the_buffer() {
        let b = chunk_bounds(10, 3);
        assert_eq!(b, vec![(0, 4), (4, 7), (7, 10)]);
        let b = chunk_bounds(3, 4);
        assert_eq!(b.last().unwrap().1, 3);
    }

    #[test]
    fn ring_handles_buffers_smaller_than_the_group() {
        // A 3-element tensor across 4 ranks forces empty chunks.
        let tiny = vec![ParamManifestSpec {
            name: "w".into(),
            shape: Shape::of(&[3]),
            init: "zeros".into(),
            std: 0.0,
            bias_value: 0.0,
        }];
        let n = 4;
        let mut joins = Vec::new();
        for (rank, mut node) in ring_fabric(&vec![TransportKind::P2p; n]).into_iter().enumerate() {
            let specs = tiny.clone();
            joins.push(std::thread::spawn(move || {
                let mut store = ParamStore::init(&specs, 0);
                store.params[0].as_mut_slice().fill((rank + 1) as f32);
                node.all_reduce_average(&mut store, false).unwrap();
                store
            }));
        }
        for j in joins {
            let store = j.join().unwrap();
            assert!(store.params[0].as_slice().iter().all(|&v| (v - 2.5).abs() < 1e-6));
        }
    }

    #[test]
    fn noop_leaves_store_untouched_and_counts_nothing() {
        let mut noop = NoopCollective::new();
        let mut store = rank_store(4);
        let before = store.clone();
        let round = noop.all_reduce_average(&mut store, true).unwrap();
        assert_eq!(round.rounds, 0);
        assert_eq!(noop.rounds(), 0);
        assert_eq!(noop.world_size(), 1);
        assert_eq!(store.max_divergence(&before), 0.0);
    }

    #[test]
    fn flat_all_reduce_matches_the_mean_for_all_world_sizes() {
        for n in [2usize, 3, 4] {
            let fabrics = build_fabric(n, &vec![TransportKind::P2p; n]);
            let mut joins = Vec::new();
            for (rank, mut fabric) in fabrics.into_iter().enumerate() {
                joins.push(std::thread::spawn(move || {
                    // An awkward length: not divisible by any n in play.
                    let mut data = vec![(rank + 1) as f32; 103];
                    let round = fabric.all_reduce_flat(&mut data).unwrap();
                    assert_eq!(round.bucket_rounds, 1);
                    assert_eq!(round.rounds, 0);
                    data
                }));
            }
            let results: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
            let want = (1..=n).sum::<usize>() as f32 / n as f32;
            for (rank, data) in results.iter().enumerate() {
                assert!(
                    data.iter().all(|v| (v - want).abs() < 1e-6),
                    "n={n} rank {rank}"
                );
            }
            // Bitwise agreement across ranks.
            for data in &results[1..] {
                assert_eq!(&results[0], data);
            }
        }
    }

    #[test]
    fn flat_buckets_share_the_sequence_stream_with_store_rounds() {
        // A store round followed by two flat buckets must stay in
        // lockstep; a rank that skips a bucket is caught by the
        // sequence check on the next message, not silently averaged.
        let mut nodes = ring_fabric(&[TransportKind::P2p; 2]);
        let mut b = nodes.pop().unwrap();
        let mut a = nodes.pop().unwrap();
        let h = std::thread::spawn(move || {
            let mut store = rank_store(1);
            b.all_reduce_average(&mut store, true).unwrap();
            let mut bucket = vec![1.0f32; 8];
            b.all_reduce_flat(&mut bucket).unwrap();
            b.all_reduce_flat(&mut bucket).unwrap();
            b
        });
        let mut store = rank_store(0);
        a.all_reduce_average(&mut store, true).unwrap();
        let mut bucket = vec![2.0f32; 8];
        a.all_reduce_flat(&mut bucket).unwrap();
        a.all_reduce_flat(&mut bucket).unwrap();
        let b = h.join().unwrap();
        assert_eq!(a.stats().bucket_rounds, 2);
        assert_eq!(b.stats().bucket_rounds, 2);
        assert_eq!(a.stats().rounds, 1);
    }

    #[test]
    fn stale_bucket_message_is_a_protocol_error_not_a_hang() {
        let mut nodes = ring_fabric(&[TransportKind::P2p; 2]);
        let mut b = nodes.pop().unwrap();
        let mut a = nodes.pop().unwrap();
        // Rank 0 replays an old round number into the ring; rank 1's
        // bucket recv expects seq 0 and must reject it loudly.
        a.to_next.send_vec(7, vec![0.5; 4]).unwrap();
        let h = std::thread::spawn(move || {
            let mut bucket = vec![1.0f32; 8];
            b.all_reduce_flat(&mut bucket)
        });
        let err = h.join().unwrap().unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        drop(a);
    }

    #[test]
    fn mixed_hop_transports_still_average_exactly() {
        // The §4.4 shape: one same-switch P2P hop, two host-staged hops.
        let hops = [TransportKind::P2p, TransportKind::HostStaged, TransportKind::HostStaged];
        let fabrics = ring_fabric(&hops)
            .into_iter()
            .map(|n| Box::new(n) as Box<dyn Collective>)
            .collect::<Vec<_>>();
        let stores = run_group(fabrics, 2, true);
        let want = (1 + 2 + 3) as f32 / 3.0;
        // Two rounds of averaging an already-averaged group is stable.
        for s in &stores {
            for t in &s.params {
                assert!(t.as_slice().iter().all(|v| (v - want).abs() < 1e-5));
            }
        }
    }
}
