//! Fixed-bucket latency histogram (log-spaced) with quantile queries.

/// Log-spaced histogram from 1 µs to ~1000 s, for step/exchange/copy
/// latencies in the benches.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    total: u64,
    lo: f64,
    ratio: f64,
}

impl Histogram {
    /// 180 buckets, factor ~1.12 apart, covering [1e-6, ~1e3] seconds.
    pub fn new_latency() -> Self {
        Histogram::new(1e-6, 1.12, 180)
    }

    pub fn new(lo: f64, ratio: f64, buckets: usize) -> Self {
        assert!(lo > 0.0 && ratio > 1.0 && buckets > 0);
        Histogram { buckets: vec![0; buckets], total: 0, lo, ratio }
    }

    fn bucket_of(&self, v: f64) -> usize {
        if v <= self.lo {
            return 0;
        }
        let idx = ((v / self.lo).ln() / self.ratio.ln()) as usize;
        idx.min(self.buckets.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        let b = self.bucket_of(v);
        self.buckets[b] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Upper edge of the bucket containing quantile `q` (0..=1).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.lo * self.ratio.powi(i as i32 + 1);
            }
        }
        self.lo * self.ratio.powi(self.buckets.len() as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = Histogram::new_latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 < p99);
        // p50 of uniform [1e-5, 1e-2] is ~5e-3; allow a bucket factor.
        assert!((2e-3..9e-3).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn empty_quantile_zero() {
        let h = Histogram::new_latency();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn out_of_range_clamps() {
        let mut h = Histogram::new(1e-6, 2.0, 4);
        h.record(1e-9);
        h.record(1e9);
        assert_eq!(h.count(), 2);
    }
}
