//! Streaming mean/variance/min/max (Welford's algorithm).

/// Numerically stable running statistics over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        RunningStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_two_pass() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_is_safe() {
        let s = RunningStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), 0.0);
    }
}
