//! Images/second + seconds-per-20-iterations meters.
//!
//! Table 1's unit is "training time per 20 iterations"; the meter keeps
//! that native so logs read like the paper.

use crate::util::Timer;

/// Windowed throughput meter.
#[derive(Debug)]
pub struct ThroughputMeter {
    timer: Timer,
    window_steps: usize,
    steps_in_window: usize,
    images_in_window: usize,
    pub last_window_secs: f64,
    pub last_images_per_sec: f64,
    total_steps: usize,
    total_images: usize,
    total_secs: f64,
}

impl ThroughputMeter {
    /// `window_steps` = 20 reproduces the paper's reporting unit.
    pub fn new(window_steps: usize) -> Self {
        ThroughputMeter {
            timer: Timer::start(),
            window_steps: window_steps.max(1),
            steps_in_window: 0,
            images_in_window: 0,
            last_window_secs: 0.0,
            last_images_per_sec: 0.0,
            total_steps: 0,
            total_images: 0,
            total_secs: 0.0,
        }
    }

    /// Record one step of `images` examples; returns Some(window secs)
    /// when a window just closed.
    pub fn step(&mut self, images: usize) -> Option<f64> {
        self.steps_in_window += 1;
        self.images_in_window += images;
        self.total_steps += 1;
        self.total_images += images;
        if self.steps_in_window == self.window_steps {
            let secs = self.timer.restart().as_secs_f64();
            self.last_window_secs = secs;
            self.last_images_per_sec =
                if secs > 0.0 { self.images_in_window as f64 / secs } else { 0.0 };
            self.total_secs += secs;
            self.steps_in_window = 0;
            self.images_in_window = 0;
            Some(secs)
        } else {
            None
        }
    }

    pub fn total_steps(&self) -> usize {
        self.total_steps
    }

    /// Mean seconds per `window_steps` iterations across closed windows.
    pub fn mean_window_secs(&self) -> f64 {
        let windows = self.total_steps / self.window_steps;
        if windows == 0 {
            0.0
        } else {
            self.total_secs / windows as f64
        }
    }

    pub fn overall_images_per_sec(&self) -> f64 {
        if self.total_secs > 0.0 {
            // Count only images inside closed windows.
            let closed = (self.total_steps / self.window_steps) * self.window_steps;
            let per_step = if self.total_steps > 0 {
                self.total_images as f64 / self.total_steps as f64
            } else {
                0.0
            };
            closed as f64 * per_step / self.total_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_closes_every_n_steps() {
        let mut m = ThroughputMeter::new(5);
        let mut closes = 0;
        for _ in 0..12 {
            if m.step(4).is_some() {
                closes += 1;
            }
        }
        assert_eq!(closes, 2);
        assert_eq!(m.total_steps(), 12);
    }

    #[test]
    fn rates_positive() {
        let mut m = ThroughputMeter::new(2);
        m.step(8);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.step(8);
        assert!(m.last_window_secs > 0.0);
        assert!(m.last_images_per_sec > 0.0);
        assert!(m.mean_window_secs() > 0.0);
        assert!(m.overall_images_per_sec() > 0.0);
    }
}
