//! Images/second + seconds-per-20-iterations meters.
//!
//! Table 1's unit is "training time per 20 iterations"; the meter keeps
//! that native so logs read like the paper.

use crate::util::Timer;

/// Windowed throughput meter.
#[derive(Debug)]
pub struct ThroughputMeter {
    timer: Timer,
    window_steps: usize,
    steps_in_window: usize,
    images_in_window: usize,
    pub last_window_secs: f64,
    pub last_images_per_sec: f64,
    total_steps: usize,
    total_images: usize,
    /// Images inside *closed* windows — the numerator that matches
    /// `total_secs` (which only accumulates at window close).
    closed_images: usize,
    total_secs: f64,
}

impl ThroughputMeter {
    /// `window_steps` = 20 reproduces the paper's reporting unit.
    pub fn new(window_steps: usize) -> Self {
        ThroughputMeter {
            timer: Timer::start(),
            window_steps: window_steps.max(1),
            steps_in_window: 0,
            images_in_window: 0,
            last_window_secs: 0.0,
            last_images_per_sec: 0.0,
            total_steps: 0,
            total_images: 0,
            closed_images: 0,
            total_secs: 0.0,
        }
    }

    /// Record one step of `images` examples; returns Some(window secs)
    /// when a window just closed.
    pub fn step(&mut self, images: usize) -> Option<f64> {
        self.steps_in_window += 1;
        self.images_in_window += images;
        self.total_steps += 1;
        self.total_images += images;
        if self.steps_in_window == self.window_steps {
            let secs = self.timer.restart().as_secs_f64();
            self.last_window_secs = secs;
            self.last_images_per_sec =
                if secs > 0.0 { self.images_in_window as f64 / secs } else { 0.0 };
            self.total_secs += secs;
            self.closed_images += self.images_in_window;
            self.steps_in_window = 0;
            self.images_in_window = 0;
            Some(secs)
        } else {
            None
        }
    }

    pub fn total_steps(&self) -> usize {
        self.total_steps
    }

    /// Mean seconds per `window_steps` iterations across closed windows.
    pub fn mean_window_secs(&self) -> f64 {
        let windows = self.total_steps / self.window_steps;
        if windows == 0 {
            0.0
        } else {
            self.total_secs / windows as f64
        }
    }

    /// Images actually recorded inside closed windows.  This is a
    /// count, not the old `closed_steps × mean images/step` estimate —
    /// that estimate was wrong whenever batch sizes vary (ragged eval
    /// tails, serve-mode dynamic batches) and the open window's steps
    /// skew the mean.
    pub fn closed_window_images(&self) -> usize {
        self.closed_images
    }

    /// Wall seconds accumulated by closed windows.
    pub fn closed_seconds(&self) -> f64 {
        self.total_secs
    }

    pub fn overall_images_per_sec(&self) -> f64 {
        if self.total_secs > 0.0 {
            // Only images inside closed windows: the open window has
            // contributed no time yet, so its images must not count.
            self.closed_images as f64 / self.total_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_closes_every_n_steps() {
        let mut m = ThroughputMeter::new(5);
        let mut closes = 0;
        for _ in 0..12 {
            if m.step(4).is_some() {
                closes += 1;
            }
        }
        assert_eq!(closes, 2);
        assert_eq!(m.total_steps(), 12);
    }

    #[test]
    fn ragged_batches_count_actual_images() {
        // Regression: with varying batch sizes the meter used to
        // estimate closed-window images as closed_steps × the mean
        // images/step over ALL steps — the open window's ragged steps
        // leaked into the closed-window numerator.  Count, don't model.
        let mut m = ThroughputMeter::new(2);
        m.step(8);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.step(8); // window closes: 16 images inside
        m.step(1); // ragged tail, window still open
        assert_eq!(m.closed_window_images(), 16);
        assert!(m.closed_seconds() > 0.0);
        let expected = 16.0 / m.closed_seconds();
        assert!((m.overall_images_per_sec() - expected).abs() < 1e-9);
        // Old estimate would have claimed 2 × (17/3) ≈ 11.33 images.
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.step(1); // second window closes: 2 more images
        assert_eq!(m.closed_window_images(), 18);
    }

    #[test]
    fn rates_positive() {
        let mut m = ThroughputMeter::new(2);
        m.step(8);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.step(8);
        assert!(m.last_window_secs > 0.0);
        assert!(m.last_images_per_sec > 0.0);
        assert!(m.mean_window_secs() > 0.0);
        assert!(m.overall_images_per_sec() > 0.0);
    }
}
