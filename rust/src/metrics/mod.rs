//! Training/bench metrics: running statistics, histograms, throughput
//! meters and a CSV sink for loss curves and bench tables.

mod csv;
mod histogram;
mod stats;
mod throughput;

pub use csv::CsvWriter;
pub use histogram::Histogram;
pub use stats::RunningStats;
pub use throughput::ThroughputMeter;
