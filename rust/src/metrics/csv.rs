//! Tiny CSV writer for loss curves and bench tables.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};

/// Append-only CSV file with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
            }
        }
        let f = File::create(path).map_err(|e| Error::io(path, e))?;
        let mut w = CsvWriter { out: BufWriter::new(f), columns: header.len() };
        w.write_row_str(header)?;
        Ok(w)
    }

    /// Open for appending (checkpoint resume): existing rows are kept
    /// and the header is written only when the file is new or empty, so
    /// a resumed run extends the pre-kill curve instead of truncating
    /// it.
    pub fn append(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
            }
        }
        let has_rows = std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false);
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| Error::io(path, e))?;
        let mut w = CsvWriter { out: BufWriter::new(f), columns: header.len() };
        if !has_rows {
            w.write_row_str(header)?;
        }
        Ok(w)
    }

    fn write_row_str(&mut self, cells: &[&str]) -> Result<()> {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if c.contains(',') || c.contains('"') {
                line.push('"');
                line.push_str(&c.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(c);
            }
        }
        line.push('\n');
        self.out.write_all(line.as_bytes()).map_err(Error::RawIo)
    }

    /// Write one data row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        if cells.len() != self.columns {
            return Err(Error::msg(format!(
                "csv row has {} cells, header has {}",
                cells.len(),
                self.columns
            )));
        }
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        self.write_row_str(&refs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush().map_err(Error::RawIo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let path = std::env::temp_dir().join(format!("tmg_csv_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&["1".into(), "2.5".into()]).unwrap();
            w.row(&["2".into(), "2,1".into()]).unwrap();
            w.flush().unwrap();
            assert!(w.row(&["only-one".into()]).is_err());
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "step,loss\n1,2.5\n2,\"2,1\"\n");
    }

    #[test]
    fn append_extends_without_rewriting_the_header() {
        let path = std::env::temp_dir().join(format!("tmg_csv_app_{}.csv", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            // First open on a fresh file still writes the header.
            let mut w = CsvWriter::append(&path, &["step", "loss"]).unwrap();
            w.row(&["1".into(), "2.5".into()]).unwrap();
            w.flush().unwrap();
        }
        {
            // Reopening (the resume case) keeps prior rows, no 2nd header.
            let mut w = CsvWriter::append(&path, &["step", "loss"]).unwrap();
            w.row(&["2".into(), "2.0".into()]).unwrap();
            w.flush().unwrap();
        }
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "step,loss\n1,2.5\n2,2.0\n");
    }
}
