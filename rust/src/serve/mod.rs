//! `tmg serve` — a dynamic-batching inference server.
//!
//! The paper hides *loading* latency behind compute with a
//! double-buffer (Fig 1); serving turns the same idea around for
//! request latency: a queue in front of M eval replicas forms batches
//! dynamically — flush when `max_batch` requests are waiting **or**
//! when the oldest has waited `deadline` — so throughput comes from
//! batching without unbounded tail latency.
//!
//! Structure (std only, no new crates):
//!
//! - [`queue`] — the [`queue::Batcher`]: a mutex/condvar request queue
//!   with the two flush conditions and drain-on-close semantics.
//! - [`server`] — the [`server::Server`]: one immutable shared
//!   [`ParamStore`](crate::params::ParamStore), M replica threads (each
//!   its own `build_eval_backend` + [`Engine`](crate::coordinator::eval::Engine)),
//!   and a TCP line-protocol front end.
//! - [`loadgen`] — closed-loop and open-loop (arrival-rate) load
//!   generators for the client mode, the bench, and CI.
//!
//! ## Protocol
//!
//! Newline-delimited requests over TCP, one in flight per connection
//! (drive concurrency with connections):
//!
//! ```text
//! hello                 -> ok model=M hw=H channels=C classes=K topk=T
//! classify <hex pixels> -> ok <class>:<prob> <class>:<prob> ...
//! stats                 -> ok served=N batches=N ... (key=value pairs)
//! quit                  -> connection closes
//! anything else         -> err <message>
//! ```
//!
//! `classify` takes one stored-size image as lowercase hex of
//! `channels*hw*hw` raw `u8` pixels; the reply ranks classes exactly
//! like `tmg eval` counts them (logits order, ties to the lower class
//! index), and probabilities print with `f32`'s shortest-roundtrip
//! `Display`, so parsing a reply reproduces the server's floats bit for
//! bit.

pub mod loadgen;
pub mod queue;
pub mod server;

pub use self::queue::{Batcher, Reply, Request};
pub use self::server::{ServeOpts, Server, StatsSnapshot};

use crate::error::{Error, Result};

/// Lowercase hex of raw bytes (the `classify` request payload).
pub fn hex_encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// Decode the `classify` payload; accepts upper- or lowercase hex.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    let s = s.as_bytes();
    if s.len() % 2 != 0 {
        return Err(Error::msg("hex payload has odd length"));
    }
    fn nibble(c: u8) -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(Error::msg(format!("invalid hex byte {:?}", c as char))),
        }
    }
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in s.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let data: Vec<u8> = (0u16..=255).map(|b| b as u8).collect();
        let enc = hex_encode(&data);
        assert_eq!(enc.len(), 512);
        assert_eq!(hex_decode(&enc).unwrap(), data);
        assert_eq!(hex_decode(&enc.to_uppercase()).unwrap(), data);
        assert_eq!(hex_encode(&[0x00, 0xff, 0x1a]), "00ff1a");
    }

    #[test]
    fn hex_rejects_garbage() {
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
        assert!(hex_decode("").unwrap().is_empty());
    }
}
