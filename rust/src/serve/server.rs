//! The serving core: one shared checkpoint, M eval replicas, a TCP
//! line-protocol front end.
//!
//! The checkpoint's parameters load **once** into an immutable
//! `Arc<ParamStore>` — replicas share them read-only, exactly the way
//! eval treats the store everywhere else.  Each replica thread owns its
//! own `build_eval_backend` instance (workspaces, compute pool) wrapped
//! in the shared [`Engine`], pulls dynamically formed batches off the
//! [`Batcher`], and answers every request in the batch.
//!
//! Ops surface: per-stage timings (queue wait, batch fill, compute) in
//! log-spaced histograms with p50/p99, queue depth, batch fill sizes —
//! exposed over the `stats` protocol verb, a periodic log line, and
//! [`Server::shutdown`]'s final snapshot.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::StepBackend;
use crate::config::TrainConfig;
use crate::coordinator::eval::Engine;
use crate::data::preprocess::MeanImage;
use crate::data::synth::DatasetMeta;
use crate::error::{Error, Result};
use crate::metrics::Histogram;
use crate::params::ParamStore;
use crate::serve::queue::{Batcher, Reply, Request};
use crate::util::Timer;

/// Emit the per-stage timing log line every this many served requests.
const LOG_EVERY: u64 = 256;

/// Socket read poll interval: bounds how often a connection handler
/// checks its idle clock while the peer is silent.
const READ_POLL: Duration = Duration::from_millis(500);

/// Hard cap on one request line.  A `classify` payload is 2 hex chars
/// per byte, so 16 MiB covers every supported geometry with a wide
/// margin; anything longer is a runaway or hostile client.
const MAX_LINE_BYTES: usize = 16 << 20;

/// Serving knobs (CLI flags map 1:1).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Eval replicas — independent backends sharing one `ParamStore`.
    pub replicas: usize,
    /// Flush a batch at this size even before the deadline.
    pub max_batch: usize,
    /// Flush a batch when its oldest request has waited this long.
    pub deadline: Duration,
    /// Classes per reply.
    pub topk: usize,
    /// TCP port on 127.0.0.1; 0 picks an ephemeral port.
    pub port: u16,
    /// Evict a connection that has sent no bytes for this long (the
    /// client gets an `err idle ...` reply before the close).
    pub idle_timeout: Duration,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            replicas: 1,
            max_batch: 8,
            deadline: Duration::from_millis(5),
            topk: 5,
            port: 0,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// Shared counters + per-stage latency histograms.
pub struct ServeStats {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    hists: Mutex<Hists>,
}

struct Hists {
    /// Per request: enqueue → taken by a replica.
    queue: Histogram,
    /// Per batch: oldest request's enqueue → batch taken (how long the
    /// batch took to form).
    fill: Histogram,
    /// Per batch: preprocess + forward.
    compute: Histogram,
    /// Exact batch-size counts, index = size (0..=max_batch).
    sizes: Vec<u64>,
}

/// Point-in-time stats reading (all latencies in milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    pub served: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_fill: f64,
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    pub fill_p50_ms: f64,
    pub fill_p99_ms: f64,
    pub compute_p50_ms: f64,
    pub compute_p99_ms: f64,
}

impl StatsSnapshot {
    /// The per-stage timing line: one `key=value` vocabulary shared by
    /// the periodic log, the `stats` verb, and the shutdown summary.
    pub fn line(&self, depth: usize) -> String {
        format!(
            "served={} batches={} errors={} depth={depth} mean_fill={:.2} \
             queue_p50_ms={:.3} queue_p99_ms={:.3} fill_p50_ms={:.3} fill_p99_ms={:.3} \
             compute_p50_ms={:.3} compute_p99_ms={:.3}",
            self.served,
            self.batches,
            self.errors,
            self.mean_fill,
            self.queue_p50_ms,
            self.queue_p99_ms,
            self.fill_p50_ms,
            self.fill_p99_ms,
            self.compute_p50_ms,
            self.compute_p99_ms
        )
    }
}

impl ServeStats {
    fn new(max_batch: usize) -> ServeStats {
        ServeStats {
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            hists: Mutex::new(Hists {
                queue: Histogram::new_latency(),
                fill: Histogram::new_latency(),
                compute: Histogram::new_latency(),
                sizes: vec![0; max_batch + 1],
            }),
        }
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let h = self.hists.lock().unwrap();
        let served = self.served.load(Ordering::SeqCst);
        let batches = self.batches.load(Ordering::SeqCst);
        StatsSnapshot {
            served,
            batches,
            errors: self.errors.load(Ordering::SeqCst),
            mean_fill: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
            queue_p50_ms: h.queue.quantile(0.5) * 1e3,
            queue_p99_ms: h.queue.quantile(0.99) * 1e3,
            fill_p50_ms: h.fill.quantile(0.5) * 1e3,
            fill_p99_ms: h.fill.quantile(0.99) * 1e3,
            compute_p50_ms: h.compute.quantile(0.5) * 1e3,
            compute_p99_ms: h.compute.quantile(0.99) * 1e3,
        }
    }

    /// Exact count of batches that flushed at each size.
    pub fn size_counts(&self) -> Vec<u64> {
        self.hists.lock().unwrap().sizes.clone()
    }
}

/// What one replica thread needs (cloned per replica; the store is the
/// one shared, immutable piece).
struct ReplicaCtx {
    store: Arc<ParamStore>,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    mean: MeanImage,
    stored_hw: usize,
    topk: usize,
}

impl Clone for ReplicaCtx {
    fn clone(&self) -> Self {
        ReplicaCtx {
            store: self.store.clone(),
            batcher: self.batcher.clone(),
            stats: self.stats.clone(),
            mean: self.mean.clone(),
            stored_hw: self.stored_hw,
            topk: self.topk,
        }
    }
}

fn replica_main(mut backend: Box<dyn StepBackend>, ctx: ReplicaCtx) {
    let mut engine = match Engine::new(backend.as_mut(), ctx.mean, ctx.stored_hw) {
        Ok(e) => e,
        Err(e) => {
            // A replica that can't preprocess can't serve; close the
            // queue so requests bounce instead of waiting forever.
            log::error!("serve replica failed to start: {e}");
            ctx.batcher.close();
            return;
        }
    };
    while let Some(batch) = ctx.batcher.next_batch() {
        let taken = Instant::now();
        let n = batch.len();
        engine.begin(n);
        let t = Timer::start();
        let mut failure: Option<String> = None;
        for (bi, r) in batch.iter().enumerate() {
            if let Err(e) = engine.stage(bi, &r.pixels) {
                failure = Some(e.to_string());
                break;
            }
        }
        let ranked = match failure {
            Some(msg) => Err(msg),
            None => engine
                .classify_staged(&ctx.store, ctx.topk)
                .map_err(|e| e.to_string())
                .and_then(|rows| {
                    if rows.len() == n {
                        Ok(rows)
                    } else {
                        Err(format!("backend returned {} rows for {n} requests", rows.len()))
                    }
                }),
        };
        let compute_secs = t.elapsed_secs();
        {
            let mut h = ctx.stats.hists.lock().unwrap();
            for r in &batch {
                h.queue.record(taken.duration_since(r.enqueued).as_secs_f64());
            }
            h.fill.record(taken.duration_since(batch[0].enqueued).as_secs_f64());
            h.compute.record(compute_secs);
            let slot = n.min(h.sizes.len() - 1);
            h.sizes[slot] += 1;
        }
        ctx.stats.batches.fetch_add(1, Ordering::SeqCst);
        if ranked.is_err() {
            ctx.stats.errors.fetch_add(n as u64, Ordering::SeqCst);
        }
        let before = ctx.stats.served.fetch_add(n as u64, Ordering::SeqCst);
        for (bi, r) in batch.into_iter().enumerate() {
            let topk = match &ranked {
                Ok(rows) => Ok(rows[bi].clone()),
                Err(m) => Err(m.clone()),
            };
            // A receiver gone (client hung up mid-wait) is fine.
            let _ = r.resp.send(Reply {
                topk,
                queue_secs: taken.duration_since(r.enqueued).as_secs_f64(),
                compute_secs,
                batch_size: n,
            });
        }
        if before / LOG_EVERY != (before + n as u64) / LOG_EVERY {
            log::info!("serve: {}", ctx.stats.snapshot().line(ctx.batcher.depth()));
        }
    }
}

/// What every connection handler needs.
struct FrontCtx {
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    /// Expected `classify` payload: channels * hw * hw raw bytes.
    input_bytes: usize,
    /// Canned `hello` reply (model geometry for clients).
    hello: String,
    /// Evict a connection after this long with no bytes from the peer.
    idle_timeout: Duration,
}

fn answer(line: &str, ctx: &FrontCtx) -> Option<String> {
    let line = line.trim();
    if line.is_empty() {
        return Some("err empty request".into());
    }
    let (verb, rest) = match line.split_once(' ') {
        Some((v, r)) => (v, r.trim()),
        None => (line, ""),
    };
    match verb {
        "hello" => Some(ctx.hello.clone()),
        "stats" => Some(format!("ok {}", ctx.stats.snapshot().line(ctx.batcher.depth()))),
        "quit" => None,
        "classify" => {
            let pixels = match crate::serve::hex_decode(rest) {
                Ok(p) => p,
                Err(e) => return Some(format!("err {e}")),
            };
            if pixels.len() != ctx.input_bytes {
                return Some(format!(
                    "err payload is {} bytes, model wants {}",
                    pixels.len(),
                    ctx.input_bytes
                ));
            }
            let (tx, rx) = mpsc::channel();
            let req = Request { pixels, enqueued: Instant::now(), resp: tx };
            if ctx.batcher.submit(req).is_err() {
                return Some("err server shutting down".into());
            }
            match rx.recv() {
                Ok(reply) => match reply.topk {
                    Ok(rows) => {
                        let mut s = String::from("ok");
                        for (class, prob) in rows {
                            // `{}` on f32 prints the shortest string
                            // that round-trips: clients parsing this
                            // recover the server's floats bit-exactly.
                            s.push_str(&format!(" {class}:{prob}"));
                        }
                        Some(s)
                    }
                    Err(m) => Some(format!("err {m}")),
                },
                Err(_) => Some("err server shutting down".into()),
            }
        }
        other => Some(format!("err unknown command {other:?}")),
    }
}

fn handle_conn(stream: TcpStream, ctx: Arc<FrontCtx>) {
    let _ = stream.set_nodelay(true);
    // Finite read timeout so the idle clock is polled even while the
    // peer is silent; partial lines stay buffered across polls.
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Byte-level line assembly (instead of `read_line`) so a non-UTF-8
    // request is *answered* with an `err` line, not silently dropped.
    let mut acc: Vec<u8> = Vec::new();
    let mut last_activity = Instant::now();
    loop {
        // Assemble the next full line into `line`; None = clean EOF.
        let line: Option<Vec<u8>> = loop {
            if let Some(nl) = acc.iter().position(|&b| b == b'\n') {
                let rest = acc.split_off(nl + 1);
                let mut line = std::mem::replace(&mut acc, rest);
                line.pop(); // the newline itself
                break Some(line);
            }
            let filled = match reader.fill_buf() {
                Ok([]) => break None, // EOF
                Ok(buf) => {
                    acc.extend_from_slice(buf);
                    buf.len()
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    // Idle eviction: a client that sends nothing for
                    // the whole budget is told why and disconnected —
                    // its handler thread must not live forever.
                    if last_activity.elapsed() >= ctx.idle_timeout {
                        let msg = format!(
                            "err idle for {:?} with no request — closing\n",
                            ctx.idle_timeout
                        );
                        let _ = writer.write_all(msg.as_bytes());
                        return;
                    }
                    continue;
                }
                Err(_) => return,
            };
            reader.consume(filled);
            last_activity = Instant::now();
            if acc.len() > MAX_LINE_BYTES {
                let _ = writer.write_all(b"err request line exceeds 16 MiB - closing\n");
                return;
            }
        };
        let Some(line) = line else { return };
        let reply = match std::str::from_utf8(&line) {
            Ok(s) => answer(s, &ctx),
            // A malformed (non-UTF-8) request gets a protocol-shaped
            // error reply instead of a silent connection drop.
            Err(_) => Some("err request is not valid utf-8".into()),
        };
        match reply {
            Some(mut s) => {
                s.push('\n');
                if writer.write_all(s.as_bytes()).and_then(|_| writer.flush()).is_err() {
                    return;
                }
            }
            None => {
                let _ = writer.write_all(b"ok bye\n");
                return;
            }
        }
    }
}

/// A running serve instance.
pub struct Server {
    addr: SocketAddr,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    replicas: Vec<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Spin up replicas + front end.  `store` already holds the
    /// checkpoint; the corpus dir supplies the preprocessing constants
    /// (`meta.json` geometry + `mean.f32`), same as eval.
    pub fn start(cfg: &TrainConfig, store: Arc<ParamStore>, opts: ServeOpts) -> Result<Server> {
        let meta_path = cfg.data.dir.join("meta.json");
        let meta_src =
            std::fs::read_to_string(&meta_path).map_err(|e| Error::io(&meta_path, e))?;
        let meta = DatasetMeta::from_json(&meta_src)?;
        let mean =
            MeanImage::load(&cfg.data.dir.join("mean.f32"), meta.channels, meta.hw)?;

        // Build every replica backend up front: a bad config fails
        // loudly here, not inside a detached thread.
        let replicas = opts.replicas.max(1);
        let mut backends = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            backends.push(crate::backend::build_eval_backend(cfg)?);
        }
        let first = &backends[0];
        if !first.supports_eval() || !first.supports_predict() {
            return Err(Error::msg(format!(
                "backend {:?} cannot serve per-example predictions for model {:?}; \
                 run with --backend native",
                first.name(),
                cfg.model
            )));
        }
        let model = first.model();
        if model.image_hw > meta.hw {
            return Err(Error::Shape(format!(
                "model crop {} larger than stored image {}",
                model.image_hw, meta.hw
            )));
        }
        let hello = format!(
            "ok model={} hw={} channels={} classes={} topk={}",
            cfg.model,
            meta.hw,
            meta.channels,
            model.num_classes,
            opts.topk.clamp(1, model.num_classes)
        );

        let batcher = Arc::new(Batcher::new(opts.max_batch, opts.deadline));
        let stats = Arc::new(ServeStats::new(opts.max_batch.max(1)));
        let ctx = ReplicaCtx {
            store,
            batcher: batcher.clone(),
            stats: stats.clone(),
            mean,
            stored_hw: meta.hw,
            topk: opts.topk,
        };
        let mut replica_handles = Vec::with_capacity(replicas);
        for (i, backend) in backends.into_iter().enumerate() {
            let ctx = ctx.clone();
            let h = std::thread::Builder::new()
                .name(format!("tmg-serve-r{i}"))
                .spawn(move || replica_main(backend, ctx))
                .map_err(Error::RawIo)?;
            replica_handles.push(h);
        }

        let listener = TcpListener::bind(("127.0.0.1", opts.port)).map_err(Error::RawIo)?;
        let addr = listener.local_addr().map_err(Error::RawIo)?;
        listener.set_nonblocking(true).map_err(Error::RawIo)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let front = Arc::new(FrontCtx {
            batcher: batcher.clone(),
            stats: stats.clone(),
            input_bytes: meta.channels * meta.hw * meta.hw,
            hello,
            idle_timeout: opts.idle_timeout,
        });
        let stop = shutdown.clone();
        let accept = std::thread::Builder::new()
            .name("tmg-serve-accept".into())
            .spawn(move || loop {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let ctx = front.clone();
                        // Handlers are detached: they exit on EOF, a
                        // write failure, or a post-shutdown submit.
                        let _ = std::thread::Builder::new()
                            .name("tmg-serve-conn".into())
                            .spawn(move || handle_conn(stream, ctx));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(2)),
                }
            })
            .map_err(Error::RawIo)?;

        log::info!(
            "serve: listening on {addr} ({replicas} replica(s), max_batch {}, deadline {:?}, \
             idle timeout {:?})",
            opts.max_batch,
            opts.deadline,
            opts.idle_timeout
        );
        Ok(Server {
            addr,
            batcher,
            stats,
            shutdown,
            replicas: replica_handles,
            accept: Some(accept),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Requests answered so far.
    pub fn served(&self) -> u64 {
        self.stats.served.load(Ordering::SeqCst)
    }

    /// Requests currently waiting in the queue.
    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Graceful stop: close the queue (pending requests drain — every
    /// accepted `classify` still gets its answer), join the replicas,
    /// then stop accepting.  Returns the final stats snapshot.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.batcher.close();
        for h in self.replicas.drain(..) {
            let _ = h.join();
        }
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let snap = self.stats.snapshot();
        log::info!("serve: drained; final {}", snap.line(0));
        snap
    }
}
