//! The dynamic-batching request queue.
//!
//! One mutex/condvar queue feeds every replica.  A replica's
//! [`Batcher::next_batch`] blocks until a batch is *ready*:
//!
//! - `max_batch` requests are waiting (size flush — throughput), or
//! - the oldest waiting request has aged past `deadline` (deadline
//!   flush — bounded tail latency), or
//! - the queue has been closed (shutdown drains whatever is left).
//!
//! That is the paper's Fig-1 inversion: training overlap *hides* load
//! time behind compute; serving instead *spends* a bounded deadline to
//! buy batch size.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One in-flight classification request.
pub struct Request {
    /// Raw stored-size image: `channels * hw * hw` bytes.
    pub pixels: Vec<u8>,
    /// When the request entered the queue (queue-wait timing origin).
    pub enqueued: Instant,
    /// Where the replica sends the answer.
    pub resp: mpsc::Sender<Reply>,
}

/// A replica's answer to one request.
pub struct Reply {
    /// Ranked `(class, softmax prob)` — or an error message (the crate
    /// error type is not `Clone`, and one failure answers a whole
    /// batch).
    pub topk: std::result::Result<Vec<(usize, f32)>, String>,
    /// Seconds spent queued before a replica took the batch.
    pub queue_secs: f64,
    /// Seconds of preprocess + forward for the whole batch.
    pub compute_secs: f64,
    /// How full the dynamically formed batch was.
    pub batch_size: usize,
}

struct State {
    q: VecDeque<Request>,
    open: bool,
}

/// Shared request queue with size/deadline flush (see module docs).
pub struct Batcher {
    state: Mutex<State>,
    cv: Condvar,
    max_batch: usize,
    deadline: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, deadline: Duration) -> Batcher {
        Batcher {
            state: Mutex::new(State { q: VecDeque::new(), open: true }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            deadline,
        }
    }

    /// Enqueue a request; hands it back when the queue is closed so the
    /// caller can answer "shutting down" instead of dropping it.
    pub fn submit(&self, r: Request) -> std::result::Result<(), Request> {
        let mut s = self.state.lock().unwrap();
        if !s.open {
            return Err(r);
        }
        s.q.push_back(r);
        // Wake every waiter: a size flush may free a full batch for one
        // replica while another should go back to a deadline wait.
        self.cv.notify_all();
        Ok(())
    }

    /// Requests currently waiting (the ops-surface `depth` gauge).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    /// Stop accepting new requests.  Blocked replicas wake up, drain
    /// what is queued, then get `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().open = false;
        self.cv.notify_all();
    }

    /// Block until a batch is ready; `None` means closed *and* drained
    /// — the replica's signal to exit.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.q.is_empty() {
                // Compute the age once: between a timed-out wait and
                // this check the clock has advanced, and
                // `deadline - waited` must never underflow.
                let waited = s.q.front().expect("nonempty").enqueued.elapsed();
                if !s.open || s.q.len() >= self.max_batch || waited >= self.deadline {
                    let n = s.q.len().min(self.max_batch);
                    return Some(s.q.drain(..n).collect());
                }
                let (guard, _) = self.cv.wait_timeout(s, self.deadline - waited).unwrap();
                s = guard;
            } else if !s.open {
                return None;
            } else {
                s = self.cv.wait(s).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(tag: u8) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        (Request { pixels: vec![tag], enqueued: Instant::now(), resp: tx }, rx)
    }

    #[test]
    fn size_flush_caps_and_preserves_fifo() {
        let b = Batcher::new(3, Duration::from_secs(60));
        for tag in 0..5u8 {
            let (r, _rx) = req(tag);
            b.submit(r).ok().unwrap();
        }
        assert_eq!(b.depth(), 5);
        // 5 waiting, max 3: first batch is [0,1,2] — immediately, the
        // deadline is an hour away.
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t.elapsed() < Duration::from_secs(5));
        assert_eq!(batch.iter().map(|r| r.pixels[0]).collect::<Vec<_>>(), [0, 1, 2]);
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn deadline_flush_releases_partial_batch() {
        let b = Batcher::new(64, Duration::from_millis(20));
        let (r, _rx) = req(7);
        b.submit(r).ok().unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        // One lone request: released by the deadline, not the size.
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(15), "flushed early: {:?}", t.elapsed());
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(64, Duration::from_secs(60));
        let (r, _rx) = req(1);
        b.submit(r).ok().unwrap();
        b.close();
        // Pending work is still served (drain), despite the far
        // deadline and the unreached max batch...
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        // ...then the queue reports end-of-stream,
        assert!(b.next_batch().is_none());
        // and new submissions bounce back to the caller.
        let (r, _rx) = req(2);
        assert!(b.submit(r).is_err());
    }

    #[test]
    fn close_wakes_a_parked_replica() {
        let b = Arc::new(Batcher::new(64, Duration::from_secs(60)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap(), "parked replica must see None after close");
    }
}
