//! Load generation against a running serve instance.
//!
//! Two shapes, matching the two questions a latency bench asks:
//!
//! - **Closed loop** ([`run_closed_loop`]): C connections, each with
//!   one request in flight, firing as fast as the server answers —
//!   measures best-case latency and peak per-concurrency throughput.
//! - **Open loop** ([`run_open_loop`]): requests *scheduled* at a fixed
//!   arrival rate regardless of completions, latency measured from the
//!   scheduled send time — the honest (coordinated-omission-free) view
//!   of what happens as the offered rate approaches saturation: once
//!   the server falls behind, schedule slip counts against latency.
//!
//! Percentiles here are exact (sorted samples), not histogram buckets:
//! the generator holds every latency in memory anyway.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serve::hex_encode;
use crate::util::Pcg32;

/// One protocol connection: line out, line in.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// What the server's `hello` reply announces.
#[derive(Clone, Debug)]
pub struct HelloInfo {
    pub model: String,
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    pub topk: usize,
}

impl HelloInfo {
    /// Bytes one `classify` payload must carry.
    pub fn input_bytes(&self) -> usize {
        self.channels * self.hw * self.hw
    }
}

impl ServeClient {
    /// Connect, retrying until `retry_for` elapses — covers the races
    /// where the client starts before the server finished binding.
    pub fn connect(addr: &str, retry_for: Duration) -> Result<ServeClient> {
        let start = Instant::now();
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).map_err(Error::RawIo)?;
                    let writer = stream.try_clone().map_err(Error::RawIo)?;
                    return Ok(ServeClient { reader: BufReader::new(stream), writer });
                }
                Err(e) => {
                    if start.elapsed() >= retry_for {
                        return Err(Error::msg(format!("connect {addr}: {e}")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Send one request line, read one reply line (trailing newline
    /// stripped).
    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes()).map_err(Error::RawIo)?;
        self.writer.write_all(b"\n").map_err(Error::RawIo)?;
        self.writer.flush().map_err(Error::RawIo)?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(Error::RawIo)?;
        if n == 0 {
            return Err(Error::msg("server closed the connection"));
        }
        Ok(reply.trim_end().to_string())
    }

    /// `hello` handshake, parsed.
    pub fn hello(&mut self) -> Result<HelloInfo> {
        let reply = self.request("hello")?;
        let mut info = HelloInfo {
            model: String::new(),
            hw: 0,
            channels: 0,
            classes: 0,
            topk: 0,
        };
        if !reply.starts_with("ok") {
            return Err(Error::msg(format!("hello failed: {reply}")));
        }
        for kv in reply.split_whitespace().skip(1) {
            let Some((k, v)) = kv.split_once('=') else { continue };
            match k {
                "model" => info.model = v.to_string(),
                "hw" => info.hw = v.parse().unwrap_or(0),
                "channels" => info.channels = v.parse().unwrap_or(0),
                "classes" => info.classes = v.parse().unwrap_or(0),
                "topk" => info.topk = v.parse().unwrap_or(0),
                _ => {}
            }
        }
        if info.input_bytes() == 0 {
            return Err(Error::msg(format!("malformed hello: {reply}")));
        }
        Ok(info)
    }
}

/// Deterministic random image for request `i` (hex-encoded).
pub fn synth_payload(input_bytes: usize, seed: u64, i: u64) -> String {
    let mut rng = Pcg32::seeded(seed ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    let pixels: Vec<u8> = (0..input_bytes).map(|_| rng.below(256) as u8).collect();
    hex_encode(&pixels)
}

/// Closed-loop run outcome.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub errors: u64,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Exact quantile over a sorted sample set.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Fire `requests` classifications from `concurrency` connections,
/// each keeping one request in flight.
pub fn run_closed_loop(
    addr: &str,
    requests: u64,
    concurrency: usize,
    seed: u64,
) -> Result<LoadReport> {
    let concurrency = concurrency.max(1);
    // One probe connection learns the payload geometry.
    let input_bytes = ServeClient::connect(addr, Duration::from_secs(10))?
        .hello()?
        .input_bytes();
    let next = Arc::new(AtomicUsize::new(0));
    let wall = Instant::now();
    let mut handles = Vec::with_capacity(concurrency);
    for _ in 0..concurrency {
        let addr = addr.to_string();
        let next = next.clone();
        handles.push(std::thread::spawn(move || -> Result<(u64, u64, Vec<f64>)> {
            let mut client = ServeClient::connect(&addr, Duration::from_secs(10))?;
            let (mut ok, mut errors) = (0u64, 0u64);
            let mut lat = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::SeqCst) as u64;
                if i >= requests {
                    return Ok((ok, errors, lat));
                }
                let payload = synth_payload(input_bytes, seed, i);
                let t = Instant::now();
                let reply = client.request(&format!("classify {payload}"))?;
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                if reply.starts_with("ok") {
                    ok += 1;
                } else {
                    errors += 1;
                }
            }
        }));
    }
    let (mut ok, mut errors) = (0u64, 0u64);
    let mut lat = Vec::new();
    for h in handles {
        let (o, e, l) = h.join().map_err(|_| Error::msg("load thread panicked"))??;
        ok += o;
        errors += e;
        lat.extend(l);
    }
    let wall_secs = wall.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    Ok(LoadReport {
        sent: ok + errors,
        ok,
        errors,
        wall_secs,
        throughput_rps: if wall_secs > 0.0 { (ok + errors) as f64 / wall_secs } else { 0.0 },
        p50_ms: percentile(&lat, 0.50),
        p99_ms: percentile(&lat, 0.99),
    })
}

/// One point of the open-loop saturation sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepPoint {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub ok: u64,
    pub errors: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Offer `rate` requests/second for `duration`, spread over `conns`
/// persistent connections.  Each connection sends on a fixed schedule;
/// latency is measured from the *scheduled* time, so a server that
/// falls behind shows the backlog in its percentiles instead of
/// silently shedding offered load (no coordinated omission).
pub fn run_open_loop(
    addr: &str,
    rate: f64,
    duration: Duration,
    conns: usize,
    seed: u64,
) -> Result<SweepPoint> {
    let conns = conns.max(1);
    if rate <= 0.0 {
        return Err(Error::msg("open-loop rate must be positive"));
    }
    let input_bytes = ServeClient::connect(addr, Duration::from_secs(10))?
        .hello()?
        .input_bytes();
    let start = Instant::now() + Duration::from_millis(20);
    let mut handles = Vec::with_capacity(conns);
    for j in 0..conns {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<(u64, u64, Vec<f64>)> {
            let mut client = ServeClient::connect(&addr, Duration::from_secs(10))?;
            let (mut ok, mut errors) = (0u64, 0u64);
            let mut lat = Vec::new();
            // Connection j owns arrivals j, j+conns, j+2*conns, ...
            let mut k = 0u64;
            loop {
                let arrival = j as u64 + k * conns as u64;
                let offset = Duration::from_secs_f64(arrival as f64 / rate);
                if offset >= duration {
                    return Ok((ok, errors, lat));
                }
                let scheduled = start + offset;
                let now = Instant::now();
                if scheduled > now {
                    std::thread::sleep(scheduled - now);
                }
                // Behind schedule: send immediately; the slip stays in
                // the latency measurement below.
                let payload = synth_payload(input_bytes, seed, arrival);
                let reply = client.request(&format!("classify {payload}"))?;
                lat.push(scheduled.elapsed().as_secs_f64() * 1e3);
                if reply.starts_with("ok") {
                    ok += 1;
                } else {
                    errors += 1;
                }
                k += 1;
            }
        }));
    }
    let (mut ok, mut errors) = (0u64, 0u64);
    let mut lat = Vec::new();
    for h in handles {
        let (o, e, l) = h.join().map_err(|_| Error::msg("load thread panicked"))??;
        ok += o;
        errors += e;
        lat.extend(l);
    }
    lat.sort_by(|a, b| a.total_cmp(b));
    let wall = start.elapsed().as_secs_f64().max(1e-9);
    Ok(SweepPoint {
        offered_rps: rate,
        achieved_rps: (ok + errors) as f64 / wall,
        ok,
        errors,
        p50_ms: percentile(&lat, 0.50),
        p99_ms: percentile(&lat, 0.99),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn payload_is_deterministic_per_index() {
        let a = synth_payload(16, 42, 3);
        let b = synth_payload(16, 42, 3);
        let c = synth_payload(16, 42, 4);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 32);
    }
}
