//! PCIe interconnect model — the paper's §4.4 hardware constraint.
//!
//! "To use the fast peer-to-peer GPU memory copy, GPUs have to be under
//! the same PCI-E switch.  Otherwise, communication has to go through
//! the host memory which results in longer latency."
//!
//! [`topology`] models the device/switch/root-complex tree of the
//! paper's testbed (2 Titan Blacks under one switch, a third elsewhere)
//! and arbitrary N-GPU machines for the E5 scaling study; [`routing`]
//! turns device pairs into effective transports + transfer costs.

pub mod routing;
pub mod topology;

pub use routing::{route, Route};
pub use topology::{PcieTopology, TopologyBuilder};
