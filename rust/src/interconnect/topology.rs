//! PCIe tree: devices attach to switches, switches to the root complex.

use crate::error::{Error, Result};

/// A PCIe tree with `switches` switch nodes under one root complex and
/// each device attached to exactly one switch.
#[derive(Clone, Debug)]
pub struct PcieTopology {
    pub switches: usize,
    /// switch id per device.
    pub switch_of_device: Vec<usize>,
}

impl PcieTopology {
    pub fn devices(&self) -> usize {
        self.switch_of_device.len()
    }

    pub fn validate(&self) -> Result<()> {
        if self.switches == 0 {
            return Err(Error::Topology("need at least one switch".into()));
        }
        for (d, &s) in self.switch_of_device.iter().enumerate() {
            if s >= self.switches {
                return Err(Error::Topology(format!(
                    "device {d} on switch {s}, only {} switches",
                    self.switches
                )));
            }
        }
        Ok(())
    }

    /// The §4.4 rule: P2P iff both devices share a switch.
    pub fn p2p_allowed(&self, a: usize, b: usize) -> Result<bool> {
        let n = self.devices();
        if a >= n || b >= n {
            return Err(Error::Topology(format!("device out of range ({a},{b}) of {n}")));
        }
        Ok(self.switch_of_device[a] == self.switch_of_device[b])
    }

    /// Hop count between two devices: 2 within a switch (dev–switch–dev),
    /// 4 across switches (dev–switch–root–switch–dev).
    pub fn hops(&self, a: usize, b: usize) -> Result<usize> {
        if a == b {
            return Ok(0);
        }
        Ok(if self.p2p_allowed(a, b)? { 2 } else { 4 })
    }

    /// The paper's testbed: three Titan Blacks, two under switch 0 (the
    /// pair used for the 2-GPU runs) and one under switch 1 (unused).
    pub fn paper_testbed() -> PcieTopology {
        PcieTopology { switches: 2, switch_of_device: vec![0, 0, 1] }
    }
}

/// Convenience builder for scaling-study machines.
pub struct TopologyBuilder {
    switches: usize,
    switch_of_device: Vec<usize>,
}

impl TopologyBuilder {
    pub fn new() -> Self {
        TopologyBuilder { switches: 0, switch_of_device: Vec::new() }
    }

    /// Add a switch with `devices` GPUs attached; returns the switch id.
    pub fn switch_with(mut self, devices: usize) -> Self {
        let sid = self.switches;
        self.switches += 1;
        for _ in 0..devices {
            self.switch_of_device.push(sid);
        }
        self
    }

    pub fn build(self) -> Result<PcieTopology> {
        let t = PcieTopology { switches: self.switches, switch_of_device: self.switch_of_device };
        t.validate()?;
        Ok(t)
    }
}

impl Default for TopologyBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_rules() {
        let t = PcieTopology::paper_testbed();
        t.validate().unwrap();
        assert_eq!(t.devices(), 3);
        assert!(t.p2p_allowed(0, 1).unwrap());
        assert!(!t.p2p_allowed(0, 2).unwrap());
        assert_eq!(t.hops(0, 1).unwrap(), 2);
        assert_eq!(t.hops(1, 2).unwrap(), 4);
        assert_eq!(t.hops(1, 1).unwrap(), 0);
    }

    #[test]
    fn builder_assigns_switches() {
        let t = TopologyBuilder::new().switch_with(2).switch_with(2).build().unwrap();
        assert_eq!(t.devices(), 4);
        assert!(t.p2p_allowed(0, 1).unwrap());
        assert!(t.p2p_allowed(2, 3).unwrap());
        assert!(!t.p2p_allowed(1, 2).unwrap());
    }

    #[test]
    fn invalid_topologies_rejected() {
        let t = PcieTopology { switches: 1, switch_of_device: vec![0, 3] };
        assert!(t.validate().is_err());
        let t = PcieTopology { switches: 0, switch_of_device: vec![] };
        assert!(t.validate().is_err());
        let t = PcieTopology::paper_testbed();
        assert!(t.p2p_allowed(0, 9).is_err());
    }
}
