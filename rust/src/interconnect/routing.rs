//! Routing: device pair -> effective transport + predicted cost.

use crate::comm::cost::CommCostModel;
use crate::config::TransportKind;
use crate::error::Result;
use crate::interconnect::topology::PcieTopology;

/// A resolved route between two devices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Route {
    pub src: usize,
    pub dst: usize,
    pub transport: TransportKind,
    pub hops: usize,
}

/// Resolve the transport the hardware permits for (a, b): P2P under a
/// shared switch, otherwise staged through host memory (§4.4).
pub fn route(topo: &PcieTopology, a: usize, b: usize) -> Result<Route> {
    let transport = if topo.p2p_allowed(a, b)? {
        TransportKind::P2p
    } else {
        TransportKind::HostStaged
    };
    Ok(Route { src: a, dst: b, transport, hops: topo.hops(a, b)? })
}

impl Route {
    /// Predicted one-way transfer time for `bytes` over this route.
    pub fn transfer_time(&self, model: &CommCostModel, bytes: usize) -> f64 {
        model.transfer_time(self.transport, bytes)
    }
}

/// Predicted Fig-2 exchange round time between two devices.
pub fn exchange_time(
    topo: &PcieTopology,
    model: &CommCostModel,
    a: usize,
    b: usize,
    bytes: usize,
) -> Result<f64> {
    let r = route(topo, a, b)?;
    Ok(model.exchange_round_time(r.transport, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_switch_routes_p2p() {
        let t = PcieTopology::paper_testbed();
        let r = route(&t, 0, 1).unwrap();
        assert_eq!(r.transport, TransportKind::P2p);
        assert_eq!(r.hops, 2);
    }

    #[test]
    fn cross_switch_routes_host() {
        let t = PcieTopology::paper_testbed();
        let r = route(&t, 0, 2).unwrap();
        assert_eq!(r.transport, TransportKind::HostStaged);
        assert_eq!(r.hops, 4);
    }

    #[test]
    fn cross_switch_costs_more() {
        let t = PcieTopology::paper_testbed();
        let m = CommCostModel::default();
        let bytes = 64 << 20;
        let same = exchange_time(&t, &m, 0, 1, bytes).unwrap();
        let cross = exchange_time(&t, &m, 0, 2, bytes).unwrap();
        assert!(cross > 1.5 * same, "cross {cross} vs same {same}");
    }
}
