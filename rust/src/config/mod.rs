//! Configuration system: a TOML-subset parser plus typed experiment
//! configs with validation.
//!
//! `tmg train --config experiments/tiny2gpu.toml` drives everything the
//! paper's scripts hard-coded: model/backend/batch selection, worker
//! count, exchange transport and period, loader mode, LR schedule,
//! dataset location and sizes.

mod toml;
mod types;

pub use toml::TomlDoc;
pub use types::{
    ClusterConfig, DataConfig, DistributedCfg, ExchangeCfg, LoaderMode, LrSchedule, OverlapMode,
    ResumeFrom, TrainConfig, TransportKind,
};
