//! Minimal TOML-subset parser (offline crate set has no `toml`).
//!
//! Supported grammar — everything the experiment configs need:
//!
//! - `[section]` and `[nested.section]` headers
//! - `key = "string" | integer | float | true/false | [scalar, ...]`
//! - `#` comments, blank lines
//!
//! Unsupported (rejected loudly): inline tables, arrays-of-tables,
//! multi-line strings, datetimes.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `section -> key -> value`.  Top-level keys live
/// under the empty-string section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.sections.entry(current.clone()).or_default();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| cfg_err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(cfg_err(lineno, "bad section header"));
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| cfg_err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(cfg_err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let section = doc.sections.get_mut(&current).unwrap();
            if section.insert(key.to_string(), value).is_some() {
                return Err(cfg_err(lineno, &format!("duplicate key {key:?}")));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let src = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        Self::parse(&src)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Typed getters with defaults — the shape every config loader wants.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn cfg_err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(cfg_err(lineno, "empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| cfg_err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(cfg_err(lineno, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| cfg_err(lineno, "unterminated array"))?
            .trim();
        let mut items = Vec::new();
        if !inner.is_empty() {
            for part in split_array_items(inner) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    Err(cfg_err(lineno, &format!("cannot parse value {s:?}")))
}

/// Split array items on top-level commas (strings may contain commas).
fn split_array_items(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
name = "tiny-2gpu"
steps = 400

[training]
lr = 0.01
momentum = 0.9
use_parallel_loading = true
milestones = [100, 200, 300]

[cluster.links]
kind = "p2p"
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.get("", "name").unwrap().as_str(), Some("tiny-2gpu"));
        assert_eq!(d.get("", "steps").unwrap().as_i64(), Some(400));
        assert_eq!(d.get("training", "lr").unwrap().as_f64(), Some(0.01));
        assert_eq!(d.get("training", "use_parallel_loading").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("cluster.links", "kind").unwrap().as_str(), Some("p2p"));
        let arr = match d.get("training", "milestones").unwrap() {
            TomlValue::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn defaults() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.i64_or("training", "zzz", 7), 7);
        assert_eq!(d.str_or("", "name", "x"), "tiny-2gpu");
        assert_eq!(d.f64_or("training", "lr", 1.0), 0.01);
        assert!(!d.bool_or("", "nope", false));
    }

    #[test]
    fn comment_handling() {
        let d = TomlDoc::parse("a = \"x # not comment\" # real comment").unwrap();
        assert_eq!(d.get("", "a").unwrap().as_str(), Some("x # not comment"));
    }

    #[test]
    fn int_in_float_position() {
        let d = TomlDoc::parse("lr = 1").unwrap();
        assert_eq!(d.f64_or("", "lr", 0.0), 1.0);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("k = zzz").is_err());
    }
}
