//! Typed experiment configuration, loaded from the TOML subset.

use std::path::{Path, PathBuf};

use crate::config::toml::{TomlDoc, TomlValue};
use crate::error::{Error, Result};

/// How minibatches reach the trainer (paper Fig 1 vs the baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoaderMode {
    /// Loading overlapped with compute in a separate thread (Fig 1).
    Parallel,
    /// Load-then-train in the training thread (the "No" rows of Table 1).
    Serial,
}

impl LoaderMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "parallel" => Ok(LoaderMode::Parallel),
            "serial" => Ok(LoaderMode::Serial),
            _ => Err(Error::Config(format!("loader mode {s:?} (want parallel|serial)"))),
        }
    }
}

/// Inter-replica copy path (paper §2.2 / §4.3 / §4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// GPUDirect peer-to-peer analog: direct move, no staging copy.
    P2p,
    /// Through host memory (GPUs on different switches, §4.4).
    HostStaged,
    /// `multiprocessing`-style: serialize + copy through host (§4.3).
    Serialized,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "p2p" => Ok(TransportKind::P2p),
            "host" | "host_staged" => Ok(TransportKind::HostStaged),
            "serialized" | "multiprocessing" => Ok(TransportKind::Serialized),
            _ => Err(Error::Config(format!(
                "transport {s:?} (want p2p|host_staged|serialized)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::P2p => "p2p",
            TransportKind::HostStaged => "host_staged",
            TransportKind::Serialized => "serialized",
        }
    }
}

/// How the exchange relates to backward compute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// Legacy compute-then-exchange of *parameters* (post-update
    /// averaging, the Fig-2 scheme).  The only mode valid at
    /// `period > 1`.
    Off,
    /// Bucketed *gradient* exchange, streamed on a dedicated comm
    /// thread concurrently with backward (Theano-MPI overlap).
    Stream,
    /// The same bucketed gradient exchange, executed inline after
    /// backward — the measured compute-then-exchange baseline,
    /// bit-identical to `Stream` by construction.
    Serial,
}

impl OverlapMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(OverlapMode::Off),
            "stream" | "on" => Ok(OverlapMode::Stream),
            "serial" => Ok(OverlapMode::Serial),
            _ => Err(Error::Config(format!("overlap mode {s:?} (want off|stream|serial)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Off => "off",
            OverlapMode::Stream => "stream",
            OverlapMode::Serial => "serial",
        }
    }

    /// Whether the gradient-exchange step protocol is active at all
    /// (both variants compute the same update rule).
    pub fn is_gradient_exchange(&self) -> bool {
        !matches!(self, OverlapMode::Off)
    }
}

/// Exchange-and-average settings (Fig 2).
#[derive(Clone, Debug)]
pub struct ExchangeCfg {
    pub transport: TransportKind,
    /// Exchange every `period` steps (1 = the paper's every-step scheme;
    /// >1 is the E6 ablation).
    pub period: usize,
    /// Whether momenta are exchanged along with weights (paper: yes).
    pub include_momentum: bool,
    /// Comm/compute overlap of the exchange (requires `period = 1`).
    pub overlap: OverlapMode,
    /// Bucket size (elements) of the overlapped gradient exchange;
    /// bucket boundaries derive only from this and the parameter
    /// layout, never from timing.
    pub bucket_elems: usize,
}

impl Default for ExchangeCfg {
    fn default() -> Self {
        ExchangeCfg {
            transport: TransportKind::P2p,
            period: 1,
            include_momentum: true,
            overlap: OverlapMode::Off,
            bucket_elems: 32_768,
        }
    }
}

/// Step-decay learning-rate schedule (AlexNet's "divide by 10 when the
/// validation error plateaus", expressed as fixed milestones).
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub decay_factor: f32,
    pub milestones: Vec<usize>,
}

impl LrSchedule {
    pub fn lr_at(&self, step: usize) -> f32 {
        let decays = self.milestones.iter().filter(|&&m| step >= m).count();
        self.base_lr * self.decay_factor.powi(decays as i32)
    }
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule { base_lr: 0.01, decay_factor: 0.1, milestones: vec![] }
    }
}

/// Dataset location + sizes.
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub dir: PathBuf,
    pub train_examples: usize,
    pub val_examples: usize,
    pub shard_examples: usize,
    pub seed: u64,
    /// Stored image edge; training crops to the model's input edge.
    pub stored_hw: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            dir: PathBuf::from("data/synth"),
            train_examples: 8_192,
            val_examples: 1_024,
            shard_examples: 1_024,
            seed: 1234,
            stored_hw: 72,
        }
    }
}

/// Where `tmg train --resume` picks up from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResumeFrom {
    /// Newest valid checkpoint in the checkpoint dir.
    Auto,
    /// An explicit checkpoint file (worker siblings are derived from
    /// it for multi-worker runs).
    Path(PathBuf),
}

impl ResumeFrom {
    pub fn parse(s: &str) -> ResumeFrom {
        if s == "auto" {
            ResumeFrom::Auto
        } else {
            ResumeFrom::Path(PathBuf::from(s))
        }
    }
}

/// Worker topology (which virtual GPU sits on which PCIe switch).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub workers: usize,
    /// switch id per worker; same id => P2P-eligible (paper §4.4).
    pub switch_of_worker: Vec<usize>,
}

impl ClusterConfig {
    pub fn single() -> Self {
        ClusterConfig { workers: 1, switch_of_worker: vec![0] }
    }

    pub fn pair_same_switch() -> Self {
        ClusterConfig { workers: 2, switch_of_worker: vec![0, 0] }
    }

    pub fn pair_cross_switch() -> Self {
        ClusterConfig { workers: 2, switch_of_worker: vec![0, 1] }
    }
}

/// Multi-process mode: one OS process per rank, ring links over TCP.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DistributedCfg {
    /// This process's rank — an index into `peers`.
    pub rank: usize,
    /// `peers[i]` = listen address (`host:port`) of rank `i`; every
    /// rank is launched with the same ordered list.
    pub peers: Vec<String>,
    /// Rendezvous budget: outbound connect (with exponential backoff),
    /// inbound accept, and each handshake read/write.
    pub connect_timeout_ms: u64,
    /// Steady-state per-message socket deadline; a dead peer surfaces
    /// as `Error::Timeout` within this bound instead of hanging.
    pub io_timeout_ms: u64,
}

impl Default for DistributedCfg {
    fn default() -> Self {
        DistributedCfg {
            rank: 0,
            peers: vec![],
            connect_timeout_ms: 30_000,
            io_timeout_ms: 30_000,
        }
    }
}

impl DistributedCfg {
    pub fn connect_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.connect_timeout_ms)
    }

    pub fn io_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.io_timeout_ms)
    }
}

/// Everything `tmg train` needs.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub name: String,
    pub artifacts_dir: PathBuf,
    pub model: String,
    /// Step substrate: `"native"` (pure-Rust CPU path, the default) or
    /// an artifact backend tag (`refconv`, `cudnn_r2`, …) loaded
    /// through the XLA runtime — see `backend::build_backend`.
    pub backend: String,
    /// Dropout probability on hidden FC layers (native backend only;
    /// the XLA artifacts bake their own rate in).
    pub dropout: f32,
    /// Intra-op compute threads per worker for the native backend.
    /// `0` = auto: each of the N workers gets a disjoint share of the
    /// machine, `floor(cores / workers)` (min 1), so N workers × T
    /// threads never oversubscribes.  The thread count changes
    /// wall-clock only — step results are bit-identical for any value.
    pub compute_threads: usize,
    pub batch_per_worker: usize,
    pub steps: usize,
    /// Mid-training validation cadence: evaluate the held-out split
    /// every N steps (0 = final eval only).
    pub eval_every: usize,
    /// Periodic snapshot cadence: every N steps each worker writes its
    /// replica state to `checkpoint_dir` (0 = final checkpoint only).
    pub checkpoint_every: usize,
    /// Retention: keep this many newest *completed* periodic
    /// checkpoint steps in addition to the one currently being written
    /// (plus the best-by-validation-error one), so a kill mid-save
    /// always leaves a complete resumable set.  0 = keep all.
    pub checkpoint_keep: usize,
    /// Resume source for this run (CLI `--resume auto|PATH`).
    pub resume: Option<ResumeFrom>,
    pub log_every: usize,
    pub seed: u64,
    pub loader_mode: LoaderMode,
    pub exchange: ExchangeCfg,
    pub schedule: LrSchedule,
    pub data: DataConfig,
    pub cluster: ClusterConfig,
    /// `Some` = this process runs exactly one rank of a multi-process
    /// ring over TCP (`tmg train --distributed`); `None` = all workers
    /// are threads of this process over in-memory links.
    pub distributed: Option<DistributedCfg>,
    pub checkpoint_dir: Option<PathBuf>,
    pub metrics_csv: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            name: "default".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            model: "alexnet-tiny".into(),
            backend: "native".into(),
            dropout: 0.5,
            compute_threads: 0,
            batch_per_worker: 16,
            steps: 200,
            eval_every: 0,
            checkpoint_every: 0,
            checkpoint_keep: 0,
            resume: None,
            log_every: 20,
            seed: 42,
            loader_mode: LoaderMode::Parallel,
            exchange: ExchangeCfg::default(),
            schedule: LrSchedule::default(),
            data: DataConfig::default(),
            cluster: ClusterConfig::pair_same_switch(),
            distributed: None,
            checkpoint_dir: None,
            metrics_csv: None,
        }
    }
}

fn str_list(doc: &TomlDoc, section: &str, key: &str) -> Result<Vec<String>> {
    match doc.get(section, key) {
        None => Ok(vec![]),
        Some(TomlValue::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Config(format!("{section}.{key}: non-string item")))
            })
            .collect(),
        Some(_) => Err(Error::Config(format!("{section}.{key}: expected array"))),
    }
}

fn usize_list(doc: &TomlDoc, section: &str, key: &str) -> Result<Vec<usize>> {
    match doc.get(section, key) {
        None => Ok(vec![]),
        Some(TomlValue::Array(items)) => items
            .iter()
            .map(|v| {
                v.as_i64()
                    .filter(|&i| i >= 0)
                    .map(|i| i as usize)
                    .ok_or_else(|| Error::Config(format!("{section}.{key}: non-integer item")))
            })
            .collect(),
        Some(_) => Err(Error::Config(format!("{section}.{key}: expected array"))),
    }
}

impl TrainConfig {
    /// Load from a TOML file; unknown keys are ignored, missing keys
    /// fall back to the defaults above.
    pub fn load(path: &Path) -> Result<TrainConfig> {
        let doc = TomlDoc::load(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let workers = doc.i64_or("cluster", "workers", 2).max(1) as usize;
        let switches = usize_list(doc, "cluster", "switch_of_worker")?;
        let switch_of_worker = if switches.is_empty() {
            vec![0; workers]
        } else if switches.len() == workers {
            switches
        } else {
            return Err(Error::Config(format!(
                "cluster.switch_of_worker has {} entries for {} workers",
                switches.len(),
                workers
            )));
        };

        let cfg = TrainConfig {
            name: doc.str_or("", "name", &d.name),
            artifacts_dir: PathBuf::from(doc.str_or("", "artifacts_dir", "artifacts")),
            model: doc.str_or("model", "name", &d.model),
            backend: doc.str_or("model", "backend", &d.backend),
            dropout: doc.f64_or("training", "dropout", d.dropout as f64) as f32,
            compute_threads: match doc.get("training", "threads") {
                None => d.compute_threads,
                Some(v) => match (v.as_str(), v.as_i64()) {
                    (Some("auto"), _) => 0,
                    (_, Some(i)) if i >= 0 => i as usize,
                    _ => {
                        return Err(Error::Config(
                            "training.threads: want a non-negative integer or \"auto\"".into(),
                        ))
                    }
                },
            },
            batch_per_worker: doc.i64_or("training", "batch_per_worker", 16) as usize,
            steps: doc.i64_or("training", "steps", d.steps as i64) as usize,
            eval_every: doc.i64_or("training", "eval_every", 0) as usize,
            checkpoint_every: doc.i64_or("training", "checkpoint_every", 0) as usize,
            checkpoint_keep: doc.i64_or("training", "checkpoint_keep", 0) as usize,
            resume: doc
                .get("training", "resume")
                .and_then(|v| v.as_str())
                .map(ResumeFrom::parse),
            log_every: doc.i64_or("training", "log_every", 20) as usize,
            seed: doc.i64_or("training", "seed", 42) as u64,
            loader_mode: LoaderMode::parse(&doc.str_or("training", "loader", "parallel"))?,
            exchange: ExchangeCfg {
                transport: TransportKind::parse(&doc.str_or("exchange", "transport", "p2p"))?,
                period: doc.i64_or("exchange", "period", 1).max(1) as usize,
                include_momentum: doc.bool_or("exchange", "include_momentum", true),
                overlap: OverlapMode::parse(&doc.str_or("exchange", "overlap", "off"))?,
                bucket_elems: doc.i64_or("exchange", "bucket_elems", 32_768) as usize,
            },
            schedule: LrSchedule {
                base_lr: doc.f64_or("training", "lr", 0.01) as f32,
                decay_factor: doc.f64_or("training", "lr_decay", 0.1) as f32,
                milestones: usize_list(doc, "training", "lr_milestones")?,
            },
            data: DataConfig {
                dir: PathBuf::from(doc.str_or("data", "dir", "data/synth")),
                train_examples: doc.i64_or("data", "train_examples", 8192) as usize,
                val_examples: doc.i64_or("data", "val_examples", 1024) as usize,
                shard_examples: doc.i64_or("data", "shard_examples", 1024) as usize,
                seed: doc.i64_or("data", "seed", 1234) as u64,
                stored_hw: doc.i64_or("data", "stored_hw", 72) as usize,
            },
            cluster: ClusterConfig { workers, switch_of_worker },
            distributed: {
                let peers = str_list(doc, "distributed", "peers")?;
                if peers.is_empty() {
                    None
                } else {
                    let dd = DistributedCfg::default();
                    Some(DistributedCfg {
                        rank: doc.i64_or("distributed", "rank", 0).max(0) as usize,
                        peers,
                        connect_timeout_ms: doc
                            .i64_or("distributed", "connect_timeout_ms", dd.connect_timeout_ms as i64)
                            .max(0) as u64,
                        io_timeout_ms: doc
                            .i64_or("distributed", "io_timeout_ms", dd.io_timeout_ms as i64)
                            .max(0) as u64,
                    })
                }
            },
            checkpoint_dir: doc
                .get("training", "checkpoint_dir")
                .and_then(|v| v.as_str())
                .map(PathBuf::from),
            metrics_csv: doc
                .get("training", "metrics_csv")
                .and_then(|v| v.as_str())
                .map(PathBuf::from),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_per_worker == 0 {
            return Err(Error::Config("batch_per_worker must be > 0".into()));
        }
        if self.cluster.workers == 0 || self.cluster.workers > 64 {
            return Err(Error::Config("workers must be in 1..=64".into()));
        }
        if self.cluster.switch_of_worker.len() != self.cluster.workers {
            return Err(Error::Config("switch_of_worker length != workers".into()));
        }
        if self.exchange.period == 0 {
            return Err(Error::Config("exchange.period must be >= 1".into()));
        }
        if self.exchange.overlap.is_gradient_exchange() && self.exchange.period != 1 {
            return Err(Error::Config(
                "--overlap requires --period 1: the overlapped exchange averages \
                 per-step gradients, which only equals the synchronized-replica \
                 update when every step exchanges"
                    .into(),
            ));
        }
        if self.exchange.bucket_elems == 0 {
            return Err(Error::Config("exchange.bucket_elems must be >= 1".into()));
        }
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(Error::Config("training.dropout must be in [0, 1)".into()));
        }
        if self.data.shard_examples == 0 {
            return Err(Error::Config("data.shard_examples must be > 0".into()));
        }
        if self.compute_threads > 256 {
            return Err(Error::Config("training.threads must be <= 256".into()));
        }
        if let Some(d) = &self.distributed {
            if self.cluster.workers < 2 {
                return Err(Error::Config(
                    "distributed mode needs workers >= 2 (a 1-rank ring has no peers)".into(),
                ));
            }
            if d.peers.len() != self.cluster.workers {
                return Err(Error::Config(format!(
                    "distributed.peers has {} entries for {} workers — every \
                     rank (one per worker) needs a listen address",
                    d.peers.len(),
                    self.cluster.workers
                )));
            }
            if d.rank >= d.peers.len() {
                return Err(Error::Config(format!(
                    "distributed.rank {} out of range for {} peers",
                    d.rank,
                    d.peers.len()
                )));
            }
            for (i, p) in d.peers.iter().enumerate() {
                if !p.contains(':') {
                    return Err(Error::Config(format!(
                        "distributed.peers[{i}] {p:?} is not a host:port address"
                    )));
                }
            }
            for (i, p) in d.peers.iter().enumerate() {
                if d.peers[..i].contains(p) {
                    return Err(Error::Config(format!(
                        "distributed.peers[{i}] {p:?} repeats an earlier address — \
                         each rank needs its own listen port"
                    )));
                }
            }
            if d.connect_timeout_ms == 0 || d.io_timeout_ms == 0 {
                return Err(Error::Config(
                    "distributed connect/io timeouts must be >= 1 ms (0 would \
                     turn every socket read into an instant failure)"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// Intra-op compute threads each worker's backend gets.  Explicit
    /// when `compute_threads > 0`; auto (`0`) partitions the machine's
    /// cores into disjoint per-worker shares: `floor(cores / workers)`,
    /// min 1.
    pub fn threads_per_worker(&self) -> usize {
        if self.compute_threads > 0 {
            return self.compute_threads;
        }
        (crate::util::available_cores() / self.cluster.workers.max(1)).max(1)
    }

    /// Artifact name this config resolves to (manifest lookup key).
    pub fn train_artifact_name(&self) -> String {
        format!("train_{}_{}_b{}", self.model, self.backend, self.batch_per_worker)
    }

    /// FNV-1a fingerprint of everything that must match between the
    /// saving and the resuming run for `--resume` to be bit-exact:
    /// the model architecture, worker count, exchange period, momentum
    /// inclusion, per-worker batch size, dropout rate and the
    /// experiment seed (the data/augmentation/init streams all key off
    /// it).  Stored in v2 checkpoints and checked at restore.
    /// Deliberately excludes knobs that provably do not change the
    /// math: transport, loader mode, thread count, and
    /// stream-vs-serial overlap (bit-identical by construction) — but
    /// *not* overlap on/off, which switches the update rule between
    /// param and gradient averaging.
    pub fn resume_fingerprint(&self) -> u64 {
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        // The architecture the checkpoint's tensors belong to.  Hashed
        // by normalized name (underscore and hyphen spellings are the
        // same arch); unknown names still hash — mismatch detection
        // must not depend on the lookup table.
        eat(self.model.replace('_', "-").as_bytes());
        for v in [
            self.cluster.workers as u64,
            self.exchange.period as u64,
            self.exchange.include_momentum as u64,
            self.batch_per_worker as u64,
            self.dropout.to_bits() as u64,
            self.seed,
            self.exchange.overlap.is_gradient_exchange() as u64,
            // Bucket boundaries shape the ring's summation grouping, so
            // they are resume-critical — but only when buckets exist.
            if self.exchange.overlap.is_gradient_exchange() {
                self.exchange.bucket_elems as u64
            } else {
                0
            },
        ] {
            eat(&v.to_le_bytes());
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn lr_schedule_decays() {
        let s = LrSchedule { base_lr: 0.1, decay_factor: 0.1, milestones: vec![10, 20] };
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(9), 0.1);
        assert!((s.lr_at(10) - 0.01).abs() < 1e-9);
        assert!((s.lr_at(25) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn load_from_doc() {
        let doc = TomlDoc::parse(
            r#"
name = "exp1"
[model]
name = "alexnet-micro"
backend = "cudnn_r2"
[training]
batch_per_worker = 8
steps = 40
lr = 0.05
lr_milestones = [20]
loader = "serial"
[exchange]
transport = "host_staged"
period = 2
[cluster]
workers = 2
switch_of_worker = [0, 1]
"#,
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "exp1");
        assert_eq!(cfg.backend, "cudnn_r2");
        assert_eq!(cfg.loader_mode, LoaderMode::Serial);
        assert_eq!(cfg.exchange.transport, TransportKind::HostStaged);
        assert_eq!(cfg.exchange.period, 2);
        assert_eq!(cfg.cluster.switch_of_worker, vec![0, 1]);
        assert_eq!(cfg.train_artifact_name(), "train_alexnet-micro_cudnn_r2_b8");
    }

    #[test]
    fn compute_threads_parsed_and_validated() {
        // Default is auto (0).
        assert_eq!(TrainConfig::default().compute_threads, 0);
        let doc = TomlDoc::parse("[training]\nthreads = 4").unwrap();
        assert_eq!(TrainConfig::from_doc(&doc).unwrap().compute_threads, 4);
        let doc = TomlDoc::parse("[training]\nthreads = \"auto\"").unwrap();
        assert_eq!(TrainConfig::from_doc(&doc).unwrap().compute_threads, 0);
        let doc = TomlDoc::parse("[training]\nthreads = \"lots\"").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[training]\nthreads = 10000").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        // Explicit counts pass through; auto divides cores by workers.
        let mut cfg = TrainConfig::default();
        cfg.compute_threads = 3;
        assert_eq!(cfg.threads_per_worker(), 3);
        cfg.compute_threads = 0;
        assert!(cfg.threads_per_worker() >= 1);
        // Auto shares are disjoint: workers * share <= cores.
        let cores = crate::util::available_cores();
        for workers in [1, 2, 4, 64] {
            cfg.cluster = ClusterConfig { workers, switch_of_worker: vec![0; workers] };
            assert!(workers * cfg.threads_per_worker() <= cores.max(workers));
        }
    }

    #[test]
    fn dropout_parsed_and_validated() {
        let doc = TomlDoc::parse("[training]\ndropout = 0.25").unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert!((cfg.dropout - 0.25).abs() < 1e-6);
        assert_eq!(TrainConfig::default().dropout, 0.5);
        let doc = TomlDoc::parse("[training]\ndropout = 1.5").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn lifecycle_keys_parse() {
        let doc = TomlDoc::parse(
            "[training]\ncheckpoint_every = 50\ncheckpoint_keep = 3\n\
             eval_every = 100\nresume = \"auto\"",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.checkpoint_every, 50);
        assert_eq!(cfg.checkpoint_keep, 3);
        assert_eq!(cfg.eval_every, 100);
        assert_eq!(cfg.resume, Some(ResumeFrom::Auto));
        let doc = TomlDoc::parse("[training]\nresume = \"ckpts/run_step8.ckpt\"").unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.resume, Some(ResumeFrom::Path(PathBuf::from("ckpts/run_step8.ckpt"))));
        let d = TrainConfig::default();
        assert_eq!((d.checkpoint_every, d.checkpoint_keep, d.resume), (0, 0, None));
    }

    #[test]
    fn resume_fingerprint_tracks_bit_exactness_knobs() {
        let base = TrainConfig::default();
        let fp = base.resume_fingerprint();
        assert_eq!(fp, TrainConfig::default().resume_fingerprint(), "deterministic");
        let mut c = base.clone();
        c.seed = 43;
        assert_ne!(fp, c.resume_fingerprint());
        let mut c = base.clone();
        c.exchange.period = 2;
        assert_ne!(fp, c.resume_fingerprint());
        let mut c = base.clone();
        c.exchange.include_momentum = false;
        assert_ne!(fp, c.resume_fingerprint());
        let mut c = base.clone();
        c.batch_per_worker = 32;
        assert_ne!(fp, c.resume_fingerprint());
        let mut c = base.clone();
        c.dropout = 0.25;
        assert_ne!(fp, c.resume_fingerprint());
        // A different architecture is a different set of tensors: the
        // fingerprint must refuse to resume across models.
        let mut c = base.clone();
        c.model = "alexnet-tiny-faithful".into();
        assert_ne!(fp, c.resume_fingerprint());
        // Spelling does not change the arch, so it must not change the
        // fingerprint.
        let mut c = base.clone();
        c.model = base.model.replace('-', "_");
        assert_eq!(fp, c.resume_fingerprint());
        // Knobs that never change the math leave it untouched.
        let mut c = base.clone();
        c.exchange.transport = TransportKind::Serialized;
        c.loader_mode = LoaderMode::Serial;
        c.compute_threads = 7;
        assert_eq!(fp, c.resume_fingerprint());
        // Gradient exchange vs param averaging changes the update rule;
        // stream vs serial does not (bit-identical by construction).
        let mut c = base.clone();
        c.exchange.overlap = OverlapMode::Stream;
        let fp_stream = c.resume_fingerprint();
        assert_ne!(fp, fp_stream);
        c.exchange.overlap = OverlapMode::Serial;
        assert_eq!(fp_stream, c.resume_fingerprint());
        // Bucket size shapes the summation grouping: resume-critical in
        // overlap mode, irrelevant when overlap is off.
        c.exchange.bucket_elems = 1024;
        assert_ne!(fp_stream, c.resume_fingerprint());
        let mut c = base.clone();
        c.exchange.bucket_elems = 1024;
        assert_eq!(fp, c.resume_fingerprint());
    }

    #[test]
    fn overlap_parsed_and_validated() {
        let doc = TomlDoc::parse("[exchange]\noverlap = \"stream\"\nbucket_elems = 4096").unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.exchange.overlap, OverlapMode::Stream);
        assert_eq!(cfg.exchange.bucket_elems, 4096);
        assert_eq!(TrainConfig::default().exchange.overlap, OverlapMode::Off);
        assert_eq!(TrainConfig::default().exchange.bucket_elems, 32_768);
        for (s, m) in [
            ("off", OverlapMode::Off),
            ("stream", OverlapMode::Stream),
            ("on", OverlapMode::Stream),
            ("serial", OverlapMode::Serial),
        ] {
            assert_eq!(OverlapMode::parse(s).unwrap(), m);
        }
        assert!(OverlapMode::parse("sideways").is_err());
        // Overlap at period > 1 is a config error: gradient averaging
        // is only the synchronized update when every step exchanges.
        let doc = TomlDoc::parse("[exchange]\noverlap = \"stream\"\nperiod = 2").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[exchange]\nbucket_elems = 0").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn bad_configs_rejected() {
        let doc = TomlDoc::parse("[cluster]\nworkers = 2\nswitch_of_worker = [0]").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[training]\nloader = \"warp\"").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
        let doc = TomlDoc::parse("[exchange]\ntransport = \"carrier-pigeon\"").unwrap();
        assert!(TrainConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn distributed_section_parses() {
        let doc = TomlDoc::parse(
            "[cluster]\nworkers = 2\n[distributed]\nrank = 1\n\
             peers = [\"127.0.0.1:7301\", \"127.0.0.1:7302\"]\n\
             connect_timeout_ms = 5000\nio_timeout_ms = 9000",
        )
        .unwrap();
        let cfg = TrainConfig::from_doc(&doc).unwrap();
        let d = cfg.distributed.unwrap();
        assert_eq!(d.rank, 1);
        assert_eq!(d.peers, vec!["127.0.0.1:7301", "127.0.0.1:7302"]);
        assert_eq!(d.connect_timeout_ms, 5000);
        assert_eq!(d.io_timeout_ms, 9000);
        // No [distributed] section (or an empty peer list) = in-process.
        assert!(TrainConfig::default().distributed.is_none());
    }

    #[test]
    fn distributed_misconfigurations_rejected() {
        let base = || {
            let mut cfg = TrainConfig::default();
            cfg.distributed = Some(DistributedCfg {
                rank: 0,
                peers: vec!["127.0.0.1:7301".into(), "127.0.0.1:7302".into()],
                ..DistributedCfg::default()
            });
            cfg
        };
        base().validate().unwrap();
        // Peer list must cover every worker.
        let mut cfg = base();
        cfg.distributed.as_mut().unwrap().peers.pop();
        assert!(cfg.validate().is_err());
        // Rank must index into the peer list.
        let mut cfg = base();
        cfg.distributed.as_mut().unwrap().rank = 2;
        assert!(cfg.validate().is_err());
        // Addresses must look like host:port and be distinct.
        let mut cfg = base();
        cfg.distributed.as_mut().unwrap().peers[1] = "nonsense".into();
        assert!(cfg.validate().is_err());
        let mut cfg = base();
        cfg.distributed.as_mut().unwrap().peers[1] = "127.0.0.1:7301".into();
        assert!(cfg.validate().is_err());
        // Zero timeouts are rejected.
        let mut cfg = base();
        cfg.distributed.as_mut().unwrap().io_timeout_ms = 0;
        assert!(cfg.validate().is_err());
        // A single-worker "ring" is rejected.
        let mut cfg = base();
        cfg.cluster = ClusterConfig::single();
        cfg.distributed.as_mut().unwrap().peers.truncate(1);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn distributed_mode_does_not_change_the_resume_fingerprint() {
        // The whole point of the TCP ring: a distributed run must
        // resume from (and produce) the same checkpoints as the
        // in-memory run with the same math config.
        let base = TrainConfig::default();
        let mut dist = base.clone();
        dist.distributed = Some(DistributedCfg {
            rank: 1,
            peers: vec!["10.0.0.1:7301".into(), "10.0.0.2:7301".into()],
            connect_timeout_ms: 1234,
            io_timeout_ms: 5678,
        });
        assert_eq!(base.resume_fingerprint(), dist.resume_fingerprint());
    }

    #[test]
    fn transport_parse_names() {
        for (s, k) in [
            ("p2p", TransportKind::P2p),
            ("host_staged", TransportKind::HostStaged),
            ("multiprocessing", TransportKind::Serialized),
        ] {
            assert_eq!(TransportKind::parse(s).unwrap(), k);
        }
    }
}
