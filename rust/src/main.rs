//! `tmg` — leader entrypoint.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match theano_mgpu::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
