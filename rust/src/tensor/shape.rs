//! Dense row-major shapes.

use std::fmt;

use crate::util::math::numel;

/// A dense row-major shape (arbitrary rank, scalars are rank 0).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn scalar() -> Self {
        Shape(vec![])
    }

    pub fn of(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn numel(&self) -> usize {
        numel(&self.0)
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Dims as i64 (what `xla::Literal::reshape` wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.0.iter().map(|&d| d as i64).collect()
    }

    /// Row-major strides in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.0.len()];
        for i in (0..self.0.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let s = Shape::of(&[2, 3, 4]);
        assert_eq!(s.rank(), 3);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.dims_i64(), vec![2, 3, 4]);
        assert_eq!(format!("{s}"), "[2, 3, 4]");
    }

    #[test]
    fn scalar() {
        let s = Shape::scalar();
        assert_eq!(s.rank(), 0);
        assert_eq!(s.numel(), 1);
        assert!(s.strides().is_empty());
    }
}
