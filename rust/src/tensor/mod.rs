//! Host-side tensors.
//!
//! The coordinator moves raw `f32`/`u8`/`i32` buffers between the data
//! pipeline, the exchange engine and PJRT literals; this module gives
//! those buffers shape-checked types without pulling in an ndarray
//! dependency (offline crate set).  Layout is always dense row-major
//! (NCHW for images), matching the L2 model ABI.

mod host_tensor;
mod shape;

pub use host_tensor::{HostTensor, Image8};
pub use shape::Shape;

/// Element type tags mirroring the manifest's dtype strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    /// Parse a manifest dtype string ("float32", "int32", "uint8").
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "int32" | "i32" => Some(DType::I32),
            "uint8" | "u8" => Some(DType::U8),
            _ => None,
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32"), Some(DType::F32));
        assert_eq!(DType::parse("int32"), Some(DType::I32));
        assert_eq!(DType::parse("uint8"), Some(DType::U8));
        assert_eq!(DType::parse("bfloat16"), None);
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::U8.size_bytes(), 1);
    }
}
