//! Dense host tensors (`f32`) and raw `u8` images.

use crate::error::{Error, Result};
use crate::tensor::Shape;

/// Dense row-major `f32` tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    shape: Shape,
    data: Vec<f32>,
}

impl HostTensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: Shape) -> Self {
        let n = shape.numel();
        HostTensor { shape, data: vec![0.0; n] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: Shape, v: f32) -> Self {
        let n = shape.numel();
        HostTensor { shape, data: vec![v; n] }
    }

    /// Tensor of N(0, std²) draws — synthetic batches for examples,
    /// benches and backend tests.
    pub fn rand_normal(shape: Shape, rng: &mut crate::util::Pcg32, std: f32) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(t.as_mut_slice(), std);
        t
    }

    /// Wrap an existing buffer (must match the shape's element count).
    pub fn from_vec(shape: Shape, data: Vec<f32>) -> Result<Self> {
        if shape.numel() != data.len() {
            return Err(Error::Shape(format!(
                "buffer of {} elements does not match shape {shape}",
                data.len()
            )));
        }
        Ok(HostTensor { shape, data })
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// In-place elementwise average with another tensor (Fig-2 step 3).
    pub fn average_with(&mut self, other: &HostTensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "average_with: {} vs {}",
                self.shape, other.shape
            )));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = 0.5 * (*a + *b);
        }
        Ok(())
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &HostTensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!("axpy: {} vs {}", self.shape, other.shape)));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Scale all elements in place.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }
}

/// A raw `u8` image batch or single image in NCHW / CHW layout.
///
/// The on-disk shard format and the staging side of the loading
/// pipeline both traffic in `u8` pixels (as JPEG-decoded ImageNet did);
/// conversion to `f32` happens in preprocessing.
#[derive(Clone, Debug, PartialEq)]
pub struct Image8 {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub pixels: Vec<u8>,
}

impl Image8 {
    pub fn new(channels: usize, height: usize, width: usize) -> Self {
        Image8 { channels, height, width, pixels: vec![0; channels * height * width] }
    }

    pub fn from_pixels(
        channels: usize,
        height: usize,
        width: usize,
        pixels: Vec<u8>,
    ) -> Result<Self> {
        if pixels.len() != channels * height * width {
            return Err(Error::Shape(format!(
                "pixel buffer {} != {}x{}x{}",
                pixels.len(),
                channels,
                height,
                width
            )));
        }
        Ok(Image8 { channels, height, width, pixels })
    }

    pub fn numel(&self) -> usize {
        self.pixels.len()
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> u8 {
        self.pixels[(c * self.height + y) * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: u8) {
        self.pixels[(c * self.height + y) * self.width + x] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_mismatch() {
        let t = HostTensor::zeros(Shape::of(&[2, 3]));
        assert_eq!(t.numel(), 6);
        assert!(HostTensor::from_vec(Shape::of(&[2, 2]), vec![0.0; 5]).is_err());
    }

    #[test]
    fn rand_normal_moments() {
        let mut rng = crate::util::Pcg32::seeded(3);
        let t = HostTensor::rand_normal(Shape::of(&[10_000]), &mut rng, 0.5);
        let std = crate::util::math::stddev(
            &t.as_slice().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!((std - 0.5).abs() < 0.05, "std {std}");
    }

    #[test]
    fn average() {
        let mut a = HostTensor::from_vec(Shape::of(&[3]), vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::from_vec(Shape::of(&[3]), vec![3.0, 2.0, 1.0]).unwrap();
        a.average_with(&b).unwrap();
        assert_eq!(a.as_slice(), &[2.0, 2.0, 2.0]);
        let c = HostTensor::zeros(Shape::of(&[4]));
        assert!(a.average_with(&c).is_err());
    }

    #[test]
    fn axpy_scale() {
        let mut a = HostTensor::from_vec(Shape::of(&[2]), vec![1.0, 1.0]).unwrap();
        let b = HostTensor::from_vec(Shape::of(&[2]), vec![2.0, 4.0]).unwrap();
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.as_slice(), &[2.0, 3.0]);
        a.scale(2.0);
        assert_eq!(a.as_slice(), &[4.0, 6.0]);
    }

    #[test]
    fn image_indexing() {
        let mut im = Image8::new(3, 4, 5);
        im.set(2, 3, 4, 77);
        assert_eq!(im.at(2, 3, 4), 77);
        assert_eq!(im.numel(), 60);
        assert!(Image8::from_pixels(3, 4, 5, vec![0; 59]).is_err());
    }
}
