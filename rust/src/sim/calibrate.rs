//! Calibration: measure real costs on this machine to drive the
//! simulator.
//!
//! Three measurements, all of code paths this repo actually runs:
//!
//! 1. **Compiled-step time per backend** — executes the micro-model
//!    train artifacts (`train_alexnet-micro_<backend>_b8`) through the
//!    PJRT runtime and takes the min of several runs.  These carry
//!    the *relative* cost of the conv backends (the paper's
//!    cuda-convnet vs cuDNN-R1 vs cuDNN-R2 comparison).
//! 2. **Loader time per image** — times `SerialLoader` over a real
//!    generated shard set (disk read + preprocess).
//! 3. **Host copy bandwidth** — times large memcpys; rescales the
//!    interconnect cost model.

use std::collections::BTreeMap;
use std::path::Path;

use crate::data::loader::{BatchSource, LoaderCfg, SerialLoader};
use crate::data::synth::{generate_dataset, SynthSpec};
use crate::error::Result;
use crate::runtime::literal_bridge::{f32_scalar, i32_scalar, i32_to_literal, tensor_to_literal};
use crate::runtime::{Manifest, RuntimeClient};
use crate::tensor::{HostTensor, Shape};
use crate::util::timer::{measure_runs, median, Timer};

/// Everything the Table-1 / scaling simulators need.
#[derive(Clone, Debug)]
pub struct CalibratedCosts {
    /// Median seconds of one micro-model train step, per backend.
    pub backend_step_s: BTreeMap<String, f64>,
    /// Batch size those steps were measured at.
    pub micro_batch: usize,
    /// Seconds to load + preprocess one image (stored 20px edge).
    pub load_s_per_image: f64,
    /// Edge of the images the loader was measured on.
    pub load_hw: usize,
    /// Measured host memcpy bandwidth (bytes/s).
    pub host_copy_bytes_per_s: f64,
}

impl CalibratedCosts {
    /// Canned values (measured once on the dev box) for tests and for
    /// running the simulator without artifacts present.
    pub fn canned() -> Self {
        let mut m = BTreeMap::new();
        m.insert("refconv".into(), 0.010);
        m.insert("convnet".into(), 0.055);
        m.insert("cudnn_r1".into(), 0.045);
        m.insert("cudnn_r2".into(), 0.040);
        CalibratedCosts {
            backend_step_s: m,
            micro_batch: 8,
            load_s_per_image: 120e-6,
            load_hw: 20,
            host_copy_bytes_per_s: 8.0e9,
        }
    }

    pub fn step_s(&self, backend: &str) -> Option<f64> {
        self.backend_step_s.get(backend).copied()
    }
}

/// The measurement harness.
pub struct Calibration;

impl Calibration {
    /// Measure compiled-step time for every micro-model train artifact
    /// present in the manifest.
    pub fn measure_backends(artifacts_dir: &Path, runs: usize) -> Result<BTreeMap<String, f64>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = RuntimeClient::cpu()?;
        let mut out = BTreeMap::new();
        for spec in manifest
            .artifacts
            .iter()
            .filter(|a| a.model == "alexnet-micro" && matches!(a.kind, crate::runtime::artifact::ArtifactKind::Train))
        {
            let exe = client.load_step(spec)?;
            let model = manifest.model(&spec.model)?;
            let b = spec.batch_size;
            let hw = model.image_hw;
            let images = HostTensor::zeros(Shape::of(&[b, model.in_channels, hw, hw]));
            let labels = vec![0i32; b];
            let store = crate::params::ParamStore::init(&model.params, 7);
            let build_inputs = || -> Result<Vec<xla::Literal>> {
                let mut v = Vec::new();
                v.push(tensor_to_literal(&images)?);
                v.push(i32_to_literal(&labels)?);
                v.push(f32_scalar(0.01));
                v.push(i32_scalar(0));
                for p in &store.params {
                    v.push(tensor_to_literal(p)?);
                }
                for m in &store.momenta {
                    v.push(tensor_to_literal(m)?);
                }
                Ok(v)
            };
            let inputs = build_inputs()?;
            // Min-of-N: the most noise-robust point estimate on a busy
            // shared core (any positive noise only inflates samples).
            let times = measure_runs(2, runs.max(5), || {
                exe.run(&inputs).expect("calibration step failed");
            });
            out.insert(spec.backend.clone(), times[0]);
        }
        Ok(out)
    }

    /// Measure loader seconds/image over a throwaway generated dataset.
    pub fn measure_loader(tmp_dir: &Path) -> Result<(f64, usize)> {
        let hw = 20usize;
        if !tmp_dir.join("meta.json").exists() {
            let spec = SynthSpec { classes: 8, hw, seed: 99, ..Default::default() };
            generate_dataset(tmp_dir, &spec, 512, 64, 256)?;
        }
        let cfg = LoaderCfg {
            data_dir: tmp_dir,
            split: "train",
            batch: 32,
            crop_hw: 16,
            worker: 0,
            workers: 1,
            seed: 1,
            train_augment: true,
            verify_shards: false,
        };
        let mut loader = SerialLoader::new(&cfg)?;
        // Warm the page cache, then measure.
        for _ in 0..2 {
            loader.next_batch()?;
        }
        let t = Timer::start();
        let batches = 8;
        for _ in 0..batches {
            loader.next_batch()?;
        }
        let per_image = t.elapsed_secs() / (batches * 32) as f64;
        Ok((per_image, hw))
    }

    /// Measure host copy bandwidth with large buffer copies.
    pub fn measure_memcpy() -> f64 {
        let n = 32 << 20; // 32 MiB of f32
        let src = vec![1.0f32; n / 4];
        let mut dst = vec![0.0f32; n / 4];
        let times = measure_runs(1, 5, || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&dst);
        });
        n as f64 / median(&times)
    }

    /// Full calibration (requires artifacts + scratch dir).
    pub fn measure(artifacts_dir: &Path, scratch: &Path, runs: usize) -> Result<CalibratedCosts> {
        let backend_step_s = Self::measure_backends(artifacts_dir, runs)?;
        let (load_s_per_image, load_hw) = Self::measure_loader(scratch)?;
        let host_copy_bytes_per_s = Self::measure_memcpy();
        Ok(CalibratedCosts {
            backend_step_s,
            micro_batch: 8,
            load_s_per_image,
            load_hw,
            host_copy_bytes_per_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canned_costs_sane() {
        let c = CalibratedCosts::canned();
        assert!(c.step_s("cudnn_r2").unwrap() < c.step_s("convnet").unwrap());
        assert!(c.step_s("refconv").unwrap() > 0.0);
        assert!(c.step_s("nope").is_none());
    }

    #[test]
    fn memcpy_bandwidth_positive() {
        let bw = Calibration::measure_memcpy();
        assert!(bw > 1e8, "memcpy bandwidth {bw} implausibly low");
    }
}
