//! Discrete-event simulation of the Fig-1 + Fig-2 schedule.
//!
//! State advances through the exact event structure of the paper's
//! pipeline: per worker, a loader (depth-1 double buffer) and a
//! trainer; per exchange period, a synchronization point where all
//! replicas barrier and pay the exchange cost.  Costs are sampled per
//! event from calibrated means with multiplicative jitter, so window
//! times fluctuate realistically rather than being `n * mean`.
//!
//! Event recurrence (worker w, step k):
//!
//! ```text
//! start[w,k]  = max(done[w,k-1], ready[w,k])        (need batch + free trainer)
//! ready[w,k+1]= max(start[w,k], ready[w,k]) + load  (buffer freed at handoff)
//! comp[w,k]   = start[w,k] + compute
//! done[w,k]   = comp[w,k]                    if no exchange this step
//!             = max_w(comp[w,k]) + exchange  otherwise (barrier + Fig 2)
//! ```
//!
//! Serial loading is the same recurrence with `ready[w,k+1]` forced to
//! `start loading at done[w,k]` — i.e. load happens inside the step.

use crate::util::Pcg32;

/// Inputs to one simulation run.
#[derive(Clone, Debug)]
pub struct PipelineParams {
    pub workers: usize,
    /// Mean seconds of one local compute step.
    pub compute_s: f64,
    /// Mean seconds to load + preprocess + stage one minibatch.
    pub load_s: f64,
    /// Seconds of one exchange round (0 disables).
    pub exchange_s: f64,
    /// Exchange every `period` steps.
    pub period: usize,
    /// Parallel (Fig 1) vs serial loading.
    pub parallel_loading: bool,
    /// Multiplicative jitter half-width (0.05 = ±5%).
    pub jitter: f64,
    pub seed: u64,
}

impl Default for PipelineParams {
    fn default() -> Self {
        PipelineParams {
            workers: 2,
            compute_s: 1.0,
            load_s: 0.3,
            exchange_s: 0.05,
            period: 1,
            parallel_loading: true,
            jitter: 0.03,
            seed: 7,
        }
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub steps: usize,
    pub total_s: f64,
    /// Completion time of each step (synchronized across workers).
    pub step_done_s: Vec<f64>,
    /// Seconds per 20 iterations (Table 1's unit), per closed window.
    pub per20: Vec<f64>,
    /// Mean trainer stall waiting on the loader.
    pub stall_s: f64,
    /// Fraction of load time hidden under compute (1.0 = fully hidden).
    pub overlap_efficiency: f64,
}

impl SimOutcome {
    pub fn mean_per20(&self) -> f64 {
        if self.per20.is_empty() {
            // Extrapolate from total when the run is shorter than a window.
            self.total_s / self.steps as f64 * 20.0
        } else {
            self.per20.iter().sum::<f64>() / self.per20.len() as f64
        }
    }
}

fn sample(rng: &mut Pcg32, mean: f64, jitter: f64) -> f64 {
    if jitter <= 0.0 {
        return mean;
    }
    let u = rng.next_f32() as f64 * 2.0 - 1.0;
    mean * (1.0 + jitter * u)
}

/// Run the schedule for `steps` steps.
pub fn simulate(p: &PipelineParams, steps: usize) -> SimOutcome {
    assert!(p.workers >= 1 && steps > 0 && p.period >= 1);
    let w = p.workers;
    let mut rng = Pcg32::new(p.seed, 0x51B);

    // ready[w] = completion time of the *staged* next batch.
    // For parallel loading the loader starts prefetching at t=0.
    let mut ready = vec![0.0f64; w];
    let mut loader_free = vec![0.0f64; w]; // when the loader can start the next load
    let mut done = vec![0.0f64; w];
    let mut stall = 0.0f64;
    let mut load_total = 0.0f64;
    let mut load_hidden = 0.0f64;
    let mut step_done = Vec::with_capacity(steps);

    if p.parallel_loading {
        for i in 0..w {
            let l = sample(&mut rng, p.load_s, p.jitter);
            ready[i] = l; // first batch prefetched from t=0
            loader_free[i] = l;
            load_total += l;
        }
    }

    for k in 0..steps {
        let mut comp_end = vec![0.0f64; w];
        for i in 0..w {
            let start;
            if p.parallel_loading {
                start = done[i].max(ready[i]);
                stall += (ready[i] - done[i]).max(0.0);
                // Loader begins the next batch at handoff (buffer freed),
                // or when it finished the previous one, whichever is later.
                let l = sample(&mut rng, p.load_s, p.jitter);
                let lstart = loader_free[i].max(start);
                loader_free[i] = lstart + l;
                // Hidden fraction: how much of this load fits under compute.
                load_total += l;
                ready[i] = loader_free[i];
            } else {
                // Serial: load happens inside the step, on the trainer.
                let l = sample(&mut rng, p.load_s, p.jitter);
                start = done[i] + l;
                stall += l;
                load_total += l;
            }
            let c = sample(&mut rng, p.compute_s, p.jitter);
            comp_end[i] = start + c;
            if p.parallel_loading {
                // Load time overlapped with this step's compute window.
                let window = c.min((loader_free[i] - start).max(0.0));
                load_hidden += window.min(c);
            }
        }
        // Exchange boundary: replicas barrier, then pay the round cost.
        let step_end = if p.exchange_s > 0.0 && w > 1 && (k + 1) % p.period == 0 {
            let barrier = comp_end.iter().cloned().fold(0.0f64, f64::max);
            let e = sample(&mut rng, p.exchange_s, p.jitter);
            barrier + e
        } else if w > 1 && (k + 1) % p.period == 0 {
            comp_end.iter().cloned().fold(0.0f64, f64::max)
        } else {
            // No sync this step: workers proceed independently; for
            // reporting we track the slowest.
            comp_end.iter().cloned().fold(0.0f64, f64::max)
        };
        for i in 0..w {
            done[i] = if w > 1 && (k + 1) % p.period == 0 {
                step_end
            } else {
                comp_end[i]
            };
        }
        step_done.push(step_end);
    }

    let total = *step_done.last().unwrap();
    let mut per20 = Vec::new();
    let mut prev = 0.0;
    let mut count = 0;
    for (i, &t) in step_done.iter().enumerate() {
        count += 1;
        if count == 20 {
            per20.push(t - prev);
            prev = t;
            count = 0;
        }
        let _ = i;
    }

    SimOutcome {
        steps,
        total_s: total,
        step_done_s: step_done,
        per20,
        stall_s: stall / (steps * w) as f64,
        overlap_efficiency: if load_total > 0.0 && p.parallel_loading {
            (load_hidden / load_total).min(1.0)
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> PipelineParams {
        PipelineParams { jitter: 0.0, ..Default::default() }
    }

    #[test]
    fn serial_is_load_plus_compute() {
        let p = PipelineParams {
            workers: 1,
            parallel_loading: false,
            exchange_s: 0.0,
            compute_s: 1.0,
            load_s: 0.25,
            ..base()
        };
        let out = simulate(&p, 40);
        assert!((out.total_s - 40.0 * 1.25).abs() < 1e-9);
        assert_eq!(out.per20.len(), 2);
        assert!((out.mean_per20() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn parallel_hides_load_when_compute_dominates() {
        let p = PipelineParams {
            workers: 1,
            parallel_loading: true,
            exchange_s: 0.0,
            compute_s: 1.0,
            load_s: 0.25,
            ..base()
        };
        let out = simulate(&p, 40);
        // First batch can't be hidden; steady state is compute-bound.
        let expect = 0.25 + 40.0 * 1.0;
        assert!((out.total_s - expect).abs() < 1e-6, "{}", out.total_s);
        assert!(out.overlap_efficiency > 0.9);
    }

    #[test]
    fn loader_bound_when_load_dominates() {
        let p = PipelineParams {
            workers: 1,
            parallel_loading: true,
            exchange_s: 0.0,
            compute_s: 0.2,
            load_s: 1.0,
            ..base()
        };
        let out = simulate(&p, 30);
        // Pipeline is loader-bound: ~load per step.
        assert!((out.total_s - (1.0 * 30.0 + 0.2)).abs() < 1e-6, "{}", out.total_s);
        assert!(out.stall_s > 0.5);
    }

    #[test]
    fn two_workers_pay_exchange_each_period() {
        let base_p = PipelineParams {
            workers: 2,
            parallel_loading: true,
            compute_s: 1.0,
            load_s: 0.1,
            exchange_s: 0.2,
            period: 1,
            ..base()
        };
        let with = simulate(&base_p, 20);
        let without = simulate(&PipelineParams { exchange_s: 0.0, ..base_p.clone() }, 20);
        let delta = with.total_s - without.total_s;
        assert!((delta - 20.0 * 0.2).abs() < 1e-6, "delta {delta}");
        // Period 2 halves the exchange bill.
        let p2 = simulate(&PipelineParams { period: 2, ..base_p }, 20);
        let delta2 = p2.total_s - without.total_s;
        assert!((delta2 - 10.0 * 0.2).abs() < 1e-6, "delta2 {delta2}");
    }

    #[test]
    fn parallel_beats_serial() {
        for workers in [1, 2] {
            let p = PipelineParams {
                workers,
                compute_s: 1.0,
                load_s: 0.4,
                exchange_s: 0.05,
                ..base()
            };
            let par = simulate(&PipelineParams { parallel_loading: true, ..p.clone() }, 60);
            let ser = simulate(&PipelineParams { parallel_loading: false, ..p }, 60);
            assert!(
                par.total_s < 0.8 * ser.total_s,
                "workers={workers}: par {} ser {}",
                par.total_s,
                ser.total_s
            );
        }
    }

    #[test]
    fn jitter_preserves_mean_roughly() {
        let p = PipelineParams {
            workers: 1,
            parallel_loading: false,
            exchange_s: 0.0,
            compute_s: 1.0,
            load_s: 0.0,
            jitter: 0.05,
            ..Default::default()
        };
        let out = simulate(&p, 400);
        assert!((out.total_s - 400.0).abs() < 400.0 * 0.02);
    }
}
