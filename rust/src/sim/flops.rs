//! Analytic FLOP/parameter counts for the AlexNet family.
//!
//! Mirrors python/compile/model.py's architecture descriptions; used to
//! scale measured micro-model step times to paper-scale AlexNet without
//! having to run the full net on this CPU testbed.

/// One conv stage (see model.py ConvSpec).
#[derive(Clone, Copy, Debug)]
pub struct ConvStage {
    pub cout: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    pub pool: bool,
}

/// Architecture description sufficient for FLOP counting.
#[derive(Clone, Debug)]
pub struct ArchDesc {
    pub name: &'static str,
    pub image_hw: usize,
    pub in_channels: usize,
    pub num_classes: usize,
    pub convs: Vec<ConvStage>,
    pub fc_dims: Vec<usize>,
    pub pool_window: usize,
    pub pool_stride: usize,
}

/// The full AlexNet of the paper.
pub fn alexnet() -> ArchDesc {
    ArchDesc {
        name: "alexnet",
        image_hw: 227,
        in_channels: 3,
        num_classes: 1000,
        convs: vec![
            ConvStage { cout: 96, kernel: 11, stride: 4, pad: 0, pool: true },
            ConvStage { cout: 256, kernel: 5, stride: 1, pad: 2, pool: true },
            ConvStage { cout: 384, kernel: 3, stride: 1, pad: 1, pool: false },
            ConvStage { cout: 384, kernel: 3, stride: 1, pad: 1, pool: false },
            ConvStage { cout: 256, kernel: 3, stride: 1, pad: 1, pool: true },
        ],
        fc_dims: vec![4096, 4096],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// The CPU-scale variant the end-to-end driver trains.
pub fn alexnet_tiny() -> ArchDesc {
    ArchDesc {
        name: "alexnet-tiny",
        image_hw: 64,
        in_channels: 3,
        num_classes: 100,
        convs: vec![
            ConvStage { cout: 32, kernel: 5, stride: 2, pad: 2, pool: true },
            ConvStage { cout: 64, kernel: 3, stride: 1, pad: 1, pool: true },
            ConvStage { cout: 96, kernel: 3, stride: 1, pad: 1, pool: false },
            ConvStage { cout: 96, kernel: 3, stride: 1, pad: 1, pool: false },
            ConvStage { cout: 64, kernel: 3, stride: 1, pad: 1, pool: true },
        ],
        fc_dims: vec![512, 256],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// Test-scale variant (the calibration workhorse).
pub fn alexnet_micro() -> ArchDesc {
    ArchDesc {
        name: "alexnet-micro",
        image_hw: 32,
        in_channels: 3,
        num_classes: 10,
        convs: vec![
            ConvStage { cout: 8, kernel: 5, stride: 2, pad: 2, pool: true },
            ConvStage { cout: 16, kernel: 3, stride: 1, pad: 1, pool: false },
        ],
        fc_dims: vec![64],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// Look up an architecture by name.  Underscore and hyphen spellings
/// are equivalent (`alexnet_micro` == `alexnet-micro`).
pub fn arch_by_name(name: &str) -> Option<ArchDesc> {
    match name.replace('_', "-").as_str() {
        "alexnet" => Some(alexnet()),
        "alexnet-tiny" => Some(alexnet_tiny()),
        "alexnet-micro" => Some(alexnet_micro()),
        _ => None,
    }
}

impl ArchDesc {
    /// Forward multiply-accumulates for one example.
    pub fn forward_macs(&self) -> u64 {
        let mut macs = 0u64;
        let mut cin = self.in_channels;
        let mut hw = self.image_hw;
        for c in &self.convs {
            let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            macs += (c.cout * cin * c.kernel * c.kernel) as u64 * (out_hw * out_hw) as u64;
            hw = out_hw;
            if c.pool {
                hw = (hw - self.pool_window) / self.pool_stride + 1;
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        for &d in &self.fc_dims {
            macs += (feat * d) as u64;
            feat = d;
        }
        macs += (feat * self.num_classes) as u64;
        macs
    }

    /// Train-step MACs per example: fwd + bwd (~2x fwd) = 3x fwd.
    pub fn train_macs(&self) -> u64 {
        3 * self.forward_macs()
    }

    /// Parameter element count (weights + biases).
    pub fn param_elements(&self) -> u64 {
        let mut n = 0u64;
        let mut cin = self.in_channels;
        let mut hw = self.image_hw;
        for c in &self.convs {
            n += (c.cout * cin * c.kernel * c.kernel + c.cout) as u64;
            let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            hw = out_hw;
            if c.pool {
                hw = (hw - self.pool_window) / self.pool_stride + 1;
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        for &d in &self.fc_dims {
            n += (feat * d + d) as u64;
            feat = d;
        }
        n += (feat * self.num_classes + self.num_classes) as u64;
        n
    }

    /// Bytes of one Fig-2 exchange payload (params + momenta, f32).
    pub fn exchange_bytes(&self) -> u64 {
        self.param_elements() * 4 * 2
    }
}

/// Compute-cost scale factor from a measured (arch_a, batch_a) step to
/// a target (arch_b, batch_b) step.
pub fn scale_factor(from: &ArchDesc, batch_from: usize, to: &ArchDesc, batch_to: usize) -> f64 {
    (to.train_macs() as f64 * batch_to as f64) / (from.train_macs() as f64 * batch_from as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_params_near_60m() {
        // Krizhevsky et al. report ~60M parameters.
        let n = alexnet().param_elements();
        assert!((55_000_000..66_000_000).contains(&n), "{n}");
    }

    #[test]
    fn alexnet_fwd_flops_near_700m_macs() {
        // Literature: ~0.7 GMACs (1.4 GFLOPs) per 227x227 forward pass.
        let m = alexnet().forward_macs();
        assert!((600_000_000..1_300_000_000).contains(&m), "{m}");
    }

    #[test]
    fn ordering_micro_tiny_full() {
        let micro = alexnet_micro().train_macs();
        let tiny = alexnet_tiny().train_macs();
        let full = alexnet().train_macs();
        assert!(micro < tiny && tiny < full);
    }

    #[test]
    fn scale_factor_linear_in_batch() {
        let a = alexnet_micro();
        let f1 = scale_factor(&a, 8, &a, 16);
        assert!((f1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lookup_by_name() {
        assert!(arch_by_name("alexnet").is_some());
        assert!(arch_by_name("alexnet_micro").is_some());
        assert!(arch_by_name("resnet").is_none());
    }
}
