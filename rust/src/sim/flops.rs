//! Analytic FLOP/parameter counts for the AlexNet family.
//!
//! Mirrors python/compile/model.py's architecture descriptions; used to
//! scale measured micro-model step times to paper-scale AlexNet without
//! having to run the full net on this CPU testbed.
//!
//! Architecture variation flows through data: a `ConvStage` carries its
//! group count and an optional local-response-normalization spec, so the
//! faithful paper model and the CPU-scale variants are the same code path
//! with different descriptions.

/// Cross-channel local response normalization (Krizhevsky et al. 2012,
/// section 3.3): `b_c = a_c / (bias + (alpha/n) * sum_{|c'-c|<=r} a_{c'}^2)^beta`
/// with `n = 2*radius + 1`.  Matches python/compile/kernels/ref.py::lrn_ref.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LrnSpec {
    pub radius: usize,
    pub bias: f32,
    pub alpha: f32,
    pub beta: f32,
}

impl LrnSpec {
    /// The constants of the paper (depth radius 2, k=2, alpha=1e-4, beta=0.75).
    pub const fn krizhevsky() -> Self {
        LrnSpec { radius: 2, bias: 2.0, alpha: 1e-4, beta: 0.75 }
    }
}

/// One conv stage (see model.py ConvSpec).
#[derive(Clone, Copy, Debug)]
pub struct ConvStage {
    pub cout: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    pub pool: bool,
    /// Channel groups: weights are `cout x (cin/groups) x k x k`, so
    /// groups > 1 divides both weight elements and MACs by `groups`.
    /// This is the two-GPU model-parallel split of the paper baked into
    /// the architecture (conv2/4/5 of faithful AlexNet use groups=2).
    pub groups: usize,
    /// Optional LRN applied after this stage's ReLU (before pooling).
    pub lrn: Option<LrnSpec>,
}

impl ConvStage {
    /// Plain ungrouped stage with no normalization.
    pub const fn plain(cout: usize, kernel: usize, stride: usize, pad: usize, pool: bool) -> Self {
        ConvStage { cout, kernel, stride, pad, pool, groups: 1, lrn: None }
    }

    /// Split this stage's channels into `groups` filter groups.
    pub const fn grouped(mut self, groups: usize) -> Self {
        self.groups = groups;
        self
    }

    /// Follow this stage's ReLU with local response normalization.
    pub const fn with_lrn(mut self, lrn: LrnSpec) -> Self {
        self.lrn = Some(lrn);
        self
    }
}

/// Architecture description sufficient for FLOP counting.
#[derive(Clone, Debug)]
pub struct ArchDesc {
    pub name: &'static str,
    pub image_hw: usize,
    pub in_channels: usize,
    pub num_classes: usize,
    pub convs: Vec<ConvStage>,
    pub fc_dims: Vec<usize>,
    pub pool_window: usize,
    pub pool_stride: usize,
}

/// The full AlexNet of the paper: 2-group convolutions on conv2/4/5
/// (the two-GPU split of Krizhevsky 2012) and LRN after conv1/conv2.
pub fn alexnet() -> ArchDesc {
    let lrn = LrnSpec::krizhevsky();
    ArchDesc {
        name: "alexnet",
        image_hw: 227,
        in_channels: 3,
        num_classes: 1000,
        convs: vec![
            ConvStage::plain(96, 11, 4, 0, true).with_lrn(lrn),
            ConvStage::plain(256, 5, 1, 2, true).grouped(2).with_lrn(lrn),
            ConvStage::plain(384, 3, 1, 1, false),
            ConvStage::plain(384, 3, 1, 1, false).grouped(2),
            ConvStage::plain(256, 3, 1, 1, true).grouped(2),
        ],
        fc_dims: vec![4096, 4096],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// The CPU-scale variant the end-to-end driver trains (ungrouped, no LRN).
pub fn alexnet_tiny() -> ArchDesc {
    ArchDesc {
        name: "alexnet-tiny",
        image_hw: 64,
        in_channels: 3,
        num_classes: 100,
        convs: vec![
            ConvStage::plain(32, 5, 2, 2, true),
            ConvStage::plain(64, 3, 1, 1, true),
            ConvStage::plain(96, 3, 1, 1, false),
            ConvStage::plain(96, 3, 1, 1, false),
            ConvStage::plain(64, 3, 1, 1, true),
        ],
        fc_dims: vec![512, 256],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// Tiny geometry with the faithful model's structure (groups=2 on
/// conv2/4/5, LRN after conv1/conv2): exercises the grouped + LRN code
/// paths at CPU test scale.
pub fn alexnet_tiny_faithful() -> ArchDesc {
    let lrn = LrnSpec::krizhevsky();
    ArchDesc {
        name: "alexnet-tiny-faithful",
        image_hw: 64,
        in_channels: 3,
        num_classes: 100,
        convs: vec![
            ConvStage::plain(32, 5, 2, 2, true).with_lrn(lrn),
            ConvStage::plain(64, 3, 1, 1, true).grouped(2).with_lrn(lrn),
            ConvStage::plain(96, 3, 1, 1, false),
            ConvStage::plain(96, 3, 1, 1, false).grouped(2),
            ConvStage::plain(64, 3, 1, 1, true).grouped(2),
        ],
        fc_dims: vec![512, 256],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// Test-scale variant (the calibration workhorse).
pub fn alexnet_micro() -> ArchDesc {
    ArchDesc {
        name: "alexnet-micro",
        image_hw: 32,
        in_channels: 3,
        num_classes: 10,
        convs: vec![
            ConvStage::plain(8, 5, 2, 2, true),
            ConvStage::plain(16, 3, 1, 1, false),
        ],
        fc_dims: vec![64],
        pool_window: 3,
        pool_stride: 2,
    }
}

/// Every architecture `arch_by_name` knows, hyphen spelling.
pub fn known_arch_names() -> &'static [&'static str] {
    &["alexnet", "alexnet-tiny", "alexnet-tiny-faithful", "alexnet-micro"]
}

/// Look up an architecture by name.  Underscore and hyphen spellings
/// are equivalent (`alexnet_micro` == `alexnet-micro`).
pub fn arch_by_name(name: &str) -> Option<ArchDesc> {
    match name.replace('_', "-").as_str() {
        "alexnet" => Some(alexnet()),
        "alexnet-tiny" => Some(alexnet_tiny()),
        "alexnet-tiny-faithful" => Some(alexnet_tiny_faithful()),
        "alexnet-micro" => Some(alexnet_micro()),
        _ => None,
    }
}

/// One row of the per-layer summary table (`tmg inspect --model`).
#[derive(Clone, Debug)]
pub struct LayerRow {
    pub name: String,
    /// Output channels (or feature width for FC layers).
    pub out_ch: usize,
    /// Output spatial extent; 0 for FC layers.
    pub out_hw: usize,
    pub params: u64,
    pub fwd_macs: u64,
    pub groups: usize,
    pub lrn: Option<LrnSpec>,
}

impl ArchDesc {
    /// Forward multiply-accumulates for one example.  Grouped convs do
    /// `cout x (cin/groups) x k^2` work per output pixel.
    pub fn forward_macs(&self) -> u64 {
        let mut macs = 0u64;
        let mut cin = self.in_channels;
        let mut hw = self.image_hw;
        for c in &self.convs {
            let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            macs +=
                (c.cout * (cin / c.groups) * c.kernel * c.kernel) as u64 * (out_hw * out_hw) as u64;
            hw = out_hw;
            if c.pool {
                hw = (hw - self.pool_window) / self.pool_stride + 1;
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        for &d in &self.fc_dims {
            macs += (feat * d) as u64;
            feat = d;
        }
        macs += (feat * self.num_classes) as u64;
        macs
    }

    /// Train-step MACs per example: fwd + bwd (~2x fwd) = 3x fwd.
    pub fn train_macs(&self) -> u64 {
        3 * self.forward_macs()
    }

    /// Parameter element count (weights + biases).  Grouped conv weights
    /// are `cout x (cin/groups) x k x k`.
    pub fn param_elements(&self) -> u64 {
        let mut n = 0u64;
        let mut cin = self.in_channels;
        let mut hw = self.image_hw;
        for c in &self.convs {
            n += (c.cout * (cin / c.groups) * c.kernel * c.kernel + c.cout) as u64;
            let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            hw = out_hw;
            if c.pool {
                hw = (hw - self.pool_window) / self.pool_stride + 1;
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        for &d in &self.fc_dims {
            n += (feat * d + d) as u64;
            feat = d;
        }
        n += (feat * self.num_classes + self.num_classes) as u64;
        n
    }

    /// Bytes of one Fig-2 exchange payload (params + momenta, f32).
    pub fn exchange_bytes(&self) -> u64 {
        self.param_elements() * 4 * 2
    }

    /// Per-layer breakdown (conv/lrn/pool/fc rows).  The param/MAC totals
    /// reconcile with `param_elements()` / `forward_macs()` by test and
    /// by the `tmg inspect --model` runtime assertion.
    pub fn layer_rows(&self) -> Vec<LayerRow> {
        let mut rows = Vec::new();
        let mut cin = self.in_channels;
        let mut hw = self.image_hw;
        for (i, c) in self.convs.iter().enumerate() {
            let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            let w = c.cout * (cin / c.groups) * c.kernel * c.kernel;
            rows.push(LayerRow {
                name: format!("conv{}", i + 1),
                out_ch: c.cout,
                out_hw,
                params: (w + c.cout) as u64,
                fwd_macs: w as u64 * (out_hw * out_hw) as u64,
                groups: c.groups,
                lrn: None,
            });
            hw = out_hw;
            if let Some(lrn) = c.lrn {
                rows.push(LayerRow {
                    name: format!("lrn{}", i + 1),
                    out_ch: c.cout,
                    out_hw: hw,
                    params: 0,
                    fwd_macs: 0,
                    groups: 1,
                    lrn: Some(lrn),
                });
            }
            if c.pool {
                hw = (hw - self.pool_window) / self.pool_stride + 1;
                rows.push(LayerRow {
                    name: format!("pool{}", i + 1),
                    out_ch: c.cout,
                    out_hw: hw,
                    params: 0,
                    fwd_macs: 0,
                    groups: 1,
                    lrn: None,
                });
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        for (j, &d) in self.fc_dims.iter().enumerate() {
            rows.push(LayerRow {
                name: format!("fc{}", j + 1),
                out_ch: d,
                out_hw: 0,
                params: (feat * d + d) as u64,
                fwd_macs: (feat * d) as u64,
                groups: 1,
                lrn: None,
            });
            feat = d;
        }
        rows.push(LayerRow {
            name: "softmax".to_string(),
            out_ch: self.num_classes,
            out_hw: 0,
            params: (feat * self.num_classes + self.num_classes) as u64,
            fwd_macs: (feat * self.num_classes) as u64,
            groups: 1,
            lrn: None,
        });
        rows
    }
}

/// Compute-cost scale factor from a measured (arch_a, batch_a) step to
/// a target (arch_b, batch_b) step.
pub fn scale_factor(from: &ArchDesc, batch_from: usize, to: &ArchDesc, batch_to: usize) -> f64 {
    (to.train_macs() as f64 * batch_to as f64) / (from.train_macs() as f64 * batch_from as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_params_near_60m() {
        // Krizhevsky et al. report ~60M parameters.
        let n = alexnet().param_elements();
        assert!((55_000_000..66_000_000).contains(&n), "{n}");
    }

    #[test]
    fn faithful_alexnet_params_exactly_canonical() {
        // conv1 34_944 + conv2(g2) 307_456 + conv3 885_120 + conv4(g2)
        // 663_936 + conv5(g2) 442_624 + fc1 37_752_832 + fc2 16_781_312
        // + softmax 4_097_000 = the canonical 60.97M.
        assert_eq!(alexnet().param_elements(), 60_965_224);
    }

    #[test]
    fn faithful_alexnet_structure_matches_paper() {
        let a = alexnet();
        let groups: Vec<usize> = a.convs.iter().map(|c| c.groups).collect();
        assert_eq!(groups, vec![1, 2, 1, 2, 2]);
        let lrn: Vec<bool> = a.convs.iter().map(|c| c.lrn.is_some()).collect();
        assert_eq!(lrn, vec![true, true, false, false, false]);
        let spec = a.convs[0].lrn.unwrap();
        assert_eq!(spec, LrnSpec::krizhevsky());
        assert_eq!((spec.radius, spec.bias, spec.alpha, spec.beta), (2, 2.0, 1e-4, 0.75));
    }

    #[test]
    fn alexnet_fwd_flops_near_700m_macs() {
        // Literature: ~0.7 GMACs (1.4 GFLOPs) per 227x227 forward pass.
        let m = alexnet().forward_macs();
        assert!((600_000_000..1_300_000_000).contains(&m), "{m}");
    }

    #[test]
    fn grouping_divides_macs_and_params() {
        // Same geometry with groups stripped must cost strictly more.
        let faithful = alexnet();
        let mut plain = faithful.clone();
        for c in &mut plain.convs {
            c.groups = 1;
        }
        assert!(plain.forward_macs() > faithful.forward_macs());
        assert!(plain.param_elements() > faithful.param_elements());
        // And the per-conv deltas are exactly the grouped halves.
        let f_rows = faithful.layer_rows();
        let p_rows = plain.layer_rows();
        for (f, p) in f_rows.iter().zip(&p_rows) {
            if f.groups == 2 {
                assert_eq!(p.fwd_macs, 2 * f.fwd_macs, "{}", f.name);
            }
        }
    }

    #[test]
    fn layer_rows_reconcile_with_totals() {
        for arch in
            [alexnet(), alexnet_tiny(), alexnet_tiny_faithful(), alexnet_micro()]
        {
            let rows = arch.layer_rows();
            let params: u64 = rows.iter().map(|r| r.params).sum();
            let macs: u64 = rows.iter().map(|r| r.fwd_macs).sum();
            assert_eq!(params, arch.param_elements(), "{}", arch.name);
            assert_eq!(macs, arch.forward_macs(), "{}", arch.name);
        }
    }

    #[test]
    fn tiny_faithful_is_cheaper_than_tiny() {
        // Grouping sheds weights/MACs; LRN adds none to the MAC model.
        assert!(alexnet_tiny_faithful().param_elements() < alexnet_tiny().param_elements());
        assert!(alexnet_tiny_faithful().forward_macs() < alexnet_tiny().forward_macs());
    }

    #[test]
    fn ordering_micro_tiny_full() {
        let micro = alexnet_micro().train_macs();
        let tiny = alexnet_tiny().train_macs();
        let full = alexnet().train_macs();
        assert!(micro < tiny && tiny < full);
    }

    #[test]
    fn scale_factor_linear_in_batch() {
        let a = alexnet_micro();
        let f1 = scale_factor(&a, 8, &a, 16);
        assert!((f1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lookup_by_name() {
        assert!(arch_by_name("alexnet").is_some());
        assert!(arch_by_name("alexnet_micro").is_some());
        assert!(arch_by_name("alexnet-tiny-faithful").is_some());
        assert!(arch_by_name("resnet").is_none());
        for name in known_arch_names() {
            assert!(arch_by_name(name).is_some(), "{name}");
        }
    }
}
