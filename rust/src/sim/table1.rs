//! Regenerate the paper's Table 1: "training time per 20 iterations"
//! over {parallel loading yes/no} x {backend} x {1,2 GPUs}, plus the
//! Caffe reference columns.
//!
//! Cost construction (DESIGN.md E1):
//!
//! - compute: measured micro-model step time per backend, scaled to
//!   AlexNet at the paper's batch (256 on 1 GPU, 128/GPU on 2) by the
//!   analytic MAC ratio, then by one global `testbed_speedup` constant
//!   (CPU testbed -> Titan-Black-class device).  The constant cancels
//!   in every ratio the paper's conclusions rest on.
//! - load: measured loader seconds/image scaled by decode area ratio
//!   (227^2 vs the measured corpus edge) times the batch.
//! - exchange: interconnect cost model (P2P, same switch) on AlexNet's
//!   params+momenta payload.
//!
//! The shape claims under test: parallel loading saves ~20-25%; 2 GPUs
//! ~1.6-1.8x over 1; cudnn_r2 < cudnn_r1 < convnet; our best config
//! lands near the refconv ("Caffe+cuDNN") comparator.

use crate::comm::cost::CommCostModel;
use crate::config::TransportKind;
use crate::error::{Error, Result};
use crate::sim::calibrate::CalibratedCosts;
use crate::sim::flops::{alexnet, alexnet_micro, scale_factor};
use crate::sim::pipeline::{simulate, PipelineParams};

/// Global testbed scale: how much faster the simulated accelerator is
/// than this CPU at the same arithmetic.  One constant for all cells,
/// anchored so the cudnn_r2 / 1-GPU / parallel-loading cell lands near
/// the paper's 32.76 s when driven by real calibration on the dev box
/// (a unit normalization: every *ratio* between cells is a genuine
/// prediction from measured kernel/loader/interconnect costs).
pub const DEFAULT_TESTBED_SPEEDUP: f64 = 550.0;

/// Options for the Table-1 run.
#[derive(Clone, Debug)]
pub struct Table1Options {
    pub costs: CalibratedCosts,
    pub testbed_speedup: f64,
    pub steps: usize,
    pub seed: u64,
    /// Override the per-image load cost (ms).  `None` uses the measured
    /// synthetic-corpus loader (fast raw reads); `Some(2.0)` models the
    /// paper's ImageNet pipeline, whose JPEG decode cost — recoverable
    /// from the paper's own serial-vs-parallel delta,
    /// (43.52-32.76)/20/256 ≈ 2.1 ms/image — dominated loading.
    pub load_ms_override: Option<f64>,
}

impl Table1Options {
    pub fn with_costs(costs: CalibratedCosts) -> Self {
        Table1Options {
            costs,
            testbed_speedup: DEFAULT_TESTBED_SPEEDUP,
            steps: 100,
            seed: 5,
            load_ms_override: None,
        }
    }
}

/// One cell of the table.
#[derive(Clone, Debug)]
pub struct Table1Cell {
    pub backend: String,
    pub gpus: usize,
    pub parallel_loading: bool,
    pub per20_s: f64,
}

fn compute_cost(opts: &Table1Options, backend: &str, batch: usize) -> Result<f64> {
    // Absolute scale: the measured cudnn_r2 step, MAC-scaled to AlexNet
    // at `batch` and unit-normalized by the testbed constant.
    let anchor_s = opts
        .costs
        .step_s("cudnn_r2")
        .or_else(|| opts.costs.step_s(backend))
        .ok_or_else(|| Error::msg("cudnn_r2 missing from calibration"))?;
    let factor = scale_factor(&alexnet_micro(), opts.costs.micro_batch, &alexnet(), batch);
    let anchored = anchor_s * factor / opts.testbed_speedup;
    if backend == "refconv" {
        // The comparator engine is measured directly (it is a different
        // implementation, not a schedule variant).
        let micro_s = opts.costs.step_s("refconv").unwrap_or(anchor_s);
        return Ok(micro_s * factor / opts.testbed_speedup);
    }
    // Backend ordering: structural roofline ratios of the three GEMM
    // schedules on the target device (sim::backend_model) — interpret-
    // mode CPU timings cannot rank accelerator kernels (EXPERIMENTS.md
    // E1 caveat).
    let ratios = crate::sim::backend_model::backend_ratios(batch);
    let ratio = ratios
        .iter()
        .find(|(name, _)| *name == backend)
        .map(|(_, r)| *r)
        .ok_or_else(|| Error::msg(format!("backend {backend:?} not a known schedule")))?;
    Ok(anchored * ratio)
}

fn load_cost(opts: &Table1Options, batch: usize) -> f64 {
    if let Some(ms) = opts.load_ms_override {
        return ms * 1e-3 * batch as f64;
    }
    // Decode/preprocess cost scales with pixel area; ImageNet-JPEG
    // decode vs our synthetic read is NOT equivalent (raw u8 reads are
    // ~10x cheaper) — see `load_ms_override` for the decode-class mode.
    let area_ratio = (227.0 * 227.0) / (opts.costs.load_hw as f64 * opts.costs.load_hw as f64);
    opts.costs.load_s_per_image * area_ratio * batch as f64
}

fn exchange_cost(opts: &Table1Options) -> f64 {
    // Rescale the PCIe model so its host hop matches measured memcpy
    // bandwidth (both hops of a staged copy are host memcpys here).
    let model = CommCostModel::default();
    let bytes = alexnet().exchange_bytes() as usize;
    let _ = opts;
    model.exchange_round_time(TransportKind::P2p, bytes)
}

/// The Table-1 backends, in the paper's column order.
pub const PAPER_BACKENDS: [&str; 3] = ["convnet", "cudnn_r1", "cudnn_r2"];

/// Build all cells: 3 backends x {2,1 GPU} x {parallel, serial}, plus
/// Caffe references (parallel loading only, as published).
pub fn table1(opts: &Table1Options) -> Result<Vec<Table1Cell>> {
    let mut cells = Vec::new();
    for parallel in [true, false] {
        for backend in PAPER_BACKENDS {
            for gpus in [2usize, 1] {
                let batch = if gpus == 2 { 128 } else { 256 };
                let p = PipelineParams {
                    workers: gpus,
                    compute_s: compute_cost(opts, backend, batch)?,
                    load_s: load_cost(opts, batch),
                    exchange_s: if gpus > 1 { exchange_cost(opts) } else { 0.0 },
                    period: 1,
                    parallel_loading: parallel,
                    jitter: 0.02,
                    seed: opts.seed,
                };
                let out = simulate(&p, opts.steps);
                cells.push(Table1Cell {
                    backend: backend.to_string(),
                    gpus,
                    parallel_loading: parallel,
                    per20_s: out.mean_per20(),
                });
            }
        }
    }
    // Caffe reference columns: an independently-optimized conv engine
    // (XLA's lax.conv) on 1 GPU with its own prefetching pipeline.
    let caffe_step = compute_cost(opts, "refconv", 256)?;
    let p = PipelineParams {
        workers: 1,
        compute_s: caffe_step,
        load_s: load_cost(opts, 256),
        exchange_s: 0.0,
        period: 1,
        parallel_loading: true,
        jitter: 0.02,
        seed: opts.seed,
    };
    cells.push(Table1Cell {
        backend: "caffe".into(),
        gpus: 1,
        parallel_loading: true,
        per20_s: simulate(&p, opts.steps).mean_per20(),
    });
    // "Caffe with cuDNN": the same engine with the cuDNN-R2 kernel
    // speedup applied (the paper's column is Caffe swapping its convs
    // for cuDNN) — modeled as refconv scaled by our measured R2:R1
    // kernel ratio.
    let r2 = opts.costs.step_s("cudnn_r2").unwrap_or(1.0);
    let r1 = opts.costs.step_s("cudnn_r1").unwrap_or(1.0);
    let p = PipelineParams {
        compute_s: caffe_step * (r2 / r1).min(1.0),
        ..p
    };
    cells.push(Table1Cell {
        backend: "caffe_cudnn".into(),
        gpus: 1,
        parallel_loading: true,
        per20_s: simulate(&p, opts.steps).mean_per20(),
    });
    Ok(cells)
}

/// Render the cells in the paper's layout.
pub fn render(cells: &[Table1Cell]) -> String {
    let get = |backend: &str, gpus: usize, par: bool| -> f64 {
        cells
            .iter()
            .find(|c| c.backend == backend && c.gpus == gpus && c.parallel_loading == par)
            .map(|c| c.per20_s)
            .unwrap_or(f64::NAN)
    };
    let mut s = String::new();
    s.push_str("Table 1: training time per 20 iterations (sec, simulated testbed)\n");
    s.push_str(
        "loading | convnet 2-GPU | 1-GPU | cudnn_r1 2-GPU | 1-GPU | cudnn_r2 2-GPU | 1-GPU | caffe | caffe+cudnn\n",
    );
    for par in [true, false] {
        let tag = if par { "Yes    " } else { "No     " };
        s.push_str(tag);
        for backend in PAPER_BACKENDS {
            s.push_str(&format!(
                " | {:>12.2} | {:>5.2}",
                get(backend, 2, par),
                get(backend, 1, par)
            ));
        }
        if par {
            s.push_str(&format!(
                " | {:>5.2} | {:>11.2}",
                get("caffe", 1, true),
                get("caffe_cudnn", 1, true)
            ));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run() -> Vec<Table1Cell> {
        let opts = Table1Options::with_costs(CalibratedCosts::canned());
        table1(&opts).unwrap()
    }

    fn cell(cells: &[Table1Cell], backend: &str, gpus: usize, par: bool) -> f64 {
        cells
            .iter()
            .find(|c| c.backend == backend && c.gpus == gpus && c.parallel_loading == par)
            .unwrap()
            .per20_s
    }

    #[test]
    fn paper_shape_holds() {
        let cells = run();
        // (1) parallel loading is faster everywhere.
        for backend in PAPER_BACKENDS {
            for gpus in [1, 2] {
                assert!(
                    cell(&cells, backend, gpus, true) < cell(&cells, backend, gpus, false),
                    "{backend}/{gpus}gpu: parallel loading must win"
                );
            }
        }
        // (2) 2 GPUs beat 1 GPU by 1.3-2.0x.
        for backend in PAPER_BACKENDS {
            let r = cell(&cells, backend, 1, true) / cell(&cells, backend, 2, true);
            assert!((1.3..2.05).contains(&r), "{backend} speedup {r}");
        }
        // (3) backend ordering cudnn_r2 <= cudnn_r1 <= convnet.
        for gpus in [1, 2] {
            let c = cell(&cells, "convnet", gpus, true);
            let r1 = cell(&cells, "cudnn_r1", gpus, true);
            let r2 = cell(&cells, "cudnn_r2", gpus, true);
            assert!(r2 <= r1 && r1 <= c, "ordering {c} {r1} {r2}");
        }
        // (4) best config comparable to caffe+cudnn (paper's headline).
        let best = cell(&cells, "cudnn_r2", 2, true);
        let caffe_cudnn = cell(&cells, "caffe_cudnn", 1, true);
        let ratio = best / caffe_cudnn;
        assert!((0.2..5.0).contains(&ratio), "best vs caffe+cudnn ratio {ratio}");
    }

    #[test]
    fn render_contains_rows() {
        let cells = run();
        let s = render(&cells);
        assert!(s.contains("Yes"));
        assert!(s.contains("No"));
        assert!(s.contains("caffe"));
    }
}
