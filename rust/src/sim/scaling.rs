//! E5: the N-GPU scaling study the paper defers (§4.2/§4.4).
//!
//! Simulates N in {1,2,4,8} replicas of AlexNet data parallelism with
//! two exchange algorithms and two PCIe topologies:
//!
//! - pairwise (the paper's scheme, N=2 only) vs chunked ring
//!   all-reduce (Krizhevsky 2014's recommendation);
//! - all GPUs under one switch (P2P everywhere) vs split across two
//!   switches (ring links crossing the root complex fall back to
//!   host-staged copies — the §4.4 penalty).

use crate::comm::cost::CommCostModel;
use crate::config::TransportKind;
use crate::error::Result;
use crate::interconnect::topology::TopologyBuilder;
use crate::sim::calibrate::CalibratedCosts;
use crate::sim::flops::{alexnet, alexnet_micro, scale_factor};
use crate::sim::pipeline::{simulate, PipelineParams};
use crate::sim::table1::{Table1Options, DEFAULT_TESTBED_SPEEDUP};

/// One row of the scaling table.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub workers: usize,
    pub topology: &'static str,
    pub algorithm: &'static str,
    pub exchange_s: f64,
    pub per20_s: f64,
    /// Throughput speedup vs the 1-GPU baseline.
    pub speedup: f64,
}

/// Ring exchange time on a given topology: 2(N-1) chunk steps, each
/// paced by the slowest link in the ring.
fn ring_exchange_time(
    model: &CommCostModel,
    workers: usize,
    cross_switch_links: usize,
    bytes: usize,
) -> f64 {
    if workers < 2 {
        return 0.0;
    }
    let chunk = bytes / workers;
    let p2p_t = model.transfer_time(TransportKind::P2p, chunk);
    let host_t = model.transfer_time(TransportKind::HostStaged, chunk);
    let slowest = if cross_switch_links > 0 { host_t } else { p2p_t };
    let steps = 2 * (workers - 1);
    steps as f64 * slowest + bytes as f64 / 8.0e9 // + average pass
}

/// Pairwise exchange time (N=2): one payload transfer + average.
fn pairwise_exchange_time(model: &CommCostModel, p2p: bool, bytes: usize) -> f64 {
    let kind = if p2p { TransportKind::P2p } else { TransportKind::HostStaged };
    model.exchange_round_time(kind, bytes)
}

/// Run the scaling sweep with a per-GPU batch of 128 (the paper's
/// 2-GPU setting held fixed — weak scaling).
pub fn scaling_study(costs: &CalibratedCosts, steps: usize) -> Result<Vec<ScalingRow>> {
    let opts = Table1Options::with_costs(costs.clone());
    let batch = 128usize;
    let micro_s = costs.step_s("cudnn_r2").unwrap_or(0.04);
    let compute_s = micro_s
        * scale_factor(&alexnet_micro(), costs.micro_batch, &alexnet(), batch)
        / DEFAULT_TESTBED_SPEEDUP;
    let area = (227.0f64 * 227.0) / (costs.load_hw as f64 * costs.load_hw as f64);
    let load_s = costs.load_s_per_image * area * batch as f64;
    let bytes = alexnet().exchange_bytes() as usize;
    let model = CommCostModel::default();

    let mut rows = Vec::new();
    let baseline = {
        let p = PipelineParams {
            workers: 1,
            compute_s,
            load_s,
            exchange_s: 0.0,
            period: 1,
            parallel_loading: true,
            jitter: 0.0,
            seed: opts.seed,
        };
        simulate(&p, steps).mean_per20()
    };
    rows.push(ScalingRow {
        workers: 1,
        topology: "single-switch",
        algorithm: "none",
        exchange_s: 0.0,
        per20_s: baseline,
        speedup: 1.0,
    });

    for &n in &[2usize, 4, 8] {
        for (topology, cross_links) in [("single-switch", 0usize), ("dual-switch", 2usize)] {
            // Sanity: the topology is constructible.
            let _topo = if topology == "single-switch" {
                TopologyBuilder::new().switch_with(n).build()?
            } else {
                TopologyBuilder::new().switch_with(n / 2).switch_with(n - n / 2).build()?
            };
            let algorithms: Vec<(&'static str, f64)> = if n == 2 {
                vec![
                    ("pairwise", pairwise_exchange_time(&model, cross_links == 0, bytes)),
                    ("ring", ring_exchange_time(&model, n, cross_links, bytes)),
                ]
            } else {
                vec![("ring", ring_exchange_time(&model, n, cross_links, bytes))]
            };
            for (algorithm, exchange_s) in algorithms {
                let p = PipelineParams {
                    workers: n,
                    compute_s,
                    load_s,
                    exchange_s,
                    period: 1,
                    parallel_loading: true,
                    jitter: 0.0,
                    seed: opts.seed,
                };
                let per20 = simulate(&p, steps).mean_per20();
                // Throughput: images per unit time relative to baseline.
                let speedup = (baseline / per20) * n as f64;
                rows.push(ScalingRow {
                    workers: n,
                    topology,
                    algorithm,
                    exchange_s,
                    per20_s: per20,
                    speedup,
                });
            }
        }
    }
    Ok(rows)
}

/// ASCII-render the rows.
pub fn render(rows: &[ScalingRow]) -> String {
    let mut s = String::from("N  topology       algo      exchange(s)  s/20it   speedup\n");
    for r in rows {
        s.push_str(&format!(
            "{:<2} {:<14} {:<9} {:>10.4}  {:>7.2}  {:>6.2}x\n",
            r.workers, r.topology, r.algorithm, r.exchange_s, r.per20_s, r.speedup
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_improves_with_n_on_single_switch() {
        let rows = scaling_study(&CalibratedCosts::canned(), 40).unwrap();
        let sp = |n: usize| {
            rows.iter()
                .find(|r| r.workers == n && r.topology == "single-switch" && r.algorithm != "pairwise" || r.workers == n && n == 1)
                .map(|r| r.speedup)
                .unwrap()
        };
        assert!(sp(2) > 1.4);
        assert!(sp(4) > sp(2));
        assert!(sp(8) > sp(4));
    }

    #[test]
    fn cross_switch_hurts() {
        let rows = scaling_study(&CalibratedCosts::canned(), 40).unwrap();
        for n in [2usize, 4, 8] {
            let single = rows
                .iter()
                .find(|r| r.workers == n && r.topology == "single-switch" && r.algorithm == "ring")
                .unwrap();
            let dual = rows
                .iter()
                .find(|r| r.workers == n && r.topology == "dual-switch" && r.algorithm == "ring")
                .unwrap();
            assert!(
                dual.per20_s >= single.per20_s,
                "n={n}: dual {} vs single {}",
                dual.per20_s,
                single.per20_s
            );
        }
    }

    #[test]
    fn ring_time_decreases_per_byte_with_n() {
        let m = CommCostModel::default();
        let b = 64 << 20;
        let t2 = ring_exchange_time(&m, 2, 0, b);
        let t8 = ring_exchange_time(&m, 8, 0, b);
        // Ring total bytes moved per rank: 2(N-1)/N * B — grows slowly,
        // so per-round time should be within ~2x across N.
        assert!(t8 < 2.0 * t2, "t2 {t2} t8 {t8}");
    }

    #[test]
    fn render_has_all_rows() {
        let rows = scaling_study(&CalibratedCosts::canned(), 20).unwrap();
        let s = render(&rows);
        assert!(s.contains("dual-switch"));
        assert!(s.contains("pairwise"));
    }
}
