//! Structural (roofline) model of the three GEMM schedules on the
//! target accelerator — the DESIGN.md §Hardware-Adaptation estimate.
//!
//! Interpret-mode CPU timings cannot rank TPU/GPU kernel schedules: at
//! micro scale XLA fuses the naive full-K dot into one efficient CPU
//! GEMM while the K-tiled schedules pay interpreter bookkeeping, so a
//! raw CPU calibration *inverts* the paper's backend ordering (see
//! EXPERIMENTS.md E1 caveat).  What distinguishes the schedules on the
//! real device is structure: on-chip memory footprint, bytes staged per
//! MAC, launch count, and epilogue fusion.  This module prices those
//! structural terms for AlexNet's im2col GEMMs on Titan-Black-class
//! constants and yields the backend time *ratios* the Table-1 simulator
//! combines with measured absolute scale.

use crate::sim::flops::{alexnet, ArchDesc};

/// Accelerator constants (Titan-Black class, 2014).
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Peak MAC rate (MAC/s).  Titan Black: ~5.1 TFLOP/s = 2.55e12 MAC/s.
    pub mac_rate: f64,
    /// Device memory bandwidth (bytes/s).  GDDR5: ~336 GB/s.
    pub mem_bw: f64,
    /// On-chip staging budget per block (bytes).  Shared-mem/VMEM class.
    pub onchip_bytes: usize,
    /// Fixed cost per kernel invocation (one per GEMM call).
    pub launch_s: f64,
    /// Cost per grid trip (a Pallas grid step is a loop iteration with
    /// a block-spec address swap, not a kernel launch).
    pub grid_trip_s: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            mac_rate: 2.55e12,
            mem_bw: 336e9,
            onchip_bytes: 16 << 20, // VMEM-class budget per DESIGN.md
            launch_s: 6e-6,
            grid_trip_s: 1e-7,
        }
    }
}

/// One GEMM in the network: [M x K] @ [K x N].
#[derive(Clone, Copy, Debug)]
pub struct Gemm {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Block schedule mirroring python/compile/kernels/matmul_pallas.py.
#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub name: &'static str,
    pub bm: usize,
    pub bn: usize,
    /// None = full-K panels (the convnet schedule).
    pub bk: Option<usize>,
    /// Whether bias+ReLU is fused into the GEMM epilogue.
    pub fused_epilogue: bool,
    /// Per-shape tile autotuning (cuDNN-R2's heuristic dispatch): pick
    /// the better of the narrow/wide N tiles per GEMM.
    pub autotune: bool,
}

pub const SCHEDULES: [Schedule; 3] = [
    Schedule { name: "convnet", bm: 128, bn: 128, bk: None, fused_epilogue: false, autotune: false },
    Schedule { name: "cudnn_r1", bm: 128, bn: 128, bk: Some(128), fused_epilogue: false, autotune: false },
    Schedule { name: "cudnn_r2", bm: 128, bn: 256, bk: Some(128), fused_epilogue: true, autotune: true },
];

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// The im2col GEMMs of one training step (fwd; bwd ≈ 2x fwd traffic
/// through the same schedule — a uniform factor that cancels in ratios
/// but is included for absolute sanity).
pub fn arch_gemms(arch: &ArchDesc, batch: usize) -> Vec<Gemm> {
    let mut out = Vec::new();
    let mut cin = arch.in_channels;
    let mut hw = arch.image_hw;
    for c in &arch.convs {
        let out_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
        out.push(Gemm {
            m: batch * out_hw * out_hw,
            k: cin * c.kernel * c.kernel,
            n: c.cout,
        });
        hw = out_hw;
        if c.pool {
            hw = (hw - arch.pool_window) / arch.pool_stride + 1;
        }
        cin = c.cout;
    }
    let mut feat = cin * hw * hw;
    for &d in &arch.fc_dims {
        out.push(Gemm { m: batch, k: feat, n: d });
        feat = d;
    }
    out.push(Gemm { m: batch, k: feat, n: arch.num_classes });
    out
}

/// Effective block shape after shrinking to the on-chip budget
/// (the convnet schedule's full-K panels may not fit; it must halve
/// its tiles, multiplying panel re-reads — its structural penalty).
fn effective_blocks(s: &Schedule, g: &Gemm, dev: &DeviceModel) -> (usize, usize, usize) {
    let bk = s.bk.unwrap_or(g.k.max(1));
    let mut bm = s.bm;
    let mut bn = s.bn;
    // f32 staging: A block + B block + f32 accumulator.
    let fits = |bm: usize, bn: usize| (bm * bk + bk * bn + bm * bn) * 4 <= dev.onchip_bytes;
    while !fits(bm, bn) && (bm > 8 || bn > 8) {
        if bm >= bn && bm > 8 {
            bm /= 2;
        } else if bn > 8 {
            bn /= 2;
        } else {
            break;
        }
    }
    (bm, bn, bk)
}

/// Roofline time of one GEMM under a schedule (autotuning schedules
/// pick the better of their narrow/wide N tiles per shape).
pub fn gemm_time(s: &Schedule, g: &Gemm, dev: &DeviceModel) -> f64 {
    if s.autotune {
        let narrow = Schedule { bn: 128, autotune: false, ..*s };
        let wide = Schedule { bn: 256, autotune: false, ..*s };
        return gemm_time(&narrow, g, dev).min(gemm_time(&wide, g, dev));
    }
    gemm_time_fixed(s, g, dev)
}

fn gemm_time_fixed(s: &Schedule, g: &Gemm, dev: &DeviceModel) -> f64 {
    let (bm, bn, bk) = effective_blocks(s, g, dev);
    let (gm, gn, gk) = (ceil_div(g.m, bm), ceil_div(g.n, bn), ceil_div(g.k, bk));
    // MACs issued include padding waste (MXU consumes whole tiles).
    let macs_issued = (gm * bm) as f64 * (gn * bn) as f64 * (gk * bk) as f64;
    // HBM traffic: A panels re-read once per N block, B panels once per
    // M block, output written once (+read+rewritten by an unfused
    // bias+ReLU epilogue pass).
    let a_bytes = (gn * gm * gk) as f64 * (bm * bk) as f64 * 4.0;
    let b_bytes = (gm * gn * gk) as f64 * (bk * bn) as f64 * 4.0;
    let mut out_bytes = (g.m * g.n) as f64 * 4.0;
    if !s.fused_epilogue {
        out_bytes += (g.m * g.n) as f64 * 8.0; // separate epilogue: read+write
    }
    let compute_t = macs_issued / dev.mac_rate;
    let mem_t = (a_bytes + b_bytes + out_bytes) / dev.mem_bw;
    let grid_trips = (gm * gn) as f64 * if s.bk.is_some() { gk as f64 } else { 1.0 };
    // K-tiled schedules double-buffer: HBM traffic overlaps compute
    // (roofline max).  The full-K-panel schedule fills the staging
    // budget with one panel pair, leaving no room to prefetch — memory
    // time serializes with compute (cuda-convnet's structural penalty).
    let body = if s.bk.is_some() {
        compute_t.max(mem_t)
    } else {
        compute_t + mem_t
    };
    // An unfused epilogue is a second kernel launch per GEMM.
    let kernel_launches = if s.fused_epilogue { 1.0 } else { 2.0 };
    body + kernel_launches * dev.launch_s + grid_trips * dev.grid_trip_s
}

/// Total fwd+bwd GEMM time of one train step under a schedule
/// (bwd-data + bwd-filter re-run the GEMM engine: ~3x fwd volume).
pub fn step_time(s: &Schedule, arch: &ArchDesc, batch: usize, dev: &DeviceModel) -> f64 {
    3.0 * arch_gemms(arch, batch)
        .iter()
        .map(|g| gemm_time(s, g, dev))
        .sum::<f64>()
}

/// Backend time ratios relative to `cudnn_r2` for AlexNet at `batch`.
/// These carry the paper's backend ordering into the Table-1 simulator;
/// measured CPU costs provide the absolute anchor.
pub fn backend_ratios(batch: usize) -> Vec<(&'static str, f64)> {
    let dev = DeviceModel::default();
    let arch = alexnet();
    let base = step_time(&SCHEDULES[2], &arch, batch, &dev);
    SCHEDULES
        .iter()
        .map(|s| (s.name, step_time(s, &arch, batch, &dev) / base))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_backend_ordering_holds_structurally() {
        // cudnn_r2 <= cudnn_r1 <= convnet, as in Table 1.
        for batch in [128usize, 256] {
            let r = backend_ratios(batch);
            let get = |n: &str| r.iter().find(|(name, _)| *name == n).unwrap().1;
            assert!(get("cudnn_r2") <= get("cudnn_r1"), "{r:?}");
            assert!(get("cudnn_r1") <= get("convnet"), "{r:?}");
            // And the spread is in the paper's band (R2 is ~15-20%
            // faster than convnet, not 10x): 23.39/19.72 = 1.19.
            let spread = get("convnet") / get("cudnn_r2");
            assert!((1.02..2.0).contains(&spread), "spread {spread}");
        }
    }

    #[test]
    fn convnet_pays_serial_memory_time() {
        // AlexNet conv2-shaped GEMM: the full-K schedule serializes
        // HBM traffic with compute, the K-tiled ones overlap it.
        let dev = DeviceModel::default();
        let g = Gemm { m: 186_624, k: 2_400, n: 256 };
        let naive = gemm_time(&SCHEDULES[0], &g, &dev);
        let tiled = gemm_time(&SCHEDULES[1], &g, &dev);
        assert!(naive > tiled, "naive {naive} vs tiled {tiled}");
    }

    #[test]
    fn huge_k_panels_do_shrink() {
        // A pathological K forces even the full-K schedule to shrink
        // its panels to the on-chip budget.
        let dev = DeviceModel::default();
        let g = Gemm { m: 4_096, k: 200_000, n: 4_096 };
        let (bm, bn, _) = effective_blocks(&SCHEDULES[0], &g, &dev);
        assert!(bm < 128 || bn < 128, "got {bm}x{bn}");
    }

    #[test]
    fn gemm_list_matches_layer_count() {
        let arch = alexnet();
        let gemms = arch_gemms(&arch, 128);
        assert_eq!(gemms.len(), 5 + 2 + 1);
        // conv1: 55x55 output, K = 3*11*11.
        assert_eq!(gemms[0].k, 363);
        assert_eq!(gemms[0].m, 128 * 55 * 55);
        assert_eq!(gemms[0].n, 96);
    }

    #[test]
    fn times_scale_with_batch() {
        let dev = DeviceModel::default();
        let arch = alexnet();
        let t128 = step_time(&SCHEDULES[2], &arch, 128, &dev);
        let t256 = step_time(&SCHEDULES[2], &arch, 256, &dev);
        let ratio = t256 / t128;
        assert!((1.7..2.3).contains(&ratio), "batch scaling {ratio}");
    }
}
