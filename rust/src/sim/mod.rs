//! Calibrated discrete-event simulation — regenerates the paper's
//! timing results on hardware we don't have (DESIGN.md substitution).
//!
//! The chain: [`calibrate`] measures *real* costs on this machine
//! (per-backend compiled-step time, loader time per image, memcpy
//! bandwidth); [`flops`] scales compute costs analytically between
//! model sizes/batches; [`pipeline`] plays the Fig-1/Fig-2 schedule
//! step by step; [`table1`] assembles the paper's Table 1 and
//! [`scaling`] the §4.4 N-GPU study.

pub mod backend_model;
pub mod calibrate;
pub mod flops;
pub mod pipeline;
pub mod scaling;
pub mod table1;

pub use calibrate::{CalibratedCosts, Calibration};
pub use pipeline::{PipelineParams, SimOutcome};
pub use table1::{table1, Table1Cell, Table1Options};
