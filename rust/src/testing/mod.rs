//! Mini property-testing framework (offline crate set has no proptest).
//!
//! Seeded, reproducible random-case runner with optional greedy
//! shrinking.  Used by the invariant tests on the coordinator
//! substrates: averaging, exchange protocol, sampler partitioning,
//! topology routing, JSON/TOML parsers.
//!
//! ```no_run
//! use theano_mgpu::testing::{props, Gen};
//! props("sum is commutative", 100, |g| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     ((a + b) - (b + a)).abs() < 1e-6
//! });
//! ```

use crate::util::Pcg32;

/// Random-value source handed to each property case.
pub struct Gen {
    rng: Pcg32,
}

impl Gen {
    pub fn new(seed: u64, case: u64) -> Self {
        Gen { rng: Pcg32::new(seed, case.wrapping_mul(2) + 1) }
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A plausible tensor shape with bounded element count.
    pub fn shape(&mut self, max_rank: usize, max_elems: usize) -> Vec<usize> {
        let rank = self.usize_in(1, max_rank.max(1));
        let mut dims = Vec::with_capacity(rank);
        let mut elems = 1usize;
        for _ in 0..rank {
            let cap = (max_elems / elems.max(1)).max(1).min(16);
            let d = self.usize_in(1, cap);
            elems *= d;
            dims.push(d);
        }
        dims
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Environment knob: TMG_PROP_SEED overrides the base seed so a CI
/// failure can be replayed exactly.
fn base_seed() -> u64 {
    std::env::var("TMG_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEFA_17_5EED)
}

/// Run `cases` random cases of `prop`; panics with the failing case id
/// and seed on the first counterexample.
pub fn props(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> bool) {
    let seed = base_seed();
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if !prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case} \
                 (replay with TMG_PROP_SEED={seed})"
            );
        }
    }
}

/// Like [`props`] but the property returns a descriptive error.
pub fn props_err(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let seed = base_seed();
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case}: {msg} \
                 (replay with TMG_PROP_SEED={seed})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_respected() {
        props("usize_in bounds", 200, |g| {
            let v = g.usize_in(3, 9);
            (3..=9).contains(&v)
        });
        props("f32_in bounds", 200, |g| {
            let v = g.f32_in(-2.0, 2.0);
            (-2.0..=2.0).contains(&v)
        });
    }

    #[test]
    fn shapes_bounded() {
        props("shape elems bounded", 200, |g| {
            let s = g.shape(4, 256);
            let n: usize = s.iter().product();
            !s.is_empty() && n <= 256 && s.iter().all(|&d| d >= 1)
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        props("always false", 5, |_| false);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(9, 3);
        let mut b = Gen::new(9, 3);
        for _ in 0..50 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }
}
