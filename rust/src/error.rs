//! Crate-wide error type.
//!
//! Hand-rolled enum (the offline crate set has no `thiserror`) so every
//! layer (IO, manifest parsing, PJRT, protocol violations) surfaces
//! through one `Result` alias without stringly-typed loss of
//! provenance.

use std::fmt;
use std::path::PathBuf;

/// Unified error for all `theano-mgpu` operations.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure, annotated with the path when known.
    Io { path: PathBuf, source: std::io::Error },

    /// Raw I/O failure with no path context.
    RawIo(std::io::Error),

    /// XLA / PJRT failure (compile, execute, transfer).
    Xla(String),

    /// artifacts/manifest.json was malformed or inconsistent.
    Manifest(String),

    /// JSON syntax error at byte offset.
    Json { offset: usize, msg: String },

    /// Config file (TOML subset) syntax/validation error.
    Config(String),

    /// Shard file corruption (bad magic / CRC / truncation).
    Shard { path: PathBuf, msg: String },

    /// Shape mismatch between host tensors / literals / specs.
    Shape(String),

    /// Exchange/collective protocol violation (the Fig-2 state machine
    /// and its N-worker ring generalization).
    Protocol(String),

    /// Interconnect topology rejected a requested route.
    Topology(String),

    /// A peer missed an I/O deadline (dead or stalled process).
    Timeout(String),

    /// Checkpoint serialization problems.
    Checkpoint(String),

    /// Anything the CLI needs to report verbatim.
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => write!(f, "io error on {path:?}: {source}"),
            Error::RawIo(source) => write!(f, "{source}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Shard { path, msg } => write!(f, "shard {path:?}: {msg}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Topology(m) => write!(f, "topology: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint: {m}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            Error::RawIo(source) => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::RawIo(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Attach a path to a raw IO error.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Free-form error helper.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_carry_provenance() {
        assert_eq!(format!("{}", Error::Shape("a vs b".into())), "shape mismatch: a vs b");
        assert_eq!(format!("{}", Error::msg("plain")), "plain");
        let e = Error::Json { offset: 7, msg: "bad".into() };
        assert_eq!(format!("{e}"), "json parse error at byte 7: bad");
    }

    #[test]
    fn io_errors_keep_their_source() {
        use std::error::Error as _;
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        assert!(format!("{e}").contains("/tmp/x"));
        assert!(e.source().is_some());
    }
}
