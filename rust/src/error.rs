//! Crate-wide error type.
//!
//! Thin `thiserror` enum so every layer (IO, manifest parsing, PJRT,
//! protocol violations) surfaces through one `Result` alias without
//! stringly-typed loss of provenance.

use std::path::PathBuf;

/// Unified error for all `theano-mgpu` operations.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Underlying I/O failure, annotated with the path when known.
    #[error("io error on {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    /// Raw I/O failure with no path context.
    #[error(transparent)]
    RawIo(#[from] std::io::Error),

    /// XLA / PJRT failure (compile, execute, transfer).
    #[error("xla: {0}")]
    Xla(String),

    /// artifacts/manifest.json was malformed or inconsistent.
    #[error("manifest: {0}")]
    Manifest(String),

    /// JSON syntax error at byte offset.
    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Config file (TOML subset) syntax/validation error.
    #[error("config: {0}")]
    Config(String),

    /// Shard file corruption (bad magic / CRC / truncation).
    #[error("shard {path:?}: {msg}")]
    Shard { path: PathBuf, msg: String },

    /// Shape mismatch between host tensors / literals / specs.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Exchange/barrier protocol violation (the Fig-2 state machine).
    #[error("protocol: {0}")]
    Protocol(String),

    /// Interconnect topology rejected a requested route.
    #[error("topology: {0}")]
    Topology(String),

    /// Checkpoint serialization problems.
    #[error("checkpoint: {0}")]
    Checkpoint(String),

    /// Anything the CLI needs to report verbatim.
    #[error("{0}")]
    Msg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Attach a path to a raw IO error.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Free-form error helper.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
