//! The leader: spawns workers, wires the collective fabric, aggregates
//! metrics, evaluates and checkpoints.
//!
//! Topology-aware transport selection generalizes §4.4 to any worker
//! count: every ring hop `i -> (i+1) % N` is checked against the PCIe
//! tree independently, and a P2P request silently falls back to
//! host-staged copies on hops whose endpoints sit on different
//! switches — exactly what the hardware would force.  Same-switch hops
//! keep the fast path even when other hops are downgraded.

use std::sync::mpsc::channel;

use crate::comm::collective::{build_fabric, CollectiveStats};
use crate::config::{TrainConfig, TransportKind};
use crate::coordinator::eval::{evaluate, EvalResult};
use crate::coordinator::worker::{run_worker, StepRecord, WorkerSpec};
use crate::data::loader::LoaderStats;
use crate::error::{Error, Result};
use crate::interconnect::topology::PcieTopology;
use crate::metrics::{CsvWriter, ThroughputMeter};
use crate::util::Timer;

/// One closed 20-iteration window (Table 1's unit).
#[derive(Clone, Copy, Debug)]
pub struct WindowRecord {
    pub end_step: usize,
    pub seconds: f64,
    pub images_per_sec: f64,
    pub mean_loss: f32,
}

/// Aggregate training outcome.
#[derive(Debug)]
pub struct TrainSummary {
    pub steps: usize,
    pub workers: usize,
    pub wall_seconds: f64,
    pub windows: Vec<WindowRecord>,
    pub losses: Vec<f32>,
    pub loader: Vec<LoaderStats>,
    pub exchange_rounds: u64,
    pub exchange_seconds: f64,
    /// Per-phase collective timing (flatten/transfer/average), seconds
    /// averaged across workers — the Table-1/Fig-2 bench breakdown for
    /// any N.
    pub collective: CollectiveStats,
    pub compute_seconds: f64,
    /// Replica divergence after the final step.  `None` for a single
    /// worker (no peer to compare).  When replicas are supposed to be
    /// bit-synchronized (period 1 and momenta included) this is the
    /// full-state Fig-2 invariant; otherwise it is the params-only
    /// drift metric (momenta legitimately differ there).
    pub final_divergence: Option<f32>,
    pub eval: Option<EvalResult>,
    /// Mean seconds per 20 iterations (the paper's headline unit).
    pub secs_per_20_iters: f64,
}

fn cluster_topology(cfg: &TrainConfig) -> PcieTopology {
    PcieTopology {
        switches: cfg.cluster.switch_of_worker.iter().max().unwrap_or(&0) + 1,
        switch_of_device: cfg.cluster.switch_of_worker.clone(),
    }
}

/// Per-hop effective transports for the ring `i -> (i+1) % N` (§4.4
/// rule applied to every hop): a P2P request is downgraded to
/// host-staged on cross-switch hops; other kinds pass through.  Empty
/// for a single worker.
pub fn effective_hop_transports(cfg: &TrainConfig) -> Vec<TransportKind> {
    let n = cfg.cluster.workers;
    if n < 2 {
        return Vec::new();
    }
    let topo = cluster_topology(cfg);
    (0..n)
        .map(|i| {
            let j = (i + 1) % n;
            match (cfg.exchange.transport, topo.p2p_allowed(i, j)) {
                (TransportKind::P2p, Ok(false)) => {
                    // For N = 2 the two hops mirror one physical link, so
                    // warn once; for a ring every directed hop is real.
                    if i < j || n > 2 {
                        log::warn!(
                            "workers {i} and {j} sit on different PCIe switches: \
                             hop falls back to host-staged copies (paper §4.4)"
                        );
                    }
                    TransportKind::HostStaged
                }
                (kind, _) => kind,
            }
        })
        .collect()
}

/// Summary form of the §4.4 rule for any N: the configured transport,
/// downgraded to host-staged if *any* hop had to fall back.  Per-hop
/// resolution (used to build the fabric) is `effective_hop_transports`.
pub fn effective_transport(cfg: &TrainConfig) -> TransportKind {
    let hops = effective_hop_transports(cfg);
    if hops.iter().any(|&k| k != cfg.exchange.transport) {
        TransportKind::HostStaged
    } else {
        cfg.exchange.transport
    }
}

/// Core-budget check against an explicit core count: `Some(warning)`
/// when an *explicit* `--threads` makes N workers × T threads exceed
/// the machine.  Auto (`compute_threads == 0`) partitions cores into
/// disjoint per-worker shares and can never oversubscribe.
pub fn thread_budget_warning_for(cfg: &TrainConfig, cores: usize) -> Option<String> {
    if cfg.compute_threads == 0 {
        return None;
    }
    let workers = cfg.cluster.workers;
    let want = workers * cfg.compute_threads;
    (want > cores).then(|| {
        format!(
            "{workers} worker(s) x {} compute thread(s) = {want} > {cores} available \
             core(s): replicas will contend instead of overlapping \
             (--threads {} keeps the shares disjoint)",
            cfg.compute_threads,
            (cores / workers.max(1)).max(1)
        )
    })
}

/// [`thread_budget_warning_for`] against this machine's parallelism.
pub fn thread_budget_warning(cfg: &TrainConfig) -> Option<String> {
    thread_budget_warning_for(cfg, crate::util::available_cores())
}

/// Run a full training job per the config.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    cfg.validate()?;
    let workers = cfg.cluster.workers;

    // Core partitioning: each worker's backend gets a disjoint share of
    // the machine (auto) or the explicit --threads count.  Intra-op
    // threads change wall-clock only; results are thread-count-invariant.
    if let Some(w) = thread_budget_warning(cfg) {
        log::warn!("{w}");
    }
    log::info!(
        "compute: {workers} worker(s) x {} intra-op thread(s) per step",
        cfg.threads_per_worker()
    );

    // Build the collective fabric (handles move into the threads).
    // N = 1 -> no-op, N = 2 -> the paper's pairwise fast path,
    // N > 2 -> chunked ring all-reduce; all behind one trait.
    let hop_kinds = effective_hop_transports(cfg);
    let fabrics = build_fabric(workers, &hop_kinds);

    let (tx, rx) = channel::<StepRecord>();
    let wall = Timer::start();

    // Spawn the replicas.
    let mut joins = Vec::with_capacity(workers);
    for (w, fabric) in fabrics.into_iter().enumerate() {
        let spec = WorkerSpec {
            fabric,
            worker: w,
            cfg: cfg.clone(),
            reports: tx.clone(),
            restore: None,
        };
        joins.push(
            std::thread::Builder::new()
                .name(format!("tmg-worker-{w}"))
                .spawn(move || run_worker(spec))
                .map_err(Error::RawIo)?,
        );
    }
    drop(tx);

    // Leader loop: aggregate per-step reports into windows + CSV.
    let mut meter = ThroughputMeter::new(20);
    let mut windows = Vec::new();
    let mut losses = Vec::new();
    let mut window_losses: Vec<f32> = Vec::new();
    let mut csv = match &cfg.metrics_csv {
        Some(p) => Some(CsvWriter::create(
            p,
            &["step", "worker", "loss", "correct1", "lr", "step_secs", "exchange_secs"],
        )?),
        None => None,
    };
    while let Ok(rec) = rx.recv() {
        if let Some(c) = csv.as_mut() {
            c.row(&[
                rec.step.to_string(),
                rec.worker.to_string(),
                format!("{:.6}", rec.loss),
                rec.correct1.to_string(),
                format!("{:.6}", rec.lr),
                format!("{:.6}", rec.step_seconds),
                format!("{:.6}", rec.exchange_seconds),
            ])?;
        }
        if rec.worker == 0 {
            losses.push(rec.loss);
            window_losses.push(rec.loss);
            // Window images: all workers advance together.
            if let Some(secs) = meter.step(rec.batch * workers) {
                let mean_loss =
                    window_losses.iter().sum::<f32>() / window_losses.len().max(1) as f32;
                windows.push(WindowRecord {
                    end_step: rec.step + 1,
                    seconds: secs,
                    images_per_sec: meter.last_images_per_sec,
                    mean_loss,
                });
                if cfg.log_every > 0 {
                    log::info!(
                        "step {:>5}  loss {:.4}  {:>7.1} img/s  {:.2}s/20it",
                        rec.step + 1,
                        mean_loss,
                        meter.last_images_per_sec,
                        secs
                    );
                }
                window_losses.clear();
            }
        }
    }

    // Join replicas and measure the cross-replica divergence.
    let mut outcomes = Vec::with_capacity(workers);
    for j in joins {
        outcomes.push(j.join().map_err(|_| Error::msg("worker thread panicked"))??);
    }
    outcomes.sort_by_key(|o| o.worker);

    // Divergence is only a *correctness invariant* when replicas are
    // supposed to be fully synchronized after the last step: exchange
    // every step with momenta included.  Otherwise the replicas are
    // legitimately desynchronized (drifting params between exchanges,
    // or private momenta), so report the params-only drift metric
    // instead of flagging expected differences.  Max over all replica
    // pairs against worker 0, not just workers 0 and 1.
    let final_divergence: Option<f32> = if workers >= 2 {
        let strict = cfg.exchange.period == 1 && cfg.exchange.include_momentum;
        let mut d = 0f32;
        for o in &outcomes[1..] {
            d = d.max(if strict {
                outcomes[0].store.max_divergence(&o.store)
            } else {
                outcomes[0].store.param_divergence(&o.store)
            });
        }
        Some(d)
    } else {
        None
    };

    // Per-phase collective stats: seconds averaged across workers,
    // rounds/bytes taken from worker 0 (lockstep across the group).
    let collective = {
        let mut c = CollectiveStats {
            rounds: outcomes[0].collective.rounds,
            bytes_per_round: outcomes[0].collective.bytes_per_round,
            ..CollectiveStats::default()
        };
        for o in &outcomes {
            c.flatten_seconds += o.collective.flatten_seconds;
            c.transfer_seconds += o.collective.transfer_seconds;
            c.average_seconds += o.collective.average_seconds;
        }
        c.flatten_seconds /= workers as f64;
        c.transfer_seconds /= workers as f64;
        c.average_seconds /= workers as f64;
        c
    };

    // Checkpoint replica 0 (post-exchange replicas agree).
    if let Some(dir) = &cfg.checkpoint_dir {
        let path = dir.join(format!("{}_step{}.ckpt", cfg.name, cfg.steps));
        crate::params::save_checkpoint(&path, &outcomes[0].store, cfg.steps as u64)?;
        log::info!("checkpoint written to {path:?}");
    }

    // Final evaluation on the validation split, if the backend can
    // evaluate (native always can; XLA needs an eval artifact — only
    // that artifact is loaded here, not the train executable).
    let mut eval_backend = crate::backend::build_eval_backend(cfg)?;
    let eval_batch = eval_backend.eval_batch_size().unwrap_or(cfg.batch_per_worker).max(1);
    let eval = if eval_backend.supports_eval() && cfg.data.val_examples >= eval_batch {
        Some(evaluate(cfg, eval_backend.as_mut(), &outcomes[0].store, 0)?)
    } else {
        None
    };

    Ok(TrainSummary {
        steps: cfg.steps,
        workers,
        wall_seconds: wall.elapsed_secs(),
        secs_per_20_iters: meter.mean_window_secs(),
        windows,
        losses,
        loader: outcomes.iter().map(|o| o.loader).collect(),
        exchange_rounds: collective.rounds,
        exchange_seconds: outcomes.iter().map(|o| o.exchange_seconds).sum::<f64>()
            / workers as f64,
        collective,
        compute_seconds: outcomes.iter().map(|o| o.compute_seconds).sum::<f64>()
            / workers as f64,
        final_divergence,
        eval,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cfg_with(switches: Vec<usize>, kind: TransportKind) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.cluster = ClusterConfig { workers: switches.len(), switch_of_worker: switches };
        cfg.exchange.transport = kind;
        cfg
    }

    #[test]
    fn single_worker_has_no_hops() {
        let cfg = cfg_with(vec![0], TransportKind::P2p);
        assert!(effective_hop_transports(&cfg).is_empty());
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn same_switch_pair_keeps_p2p() {
        let cfg = cfg_with(vec![0, 0], TransportKind::P2p);
        assert_eq!(
            effective_hop_transports(&cfg),
            vec![TransportKind::P2p, TransportKind::P2p]
        );
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn cross_switch_pair_falls_back() {
        let cfg = cfg_with(vec![0, 1], TransportKind::P2p);
        assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    }

    /// Regression for the seed bug: `effective_transport` silently
    /// returned the configured transport whenever `workers != 2`, so a
    /// P2P request across switches with N = 3 was never downgraded.
    /// The §4.4 fallback must fire for N > 2, per hop.
    #[test]
    fn n3_cross_switch_hops_downgrade() {
        let cfg = cfg_with(vec![0, 0, 1], TransportKind::P2p);
        // Hop 0->1 shares switch 0; hops 1->2 and 2->0 cross the root.
        assert_eq!(
            effective_hop_transports(&cfg),
            vec![TransportKind::P2p, TransportKind::HostStaged, TransportKind::HostStaged]
        );
        assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    }

    #[test]
    fn n4_single_switch_keeps_p2p_everywhere() {
        let cfg = cfg_with(vec![0, 0, 0, 0], TransportKind::P2p);
        assert_eq!(effective_hop_transports(&cfg), vec![TransportKind::P2p; 4]);
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn non_p2p_transports_pass_through_unchanged() {
        for kind in [TransportKind::HostStaged, TransportKind::Serialized] {
            let cfg = cfg_with(vec![0, 1, 1], kind);
            assert_eq!(effective_hop_transports(&cfg), vec![kind; 3]);
            assert_eq!(effective_transport(&cfg), kind);
        }
    }

    #[test]
    fn thread_budget_warns_only_on_explicit_oversubscription() {
        let mut cfg = cfg_with(vec![0, 0], TransportKind::P2p);
        // Auto partitions the machine: never a warning, whatever cores.
        cfg.compute_threads = 0;
        assert!(thread_budget_warning_for(&cfg, 1).is_none());
        assert!(thread_budget_warning_for(&cfg, 64).is_none());
        // 2 workers x 2 threads fits 4 cores exactly.
        cfg.compute_threads = 2;
        assert!(thread_budget_warning_for(&cfg, 4).is_none());
        // ... but not 2 cores; the warning names a fitting value.
        let w = thread_budget_warning_for(&cfg, 2).expect("oversubscribed");
        assert!(w.contains("--threads 1"), "{w}");
        cfg.compute_threads = 8;
        assert!(thread_budget_warning_for(&cfg, 4).is_some());
    }
}
