//! The leader: spawns workers, wires the exchange fabric, aggregates
//! metrics, evaluates and checkpoints.
//!
//! Topology-aware transport selection reproduces §4.4: if the config
//! asks for P2P but the two workers sit on different PCIe switches,
//! the fabric silently falls back to host-staged copies — exactly what
//! the hardware would force.

use std::sync::mpsc::channel;

use crate::comm::exchange::ExchangePort;
use crate::comm::link::transport_pair;
use crate::comm::ring::ring;
use crate::config::{TrainConfig, TransportKind};
use crate::coordinator::eval::{evaluate, EvalResult};
use crate::coordinator::worker::{run_worker, CommFabric, StepRecord, WorkerSpec};
use crate::data::loader::LoaderStats;
use crate::error::{Error, Result};
use crate::interconnect::topology::PcieTopology;
use crate::metrics::{CsvWriter, ThroughputMeter};
use crate::runtime::{Manifest, RuntimeClient};
use crate::util::Timer;

/// One closed 20-iteration window (Table 1's unit).
#[derive(Clone, Copy, Debug)]
pub struct WindowRecord {
    pub end_step: usize,
    pub seconds: f64,
    pub images_per_sec: f64,
    pub mean_loss: f32,
}

/// Aggregate training outcome.
#[derive(Debug)]
pub struct TrainSummary {
    pub steps: usize,
    pub workers: usize,
    pub wall_seconds: f64,
    pub windows: Vec<WindowRecord>,
    pub losses: Vec<f32>,
    pub loader: Vec<LoaderStats>,
    pub exchange_rounds: u64,
    pub exchange_seconds: f64,
    pub compute_seconds: f64,
    pub final_divergence: f32,
    pub eval: Option<EvalResult>,
    /// Mean seconds per 20 iterations (the paper's headline unit).
    pub secs_per_20_iters: f64,
}

/// Resolve the effective transport per the PCIe topology (§4.4 rule).
pub fn effective_transport(cfg: &TrainConfig) -> TransportKind {
    if cfg.cluster.workers != 2 {
        return cfg.exchange.transport;
    }
    let topo = PcieTopology {
        switches: cfg.cluster.switch_of_worker.iter().max().unwrap_or(&0) + 1,
        switch_of_device: cfg.cluster.switch_of_worker.clone(),
    };
    match (cfg.exchange.transport, topo.p2p_allowed(0, 1)) {
        (TransportKind::P2p, Ok(false)) => {
            log::warn!(
                "workers on different PCIe switches: falling back to host-staged \
                 copies (paper §4.4)"
            );
            TransportKind::HostStaged
        }
        (kind, _) => kind,
    }
}

/// Run a full training job per the config.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    cfg.validate()?;
    let workers = cfg.cluster.workers;
    let transport = effective_transport(cfg);

    // Build the exchange fabric (endpoints move into the threads).
    let mut fabrics: Vec<CommFabric> = Vec::with_capacity(workers);
    if workers == 1 {
        fabrics.push(CommFabric::None);
    } else if workers == 2 {
        let (a, b) = transport_pair(transport);
        fabrics.push(CommFabric::Pair(ExchangePort::new(a)));
        fabrics.push(CommFabric::Pair(ExchangePort::new(b)));
    } else {
        for node in ring(workers) {
            fabrics.push(CommFabric::Ring(node));
        }
    }

    let (tx, rx) = channel::<StepRecord>();
    let wall = Timer::start();

    // Spawn the replicas.
    let mut joins = Vec::with_capacity(workers);
    for (w, fabric) in fabrics.into_iter().enumerate() {
        let spec = WorkerSpec {
            worker: w,
            cfg: cfg.clone(),
            fabric,
            reports: tx.clone(),
            restore: None,
        };
        joins.push(
            std::thread::Builder::new()
                .name(format!("tmg-worker-{w}"))
                .spawn(move || run_worker(spec))
                .map_err(Error::RawIo)?,
        );
    }
    drop(tx);

    // Leader loop: aggregate per-step reports into windows + CSV.
    let mut meter = ThroughputMeter::new(20);
    let mut windows = Vec::new();
    let mut losses = Vec::new();
    let mut window_losses: Vec<f32> = Vec::new();
    let mut csv = match &cfg.metrics_csv {
        Some(p) => Some(CsvWriter::create(
            p,
            &["step", "worker", "loss", "correct1", "lr", "step_secs", "exchange_secs"],
        )?),
        None => None,
    };
    while let Ok(rec) = rx.recv() {
        if let Some(c) = csv.as_mut() {
            c.row(&[
                rec.step.to_string(),
                rec.worker.to_string(),
                format!("{:.6}", rec.loss),
                rec.correct1.to_string(),
                format!("{:.6}", rec.lr),
                format!("{:.6}", rec.step_seconds),
                format!("{:.6}", rec.exchange_seconds),
            ])?;
        }
        if rec.worker == 0 {
            losses.push(rec.loss);
            window_losses.push(rec.loss);
            // Window images: all workers advance together.
            if let Some(secs) = meter.step(rec.batch * workers) {
                let mean_loss =
                    window_losses.iter().sum::<f32>() / window_losses.len().max(1) as f32;
                windows.push(WindowRecord {
                    end_step: rec.step + 1,
                    seconds: secs,
                    images_per_sec: meter.last_images_per_sec,
                    mean_loss,
                });
                if cfg.log_every > 0 {
                    log::info!(
                        "step {:>5}  loss {:.4}  {:>7.1} img/s  {:.2}s/20it",
                        rec.step + 1,
                        mean_loss,
                        meter.last_images_per_sec,
                        secs
                    );
                }
                window_losses.clear();
            }
        }
    }

    // Join replicas and cross-check the Fig-2 invariant.
    let mut outcomes = Vec::with_capacity(workers);
    for j in joins {
        outcomes.push(j.join().map_err(|_| Error::msg("worker thread panicked"))??);
    }
    outcomes.sort_by_key(|o| o.worker);

    let final_divergence = if workers >= 2 && cfg.exchange.period == 1 && cfg.exchange.include_momentum
    {
        outcomes[0].store.max_divergence(&outcomes[1].store)
    } else if workers >= 2 {
        outcomes[0].store.max_divergence(&outcomes[1].store)
    } else {
        0.0
    };

    // Checkpoint replica 0 (post-exchange replicas agree).
    if let Some(dir) = &cfg.checkpoint_dir {
        let path = dir.join(format!("{}_step{}.ckpt", cfg.name, cfg.steps));
        crate::params::save_checkpoint(&path, &outcomes[0].store, cfg.steps as u64)?;
        log::info!("checkpoint written to {path:?}");
    }

    // Final evaluation on the validation split, if an eval artifact exists.
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let eval = match manifest.eval_artifact_for(&cfg.model) {
        Some(spec) if cfg.data.val_examples >= spec.batch_size => {
            let client = RuntimeClient::cpu()?;
            let exe = client.load_step(spec)?;
            let model = manifest.model(&cfg.model)?;
            Some(evaluate(cfg, &exe, &outcomes[0].store, model.image_hw, 0)?)
        }
        _ => None,
    };

    Ok(TrainSummary {
        steps: cfg.steps,
        workers,
        wall_seconds: wall.elapsed_secs(),
        secs_per_20_iters: meter.mean_window_secs(),
        windows,
        losses,
        loader: outcomes.iter().map(|o| o.loader).collect(),
        exchange_rounds: outcomes[0].exchange_rounds,
        exchange_seconds: outcomes.iter().map(|o| o.exchange_seconds).sum::<f64>()
            / workers as f64,
        compute_seconds: outcomes.iter().map(|o| o.compute_seconds).sum::<f64>()
            / workers as f64,
        final_divergence,
        eval,
    })
}
