//! The leader: spawns workers, wires the collective fabric, aggregates
//! metrics, evaluates and checkpoints.
//!
//! Topology-aware transport selection generalizes §4.4 to any worker
//! count: every ring hop `i -> (i+1) % N` is checked against the PCIe
//! tree independently, and a P2P request silently falls back to
//! host-staged copies on hops whose endpoints sit on different
//! switches — exactly what the hardware would force.  Same-switch hops
//! keep the fast path even when other hops are downgraded.

use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;

use crate::comm::collective::{build_fabric, Collective, CollectiveStats};
use crate::comm::rendezvous::{ring_over_tcp, RendezvousCfg, FRESH_RUN};
use crate::config::{DistributedCfg, ResumeFrom, TrainConfig, TransportKind};
use crate::coordinator::eval::{evaluate, EvalResult};
use crate::coordinator::worker::{run_worker, WorkerMsg, WorkerSpec};
use crate::data::loader::LoaderStats;
use crate::data::sampler::EpochSampler;
use crate::error::{Error, Result};
use crate::interconnect::topology::PcieTopology;
use crate::metrics::{CsvWriter, ThroughputMeter};
use crate::params::{
    find_auto_resume, load_checkpoint, resume_set_from_path, ParamStore, ResumeSet, TrainState,
};
use crate::util::Timer;

/// One closed 20-iteration window (Table 1's unit).
#[derive(Clone, Copy, Debug)]
pub struct WindowRecord {
    pub end_step: usize,
    pub seconds: f64,
    pub images_per_sec: f64,
    pub mean_loss: f32,
}

/// One mid-training validation measurement (`eval_every` cadence).
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub step: usize,
    pub result: EvalResult,
}

/// Aggregate training outcome.
#[derive(Debug)]
pub struct TrainSummary {
    pub steps: usize,
    pub workers: usize,
    /// Step this run resumed from (`--resume`), if any.
    pub resumed_from: Option<usize>,
    pub wall_seconds: f64,
    pub windows: Vec<WindowRecord>,
    /// Mid-training validation curve (empty unless `eval_every > 0`).
    pub evals: Vec<EvalRecord>,
    pub losses: Vec<f32>,
    pub loader: Vec<LoaderStats>,
    pub exchange_rounds: u64,
    pub exchange_seconds: f64,
    /// Per-phase collective timing (flatten/transfer/average), seconds
    /// averaged across workers — the Table-1/Fig-2 bench breakdown for
    /// any N.
    pub collective: CollectiveStats,
    pub compute_seconds: f64,
    /// Replica divergence after the final step.  `None` for a single
    /// worker (no peer to compare).  When replicas are supposed to be
    /// bit-synchronized (period 1 and momenta included) this is the
    /// full-state Fig-2 invariant; otherwise it is the params-only
    /// drift metric (momenta legitimately differ there).
    pub final_divergence: Option<f32>,
    pub eval: Option<EvalResult>,
    /// Mean seconds per 20 iterations (the paper's headline unit).
    pub secs_per_20_iters: f64,
    /// GEMM microkernel ISA the native backend dispatched for this
    /// process (`avx2`/`neon`/`scalar`) — recorded so every run says
    /// what it actually executed.
    pub gemm_isa: String,
}

fn cluster_topology(cfg: &TrainConfig) -> PcieTopology {
    PcieTopology {
        switches: cfg.cluster.switch_of_worker.iter().max().unwrap_or(&0) + 1,
        switch_of_device: cfg.cluster.switch_of_worker.clone(),
    }
}

/// Per-hop effective transports for the ring `i -> (i+1) % N` (§4.4
/// rule applied to every hop): a P2P request is downgraded to
/// host-staged on cross-switch hops; other kinds pass through.  Empty
/// for a single worker.
pub fn effective_hop_transports(cfg: &TrainConfig) -> Vec<TransportKind> {
    let n = cfg.cluster.workers;
    if n < 2 {
        return Vec::new();
    }
    let topo = cluster_topology(cfg);
    (0..n)
        .map(|i| {
            let j = (i + 1) % n;
            match (cfg.exchange.transport, topo.p2p_allowed(i, j)) {
                (TransportKind::P2p, Ok(false)) => {
                    // For N = 2 the two hops mirror one physical link, so
                    // warn once; for a ring every directed hop is real.
                    if i < j || n > 2 {
                        log::warn!(
                            "workers {i} and {j} sit on different PCIe switches: \
                             hop falls back to host-staged copies (paper §4.4)"
                        );
                    }
                    TransportKind::HostStaged
                }
                (kind, _) => kind,
            }
        })
        .collect()
}

/// Summary form of the §4.4 rule for any N: the configured transport,
/// downgraded to host-staged if *any* hop had to fall back.  Per-hop
/// resolution (used to build the fabric) is `effective_hop_transports`.
pub fn effective_transport(cfg: &TrainConfig) -> TransportKind {
    let hops = effective_hop_transports(cfg);
    if hops.iter().any(|&k| k != cfg.exchange.transport) {
        TransportKind::HostStaged
    } else {
        cfg.exchange.transport
    }
}

/// Core-budget check against an explicit core count: `Some(warning)`
/// when an *explicit* `--threads` makes N workers × T threads exceed
/// the machine.  Auto (`compute_threads == 0`) partitions cores into
/// disjoint per-worker shares and can never oversubscribe.
pub fn thread_budget_warning_for(cfg: &TrainConfig, cores: usize) -> Option<String> {
    if cfg.compute_threads == 0 {
        return None;
    }
    let workers = cfg.cluster.workers;
    let want = workers * cfg.compute_threads;
    (want > cores).then(|| {
        format!(
            "{workers} worker(s) x {} compute thread(s) = {want} > {cores} available \
             core(s): replicas will contend instead of overlapping \
             (--threads {} keeps the shares disjoint)",
            cfg.compute_threads,
            (cores / workers.max(1)).max(1)
        )
    })
}

/// [`thread_budget_warning_for`] against this machine's parallelism.
pub fn thread_budget_warning(cfg: &TrainConfig) -> Option<String> {
    thread_budget_warning_for(cfg, crate::util::available_cores())
}

/// Resolve `cfg.resume` into a per-worker restore set.  `auto` scans
/// the checkpoint dir for the newest valid, config-compatible set and
/// silently starts fresh when none exists; an explicit path fails hard
/// when it cannot be restored.
fn resolve_resume(cfg: &TrainConfig) -> Result<Option<ResumeSet>> {
    let workers = cfg.cluster.workers;
    match &cfg.resume {
        None => Ok(None),
        Some(ResumeFrom::Auto) => {
            let dir = cfg.checkpoint_dir.as_ref().ok_or_else(|| {
                Error::Config("--resume auto needs --checkpoint-dir (nowhere to look)".into())
            })?;
            let found = find_auto_resume(dir, workers, cfg.resume_fingerprint())?;
            if found.is_none() {
                log::warn!("--resume auto: no valid checkpoint in {dir:?}; starting fresh");
            }
            Ok(found)
        }
        Some(ResumeFrom::Path(p)) => Ok(Some(resume_set_from_path(p, workers)?)),
    }
}

/// Rendezvous with the peer processes and return this rank's node of
/// the TCP ring.  The ring collective is used for every world size
/// (its N = 2 schedule is bit-identical to the in-memory pairwise
/// path), and the steady-state I/O deadline is installed before the
/// node is handed to the worker, so a peer dying mid-round surfaces as
/// `Error::Timeout` inside the normal collective error path.
fn distributed_fabric(
    cfg: &TrainConfig,
    d: &DistributedCfg,
    resume_step: u64,
) -> Result<Box<dyn Collective>> {
    log::info!(
        "distributed: rank {} of {} rendezvousing over TCP \
         (connect budget {:?}, io deadline {:?})",
        d.rank,
        d.peers.len(),
        d.connect_timeout(),
        d.io_timeout()
    );
    let node = ring_over_tcp(&RendezvousCfg {
        rank: d.rank,
        peers: &d.peers,
        fingerprint: cfg.resume_fingerprint(),
        resume_step,
        connect_timeout: d.connect_timeout(),
        io_timeout: d.io_timeout(),
    })?;
    Ok(Box::new(node))
}

/// The eval-curve CSV path derived from the step-metrics CSV path.
fn eval_csv_path(metrics_csv: &Path) -> PathBuf {
    metrics_csv.with_extension("eval.csv")
}

/// Drop CSV rows whose leading `step` column is >= `from` (rows the
/// resumed run will re-emit).  A kill can land *after* the last
/// checkpoint, leaving rows for steps the resume re-trains; without
/// this, appending would duplicate those step rows.  Missing file or
/// unparsable rows are left alone.  The rewrite is atomic (tmp +
/// rename) like every other lifecycle write: a kill mid-trim must not
/// be able to destroy the very history this exists to preserve.
fn trim_csv_rows_from(path: &Path, from: usize) -> Result<()> {
    let Ok(content) = std::fs::read_to_string(path) else {
        return Ok(());
    };
    let mut kept = String::with_capacity(content.len());
    for (i, line) in content.lines().enumerate() {
        let step: Option<usize> = line.split(',').next().and_then(|t| t.parse().ok());
        if i > 0 && matches!(step, Some(s) if s >= from) {
            continue;
        }
        kept.push_str(line);
        kept.push('\n');
    }
    if kept.len() != content.len() {
        let tmp = path.with_extension("csv.tmp");
        std::fs::write(&tmp, kept).map_err(|e| Error::io(&tmp, e))?;
        std::fs::rename(&tmp, path).map_err(|e| Error::io(path, e))?;
    }
    Ok(())
}

/// Run a full training job per the config.
pub fn train(cfg: &TrainConfig) -> Result<TrainSummary> {
    cfg.validate()?;
    let workers = cfg.cluster.workers;
    // In distributed mode this process runs exactly one rank; rank 0
    // owns the leader-only side effects (final checkpoint, final eval).
    let rank0_local = cfg.distributed.as_ref().map_or(true, |d| d.rank == 0);
    if cfg.checkpoint_every > 0 && cfg.checkpoint_dir.is_none() {
        return Err(Error::Config(
            "checkpoint_every is set but there is no checkpoint_dir to write into".into(),
        ));
    }

    // Resolve `--resume` before spawning anything: every worker must
    // restore from the same step or the exchange would desynchronize.
    let resume_set = resolve_resume(cfg)?;
    if let Some(set) = &resume_set {
        // `auto` on an already-complete run is a no-op, not an error:
        // a supervisor re-running the same command after success must
        // not crash-loop.  (An explicit `--resume PATH` whose step
        // exceeds --steps still fails loudly in the worker — the user
        // named a file that cannot be continued.)
        if set.step as usize >= cfg.steps && matches!(cfg.resume, Some(ResumeFrom::Auto)) {
            log::warn!(
                "--resume auto: checkpoint at step {} already covers --steps {}; \
                 nothing left to train (raise --steps to continue)",
                set.step,
                cfg.steps
            );
            let eval = if rank0_local {
                let mut eval_backend = crate::backend::build_eval_backend(cfg)?;
                if eval_backend.supports_eval() && cfg.data.val_examples > 0 {
                    let model = eval_backend.model().clone();
                    let mut store = ParamStore::init(&model.params, cfg.seed);
                    load_checkpoint(&set.paths[0], &mut store)?;
                    evaluate(cfg, eval_backend.as_mut(), &store, 0)?
                } else {
                    None
                }
            } else {
                None
            };
            return Ok(TrainSummary {
                steps: cfg.steps,
                workers,
                resumed_from: Some(set.step as usize),
                wall_seconds: 0.0,
                secs_per_20_iters: 0.0,
                windows: Vec::new(),
                evals: Vec::new(),
                losses: Vec::new(),
                loader: Vec::new(),
                exchange_rounds: 0,
                exchange_seconds: 0.0,
                collective: CollectiveStats::default(),
                compute_seconds: 0.0,
                final_divergence: None,
                eval,
                gemm_isa: crate::backend::native::simd::active_isa().name().to_string(),
            });
        }
        // Pre-flight the whole restore set against header-level state
        // (same hard checks the workers re-run after loading): a
        // resume that cannot succeed must fail *here*, before any side
        // effect below (metrics-CSV trim) mutates existing history.
        for (w, p) in set.paths.iter().enumerate() {
            let info = crate::params::peek_checkpoint(p)?;
            crate::coordinator::worker::validate_restore(cfg, w, p, &info)?;
        }
        log::info!(
            "resuming from step {} ({})",
            set.step,
            if set.per_worker() { "per-worker snapshots" } else { "shared checkpoint" }
        );
    }

    // Core partitioning: each worker's backend gets a disjoint share of
    // the machine (auto) or the explicit --threads count.  Intra-op
    // threads change wall-clock only; results are thread-count-invariant.
    if let Some(w) = thread_budget_warning(cfg) {
        log::warn!("{w}");
    }
    match &cfg.distributed {
        Some(d) => log::info!(
            "compute: rank {} of {workers} (one process per rank) x {} \
             intra-op thread(s) per step, gemm isa {}",
            d.rank,
            cfg.threads_per_worker(),
            crate::backend::native::simd::active_isa()
        ),
        None => log::info!(
            "compute: {workers} worker(s) x {} intra-op thread(s) per step, gemm isa {}",
            cfg.threads_per_worker(),
            crate::backend::native::simd::active_isa()
        ),
    }

    // Build the collective fabric (handles move into the threads).
    // In-process: N = 1 -> no-op, N = 2 -> the paper's pairwise fast
    // path, N > 2 -> chunked ring all-reduce; all behind one trait.
    // Distributed: this process is one rank of a TCP ring, so exactly
    // one (rank, fabric) pair is local.
    let local_fabrics: Vec<(usize, Box<dyn Collective>)> = match &cfg.distributed {
        Some(d) => {
            let resume_step = resume_set.as_ref().map(|s| s.step).unwrap_or(FRESH_RUN);
            vec![(d.rank, distributed_fabric(cfg, d, resume_step)?)]
        }
        None => {
            let hop_kinds = effective_hop_transports(cfg);
            build_fabric(workers, &hop_kinds).into_iter().enumerate().collect()
        }
    };
    let local_count = local_fabrics.len();

    let (tx, rx) = channel::<WorkerMsg>();
    let wall = Timer::start();

    // Spawn the local replicas.
    let mut joins = Vec::with_capacity(local_count);
    for (w, fabric) in local_fabrics {
        let spec = WorkerSpec {
            fabric,
            worker: w,
            cfg: cfg.clone(),
            reports: tx.clone(),
            restore: resume_set.as_ref().map(|s| s.paths[w].clone()),
        };
        joins.push(
            std::thread::Builder::new()
                .name(format!("tmg-worker-{w}"))
                .spawn(move || run_worker(spec))
                .map_err(Error::RawIo)?,
        );
    }
    drop(tx);

    // Leader loop: aggregate per-step reports into windows + CSVs
    // (step metrics and, when mid-training validation is on, the eval
    // curve in a sibling `<metrics>.eval.csv`).
    let mut meter = ThroughputMeter::new(20);
    let mut windows = Vec::new();
    let mut evals: Vec<EvalRecord> = Vec::new();
    let mut losses = Vec::new();
    let mut window_losses: Vec<f32> = Vec::new();
    // A resumed run appends to the existing CSVs (the pre-kill curve
    // is history worth keeping), first dropping any rows for steps the
    // resume re-trains — the kill may have landed after the last
    // checkpoint.  A fresh run truncates as before.
    if let (Some(set), Some(p)) = (&resume_set, &cfg.metrics_csv) {
        let start = set.step as usize;
        trim_csv_rows_from(p, start)?; // step rows log 0-based `rec.step`
        trim_csv_rows_from(&eval_csv_path(p), start + 1)?; // eval rows log `done`
    }
    let open_csv = |path: &Path, header: &[&str]| -> Result<CsvWriter> {
        if resume_set.is_some() {
            CsvWriter::append(path, header)
        } else {
            CsvWriter::create(path, header)
        }
    };
    let mut csv = match &cfg.metrics_csv {
        Some(p) => Some(open_csv(
            p,
            &[
                "step",
                "worker",
                "loss",
                "correct1",
                "lr",
                "step_secs",
                "exchange_secs",
                "overlap_secs",
                "exposed_secs",
            ],
        )?),
        None => None,
    };
    let mut eval_csv = match (&cfg.metrics_csv, cfg.eval_every > 0) {
        (Some(p), true) => Some(open_csv(
            &eval_csv_path(p),
            &["step", "examples", "mean_loss", "top1_error", "top5_error"],
        )?),
        _ => None,
    };
    while let Ok(msg) = rx.recv() {
        let rec = match msg {
            WorkerMsg::Step(rec) => rec,
            WorkerMsg::Eval { step, result } => {
                if let Some(c) = eval_csv.as_mut() {
                    c.row(&[
                        step.to_string(),
                        result.examples.to_string(),
                        format!("{:.6}", result.mean_loss),
                        format!("{:.6}", result.top1_error()),
                        format!("{:.6}", result.top5_error()),
                    ])?;
                }
                log::info!(
                    "step {:>5}  validation: top-1 error {:.2}%  top-5 {:.2}%  \
                     loss {:.4}  ({} examples)",
                    step,
                    100.0 * result.top1_error(),
                    100.0 * result.top5_error(),
                    result.mean_loss,
                    result.examples
                );
                evals.push(EvalRecord { step, result });
                continue;
            }
        };
        if let Some(c) = csv.as_mut() {
            c.row(&[
                rec.step.to_string(),
                rec.worker.to_string(),
                format!("{:.6}", rec.loss),
                rec.correct1.to_string(),
                format!("{:.6}", rec.lr),
                format!("{:.6}", rec.step_seconds),
                format!("{:.6}", rec.exchange_seconds),
                format!("{:.6}", rec.overlap_seconds),
                format!("{:.6}", rec.exposed_seconds),
            ])?;
        }
        if rec.worker == 0 {
            losses.push(rec.loss);
            window_losses.push(rec.loss);
            // Window images: all workers advance together.
            if let Some(secs) = meter.step(rec.batch * workers) {
                let mean_loss =
                    window_losses.iter().sum::<f32>() / window_losses.len().max(1) as f32;
                windows.push(WindowRecord {
                    end_step: rec.step + 1,
                    seconds: secs,
                    images_per_sec: meter.last_images_per_sec,
                    mean_loss,
                });
                if cfg.log_every > 0 {
                    log::info!(
                        "step {:>5}  loss {:.4}  {:>7.1} img/s  {:.2}s/20it",
                        rec.step + 1,
                        mean_loss,
                        meter.last_images_per_sec,
                        secs
                    );
                }
                window_losses.clear();
            }
        }
    }

    // Join replicas and measure the cross-replica divergence.
    let mut outcomes = Vec::with_capacity(local_count);
    for j in joins {
        match j.join().map_err(|_| Error::msg("worker thread panicked"))? {
            Ok(o) => outcomes.push(o),
            Err(e) => {
                if cfg.distributed.is_some() {
                    log::error!(
                        "rank failed mid-run: {e}; if a peer process died, \
                         restart every rank with --resume auto to reassemble \
                         the run from the newest complete checkpoint set"
                    );
                }
                return Err(e);
            }
        }
    }
    outcomes.sort_by_key(|o| o.worker);

    // Divergence is only a *correctness invariant* when replicas are
    // supposed to be fully synchronized after the last step: exchange
    // every step with momenta included.  Otherwise the replicas are
    // legitimately desynchronized (drifting params between exchanges,
    // or private momenta), so report the params-only drift metric
    // instead of flagging expected differences.  Max over all replica
    // pairs against worker 0, not just workers 0 and 1.
    // (In distributed mode only one replica is local, so there is no
    // in-process peer to compare — the e2e harness compares final
    // checkpoints across processes instead.)
    let final_divergence: Option<f32> = if outcomes.len() >= 2 {
        let strict = cfg.exchange.period == 1 && cfg.exchange.include_momentum;
        let mut d = 0f32;
        for o in &outcomes[1..] {
            d = d.max(if strict {
                outcomes[0].store.max_divergence(&o.store)
            } else {
                outcomes[0].store.param_divergence(&o.store)
            });
        }
        Some(d)
    } else {
        None
    };

    // Per-phase collective stats: seconds averaged across workers,
    // rounds/bytes taken from worker 0 (lockstep across the group).
    let collective = {
        let mut c = CollectiveStats {
            rounds: outcomes[0].collective.rounds,
            bytes_per_round: outcomes[0].collective.bytes_per_round,
            bucket_rounds: outcomes[0].collective.bucket_rounds,
            ..CollectiveStats::default()
        };
        for o in &outcomes {
            c.flatten_seconds += o.collective.flatten_seconds;
            c.transfer_seconds += o.collective.transfer_seconds;
            c.average_seconds += o.collective.average_seconds;
            c.overlapped_seconds += o.collective.overlapped_seconds;
            c.exposed_seconds += o.collective.exposed_seconds;
        }
        c.flatten_seconds /= local_count as f64;
        c.transfer_seconds /= local_count as f64;
        c.average_seconds /= local_count as f64;
        c.overlapped_seconds /= local_count as f64;
        c.exposed_seconds /= local_count as f64;
        c
    };
    if collective.bucket_rounds > 0 {
        log::info!(
            "exchange overlap: {:.3}s hidden behind backward, {:.3}s exposed \
             ({} buckets over {} rounds)",
            collective.overlapped_seconds,
            collective.exposed_seconds,
            collective.bucket_rounds,
            collective.rounds
        );
    }

    // Final checkpoint: replica 0's state as a single shared v2 file
    // (post-exchange replicas agree at period 1; the per-worker
    // periodic snapshots cover exact resume for every other config).
    // In distributed mode only rank 0 writes it — `outcomes[0]` is
    // that rank's replica exactly when `rank0_local`.
    if let (Some(dir), true) = (&cfg.checkpoint_dir, rank0_local) {
        let path = dir.join(format!("{}_step{}.ckpt", cfg.name, cfg.steps));
        let (sampler_epoch, sampler_next_batch) = EpochSampler::position_after(
            cfg.data.train_examples,
            cfg.batch_per_worker,
            0,
            workers,
            cfg.steps,
        );
        let state = TrainState {
            step: cfg.steps as u64,
            worker: 0,
            workers: workers as u32,
            exchange_fingerprint: cfg.resume_fingerprint(),
            sampler_epoch,
            sampler_next_batch,
            lr: cfg.schedule.lr_at(cfg.steps),
        };
        crate::params::save_checkpoint_v2(&path, &outcomes[0].store, &state)?;
        log::info!("checkpoint written to {path:?}");
    }

    // Final evaluation on the validation split, if the backend can
    // evaluate (native always can; XLA needs an eval artifact — only
    // that artifact is loaded here, not the train executable).  The
    // evaluator covers the whole split including the ragged tail for
    // variable-batch backends, so even `val_examples < batch` is
    // measured rather than silently skipped.
    // Distributed non-zero ranks skip it: rank 0 owns validation.
    let eval = if rank0_local {
        let mut eval_backend = crate::backend::build_eval_backend(cfg)?;
        if eval_backend.supports_eval() && cfg.data.val_examples > 0 {
            // `evaluate` answers None when nothing was measured — absent
            // split, or a fixed-batch backend over a too-small split —
            // which reports as "no eval" instead of a fake 100% error.
            evaluate(cfg, eval_backend.as_mut(), &outcomes[0].store, 0)?
        } else {
            None
        }
    } else {
        None
    };

    Ok(TrainSummary {
        steps: cfg.steps,
        workers,
        resumed_from: resume_set.as_ref().map(|s| s.step as usize),
        wall_seconds: wall.elapsed_secs(),
        secs_per_20_iters: meter.mean_window_secs(),
        windows,
        evals,
        losses,
        loader: outcomes.iter().map(|o| o.loader).collect(),
        exchange_rounds: collective.rounds,
        exchange_seconds: outcomes.iter().map(|o| o.exchange_seconds).sum::<f64>()
            / local_count as f64,
        collective,
        compute_seconds: outcomes.iter().map(|o| o.compute_seconds).sum::<f64>()
            / local_count as f64,
        final_divergence,
        eval,
        gemm_isa: crate::backend::native::simd::active_isa().name().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cfg_with(switches: Vec<usize>, kind: TransportKind) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.cluster = ClusterConfig { workers: switches.len(), switch_of_worker: switches };
        cfg.exchange.transport = kind;
        cfg
    }

    #[test]
    fn single_worker_has_no_hops() {
        let cfg = cfg_with(vec![0], TransportKind::P2p);
        assert!(effective_hop_transports(&cfg).is_empty());
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn same_switch_pair_keeps_p2p() {
        let cfg = cfg_with(vec![0, 0], TransportKind::P2p);
        assert_eq!(
            effective_hop_transports(&cfg),
            vec![TransportKind::P2p, TransportKind::P2p]
        );
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn cross_switch_pair_falls_back() {
        let cfg = cfg_with(vec![0, 1], TransportKind::P2p);
        assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    }

    /// Regression for the seed bug: `effective_transport` silently
    /// returned the configured transport whenever `workers != 2`, so a
    /// P2P request across switches with N = 3 was never downgraded.
    /// The §4.4 fallback must fire for N > 2, per hop.
    #[test]
    fn n3_cross_switch_hops_downgrade() {
        let cfg = cfg_with(vec![0, 0, 1], TransportKind::P2p);
        // Hop 0->1 shares switch 0; hops 1->2 and 2->0 cross the root.
        assert_eq!(
            effective_hop_transports(&cfg),
            vec![TransportKind::P2p, TransportKind::HostStaged, TransportKind::HostStaged]
        );
        assert_eq!(effective_transport(&cfg), TransportKind::HostStaged);
    }

    #[test]
    fn n4_single_switch_keeps_p2p_everywhere() {
        let cfg = cfg_with(vec![0, 0, 0, 0], TransportKind::P2p);
        assert_eq!(effective_hop_transports(&cfg), vec![TransportKind::P2p; 4]);
        assert_eq!(effective_transport(&cfg), TransportKind::P2p);
    }

    #[test]
    fn non_p2p_transports_pass_through_unchanged() {
        for kind in [TransportKind::HostStaged, TransportKind::Serialized] {
            let cfg = cfg_with(vec![0, 1, 1], kind);
            assert_eq!(effective_hop_transports(&cfg), vec![kind; 3]);
            assert_eq!(effective_transport(&cfg), kind);
        }
    }

    #[test]
    fn thread_budget_warns_only_on_explicit_oversubscription() {
        let mut cfg = cfg_with(vec![0, 0], TransportKind::P2p);
        // Auto partitions the machine: never a warning, whatever cores.
        cfg.compute_threads = 0;
        assert!(thread_budget_warning_for(&cfg, 1).is_none());
        assert!(thread_budget_warning_for(&cfg, 64).is_none());
        // 2 workers x 2 threads fits 4 cores exactly.
        cfg.compute_threads = 2;
        assert!(thread_budget_warning_for(&cfg, 4).is_none());
        // ... but not 2 cores; the warning names a fitting value.
        let w = thread_budget_warning_for(&cfg, 2).expect("oversubscribed");
        assert!(w.contains("--threads 1"), "{w}");
        cfg.compute_threads = 8;
        assert!(thread_budget_warning_for(&cfg, 4).is_some());
    }
}
