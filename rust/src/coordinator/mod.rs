//! The training coordinator — the paper's system contribution, L3.
//!
//! [`worker`] is one replica: a thread owning a PJRT client, compiled
//! train/eval steps, its parameter store, a (serial or Fig-1 parallel)
//! loader and its handle on the group collective.  [`trainer`] wires N
//! workers together through the `comm::collective` trait — no-op for
//! N=1, the paper's pairwise Fig-2 exchange for N=2, chunked ring
//! all-reduce beyond — runs the step loop, logs Table-1-style
//! per-20-iteration windows, evaluates and checkpoints.

pub mod eval;
pub mod trainer;
pub mod worker;

pub use trainer::{train, EvalRecord, TrainSummary, WindowRecord};
pub use worker::{step_seed, StepRecord, WorkerMsg, WorkerOutcome};
