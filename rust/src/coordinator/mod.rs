//! The training coordinator — the paper's system contribution, L3.
//!
//! [`worker`] is one replica: a thread owning a PJRT client, compiled
//! train/eval steps, its parameter store, a (serial or Fig-1 parallel)
//! loader and one side of the exchange fabric.  [`trainer`] wires N
//! workers together — pairwise Fig-2 exchange for the paper's N=2,
//! ring all-reduce beyond — runs the step loop, logs Table-1-style
//! per-20-iteration windows, evaluates and checkpoints.

pub mod eval;
pub mod trainer;
pub mod worker;

pub use trainer::{train, TrainSummary, WindowRecord};
pub use worker::{CommFabric, StepRecord};
