//! One replica ("virtual GPU") worker: local steps plus its handle on
//! the group collective (any N, see `comm::collective`).
//!
//! The worker is backend-agnostic: every step goes through the
//! [`StepBackend`](crate::backend::StepBackend) the config selects
//! (native pure-Rust CPU math or AOT-XLA artifacts), and the collective
//! exchange, checkpointing and divergence invariants all operate on the
//! resulting `ParamStore` identically.

use std::path::PathBuf;
use std::sync::mpsc::Sender;

use crate::comm::collective::{Collective, CollectiveStats};
use crate::config::{LoaderMode, TrainConfig};
use crate::data::loader::{BatchSource, LoaderCfg, LoaderStats, ParallelLoader, SerialLoader};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::util::Timer;

/// Per-step record streamed to the trainer for logging.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub worker: usize,
    pub step: usize,
    pub loss: f32,
    pub correct1: i32,
    pub batch: usize,
    pub lr: f32,
    pub step_seconds: f64,
    pub exchange_seconds: f64,
}

/// Final report returned from a worker thread.
#[derive(Debug)]
pub struct WorkerOutcome {
    pub worker: usize,
    pub steps: usize,
    pub store: ParamStore,
    pub loader: LoaderStats,
    /// Cumulative per-phase collective timing (flatten/transfer/average).
    pub collective: CollectiveStats,
    /// Wall seconds spent inside collective rounds (includes overhead
    /// the per-phase timers don't attribute).
    pub exchange_seconds: f64,
    pub compute_seconds: f64,
}

/// Everything a worker thread needs (built on the spawning side; all
/// backend state is created *inside* the thread).
pub struct WorkerSpec {
    /// This worker's handle on the group collective (no-op for N = 1,
    /// pairwise port for N = 2, ring node beyond — see `comm::collective`).
    pub fabric: Box<dyn Collective>,
    pub worker: usize,
    pub cfg: TrainConfig,
    pub reports: Sender<StepRecord>,
    /// Checkpoint path this worker should restore from, if any.
    pub restore: Option<PathBuf>,
}

/// Build this worker's batch source per the configured loader mode.
fn build_loader(cfg: &TrainConfig, worker: usize, crop_hw: usize) -> Result<Box<dyn BatchSource>> {
    let lcfg = LoaderCfg {
        data_dir: &cfg.data.dir,
        split: "train",
        batch: cfg.batch_per_worker,
        crop_hw,
        worker,
        workers: cfg.cluster.workers,
        seed: cfg.seed,
        train_augment: true,
        verify_shards: false,
    };
    Ok(match cfg.loader_mode {
        LoaderMode::Parallel => Box::new(ParallelLoader::new(&lcfg)?),
        LoaderMode::Serial => Box::new(SerialLoader::new(&lcfg)?),
    })
}

/// The worker thread body: runs `cfg.steps` local steps with a
/// collective exchange every `cfg.exchange.period` steps.
pub fn run_worker(spec: WorkerSpec) -> Result<WorkerOutcome> {
    let WorkerSpec { mut fabric, worker, cfg, reports, restore } = spec;

    // --- Setup (the paper's per-GPU Theano process initialization):
    // --- each replica owns its backend, parameters and loader. ---
    let mut backend = crate::backend::build_backend(&cfg)?;
    let model = backend.model().clone();

    let mut store = ParamStore::init(&model.params, cfg.seed);
    let mut start_step = 0usize;
    if let Some(ckpt) = restore {
        start_step = crate::params::load_checkpoint(&ckpt, &mut store)? as usize;
    }

    // Guard the label space: a corpus with more classes than the model
    // produces out-of-range gathers (NaN losses) inside the step.
    let meta_path = cfg.data.dir.join("meta.json");
    if let Ok(src) = std::fs::read_to_string(&meta_path) {
        let meta = crate::data::synth::DatasetMeta::from_json(&src)?;
        if meta.classes > model.num_classes {
            return Err(Error::msg(format!(
                "dataset at {:?} has {} classes but model {:?} expects {}",
                cfg.data.dir, meta.classes, model.name, model.num_classes
            )));
        }
    }

    let mut loader = build_loader(&cfg, worker, model.image_hw)?;

    let include_momentum = cfg.exchange.include_momentum;
    let mut compute_seconds = 0.0;
    let mut exchange_seconds = 0.0;

    // --- The step loop (Fig 1 + Fig 2 composed) ---
    for step in start_step..cfg.steps {
        let step_timer = Timer::start();
        let batch = loader.next_batch()?;
        let lr = cfg.schedule.lr_at(step);
        let step_seed = (cfg.seed as i32) ^ (step as i32) ^ ((worker as i32) << 20);

        let t_compute = Timer::start();
        let out = backend.train_step(&batch.images, &batch.labels, lr, step_seed, &mut store)?;
        compute_seconds += t_compute.elapsed_secs();

        if !out.loss.is_finite() {
            return Err(Error::msg(format!(
                "worker {worker}: non-finite loss {} at step {step} (lr too high?)",
                out.loss
            )));
        }

        // --- Collective exchange at the configured period (Fig 2 for
        // --- N = 2, ring all-reduce beyond) ---
        let mut dt_exchange = 0.0;
        if fabric.world_size() > 1 && (step + 1) % cfg.exchange.period == 0 {
            let t_ex = Timer::start();
            fabric.all_reduce_average(&mut store, include_momentum)?;
            dt_exchange = t_ex.elapsed_secs();
            exchange_seconds += dt_exchange;
        }

        let _ = reports.send(StepRecord {
            worker,
            step,
            loss: out.loss,
            correct1: out.correct1,
            batch: batch.labels.len(),
            lr,
            step_seconds: step_timer.elapsed_secs(),
            exchange_seconds: dt_exchange,
        });
    }

    Ok(WorkerOutcome {
        worker,
        steps: cfg.steps.saturating_sub(start_step),
        store,
        loader: loader.stats(),
        collective: fabric.stats(),
        exchange_seconds,
        compute_seconds,
    })
}
