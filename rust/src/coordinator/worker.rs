//! One replica ("virtual GPU") worker: local steps plus its handle on
//! the group collective (any N, see `comm::collective`).
//!
//! The worker is backend-agnostic: every step goes through the
//! [`StepBackend`](crate::backend::StepBackend) the config selects
//! (native pure-Rust CPU math or AOT-XLA artifacts), and the collective
//! exchange, checkpointing and divergence invariants all operate on the
//! resulting `ParamStore` identically.
//!
//! Lifecycle: with `checkpoint_every = N` each worker writes its own
//! v2 snapshot every N steps (post-exchange, so at period 1 all
//! replicas agree bit-for-bit); worker 0 additionally maintains the
//! `LATEST`/`BEST` markers and the retention policy, and runs the
//! mid-training validation (`eval_every`).  [`WorkerSpec::restore`]
//! points a worker at its checkpoint: parameters, momenta and the step
//! counter come from the file, the data loader is fast-forwarded to
//! the exact stream position, and the LR schedule re-derives from the
//! absolute step — so a killed-and-resumed run is bit-identical to an
//! uninterrupted one.

use std::path::{Path, PathBuf};
use std::sync::mpsc::Sender;

use crate::backend::GradSink;
use crate::comm::collective::{Collective, CollectiveStats};
use crate::comm::overlap::GradExchanger;
use crate::config::{LoaderMode, OverlapMode, TrainConfig};
use crate::coordinator::eval::EvalResult;
use crate::data::loader::{BatchSource, LoaderCfg, LoaderStats, ParallelLoader, SerialLoader};
use crate::data::sampler::EpochSampler;
use crate::error::{Error, Result};
use crate::params::{
    best_marker_error, load_checkpoint_full, periodic_checkpoint_name, prune_checkpoints,
    save_checkpoint_v2, write_marker, ParamStore, TrainState, BEST_MARKER, LATEST_MARKER,
};
use crate::util::Timer;

/// Per-step record streamed to the trainer for logging.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub worker: usize,
    pub step: usize,
    pub loss: f32,
    pub correct1: i32,
    pub batch: usize,
    pub lr: f32,
    pub step_seconds: f64,
    pub exchange_seconds: f64,
    /// Comm seconds hidden behind backward this step (overlap mode).
    pub overlap_seconds: f64,
    /// Comm seconds the step waited for at the pre-update barrier.
    pub exposed_seconds: f64,
}

/// Everything a worker streams to the trainer while running.
#[derive(Clone, Copy, Debug)]
pub enum WorkerMsg {
    /// One completed training step.
    Step(StepRecord),
    /// A mid-training validation result (worker 0, `eval_every` cadence).
    Eval { step: usize, result: EvalResult },
}

/// Final report returned from a worker thread.
#[derive(Debug)]
pub struct WorkerOutcome {
    pub worker: usize,
    /// Steps executed *by this run* (resume subtracts the restored ones).
    pub steps: usize,
    pub store: ParamStore,
    pub loader: LoaderStats,
    /// Cumulative per-phase collective timing (flatten/transfer/average).
    pub collective: CollectiveStats,
    /// Wall seconds spent inside collective rounds (includes overhead
    /// the per-phase timers don't attribute).
    pub exchange_seconds: f64,
    pub compute_seconds: f64,
}

/// Everything a worker thread needs (built on the spawning side; all
/// backend state is created *inside* the thread).
pub struct WorkerSpec {
    /// This worker's handle on the group collective (no-op for N = 1,
    /// pairwise port for N = 2, ring node beyond — see `comm::collective`).
    pub fabric: Box<dyn Collective>,
    pub worker: usize,
    pub cfg: TrainConfig,
    pub reports: Sender<WorkerMsg>,
    /// Checkpoint path this worker should restore from, if any.
    pub restore: Option<PathBuf>,
}

/// Attach worker/step context to a failed collective round, plus the
/// recovery runbook when the failure looks like a dead peer process
/// (distributed mode): every rank must restart with `--resume auto`
/// so the ring reassembles from the newest complete checkpoint set.
fn exchange_error_context(e: Error, worker: usize, step: usize) -> Error {
    match e {
        Error::Timeout(m) => Error::Timeout(format!(
            "worker {worker}, step {step}: {m}; a peer process likely died — \
             restart every rank with --resume auto to reassemble the run"
        )),
        Error::Protocol(m) => Error::Protocol(format!("worker {worker}, step {step}: {m}")),
        other => other,
    }
}

/// Per-step RNG seed for worker `worker` at `step`: a SplitMix64-style
/// finalizer over the full-width `(seed, step, worker)` triple,
/// truncated to the backend ABI's i32 only *after* mixing.
///
/// The seed's high bits and every step/worker bit reach all output
/// bits, unlike the old `(seed as i32) ^ (step as i32) ^ (worker << 20)`
/// scheme, which discarded the upper seed word and collided
/// structurally once `step >= 2^20` (step bit 20 was indistinguishable
/// from worker bit 0 — two different (step, worker) pairs shared the
/// dropout stream).
pub fn step_seed(seed: u64, step: u64, worker: u64) -> i32 {
    let mut z = seed
        .wrapping_add(step.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(worker.wrapping_mul(0xD1B5_4A32_D192_ED03));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) as i32
}

/// Build this worker's batch source per the configured loader mode,
/// fast-forwarded past `skip_batches` already-trained steps.
fn build_loader(
    cfg: &TrainConfig,
    worker: usize,
    crop_hw: usize,
    skip_batches: usize,
) -> Result<Box<dyn BatchSource>> {
    let lcfg = LoaderCfg {
        data_dir: &cfg.data.dir,
        split: "train",
        batch: cfg.batch_per_worker,
        crop_hw,
        worker,
        workers: cfg.cluster.workers,
        seed: cfg.seed,
        train_augment: true,
        verify_shards: false,
    };
    Ok(match cfg.loader_mode {
        LoaderMode::Parallel => Box::new(ParallelLoader::resumed(&lcfg, skip_batches)?),
        LoaderMode::Serial => Box::new(SerialLoader::resumed(&lcfg, skip_batches)?),
    })
}

/// Adapter from the backend's per-parameter gradient emissions to the
/// exchanger's flat-layout watermark: `param` index → layout offset
/// via the manifest prefix sums, completed buckets stream to the
/// collective as backward runs.
struct BucketSink<'a> {
    exchanger: &'a mut GradExchanger,
    /// `params.len() + 1` prefix offsets of the flat gradient layout.
    offsets: &'a [usize],
}

impl GradSink for BucketSink<'_> {
    fn grad_ready(&mut self, param: usize, grad: &[f32]) -> Result<()> {
        let span = self
            .offsets
            .get(param + 1)
            .map(|hi| hi - self.offsets[param]);
        if span != Some(grad.len()) {
            return Err(Error::Shape(format!(
                "grad_ready: param {param} with {} values does not match the layout",
                grad.len()
            )));
        }
        self.exchanger.grad_ready(self.offsets[param], grad)
    }
}

/// Hard compatibility checks for restoring `info` (parsed from `ckpt`)
/// as worker `worker` under `cfg`.  Shared by the worker's restore and
/// the trainer's pre-flight (which runs these against *peeked* headers
/// before any side effect like the metrics-CSV trim — a resume that
/// will fail must fail with nothing mutated).
pub fn validate_restore(
    cfg: &TrainConfig,
    worker: usize,
    ckpt: &Path,
    info: &crate::params::CheckpointInfo,
) -> Result<()> {
    let start = info.step as usize;
    if start >= cfg.steps {
        return Err(Error::Checkpoint(format!(
            "{ckpt:?} is at step {start}, but the run ends at --steps {}; \
             raise --steps to continue training",
            cfg.steps
        )));
    }
    if let Some(st) = &info.state {
        if st.workers as usize != cfg.cluster.workers {
            return Err(Error::Checkpoint(format!(
                "{ckpt:?} was saved by a {}-worker run; resuming with {} would \
                 change the data partition (not bit-exact)",
                st.workers, cfg.cluster.workers
            )));
        }
        if st.exchange_fingerprint != cfg.resume_fingerprint() {
            return Err(Error::Checkpoint(format!(
                "{ckpt:?}: resume-critical config changed since the checkpoint \
                 (workers/period/momentum/batch/dropout/seed must match for a \
                 bit-exact resume)"
            )));
        }
        if st.worker as usize == worker {
            let (epoch, next_batch) = EpochSampler::position_after(
                cfg.data.train_examples,
                cfg.batch_per_worker,
                worker,
                cfg.cluster.workers,
                start,
            );
            if (epoch, next_batch) != (st.sampler_epoch, st.sampler_next_batch) {
                return Err(Error::Checkpoint(format!(
                    "{ckpt:?}: sampler position (epoch {}, batch {}) does not match \
                     this data configuration's (epoch {epoch}, batch {next_batch}) — \
                     did the dataset size change?",
                    st.sampler_epoch, st.sampler_next_batch
                )));
            }
        }
    }
    Ok(())
}

/// Load `ckpt` into `store` and validate it against this run's config;
/// returns the step to resume at.
fn restore_worker_state(
    cfg: &TrainConfig,
    worker: usize,
    ckpt: &Path,
    store: &mut ParamStore,
) -> Result<usize> {
    let info = load_checkpoint_full(ckpt, store)?;
    validate_restore(cfg, worker, ckpt, &info)?;
    let start = info.step as usize;
    match info.state {
        Some(st) => {
            if st.worker as usize != worker
                && !(cfg.exchange.period == 1 && cfg.exchange.include_momentum)
            {
                // Restoring another replica's state is only bit-exact
                // when replicas are fully synchronized every step.
                log::warn!(
                    "worker {worker}: restoring replica-{} state with exchange period {} / \
                     include_momentum {} — replicas were not bit-synchronized, so this \
                     resume is approximate (use the per-worker .w{worker}.ckpt snapshots \
                     for exactness)",
                    st.worker,
                    cfg.exchange.period,
                    cfg.exchange.include_momentum
                );
            }
            let lr_now = cfg.schedule.lr_at(start);
            if lr_now.to_bits() != st.lr.to_bits() {
                log::warn!(
                    "worker {worker}: LR schedule changed since the checkpoint \
                     (saved lr {} at step {start}, schedule now gives {lr_now})",
                    st.lr
                );
            }
        }
        None => log::warn!(
            "worker {worker}: {ckpt:?} is a v1 checkpoint without lifecycle state; \
             resuming without config cross-checks"
        ),
    }
    log::info!("worker {worker}: restored {ckpt:?}, resuming at step {start}");
    Ok(start)
}

/// The worker thread body: runs steps `start..cfg.steps` with a
/// collective exchange every `cfg.exchange.period` steps.
pub fn run_worker(spec: WorkerSpec) -> Result<WorkerOutcome> {
    let WorkerSpec { fabric, worker, cfg, reports, restore } = spec;
    let workers = cfg.cluster.workers;

    // --- Setup (the paper's per-GPU Theano process initialization):
    // --- each replica owns its backend, parameters and loader. ---
    let mut backend = crate::backend::build_backend(&cfg)?;
    let model = backend.model().clone();

    let mut store = ParamStore::init(&model.params, cfg.seed);
    let mut start_step = 0usize;
    if let Some(ckpt) = &restore {
        start_step = restore_worker_state(&cfg, worker, ckpt, &mut store)?;
    }

    // Guard the label space: a corpus with more classes than the model
    // produces out-of-range gathers (NaN losses) inside the step.
    let meta_path = cfg.data.dir.join("meta.json");
    if let Ok(src) = std::fs::read_to_string(&meta_path) {
        let meta = crate::data::synth::DatasetMeta::from_json(&src)?;
        if meta.classes > model.num_classes {
            return Err(Error::msg(format!(
                "dataset at {:?} has {} classes but model {:?} expects {}",
                cfg.data.dir, meta.classes, model.name, model.num_classes
            )));
        }
    }

    let mut loader = build_loader(&cfg, worker, model.image_hw, start_step)?;

    // --- Exchange protocol selection.  Overlap (stream or serial)
    // --- switches period-1 synchronization from post-step parameter
    // --- averaging to bucketed *gradient* averaging before the update;
    // --- backends without the staged step fall back with a warning
    // --- (the XLA path's AOT executable fuses the whole step). ---
    let world = fabric.world_size();
    let mut use_staged = cfg.exchange.overlap.is_gradient_exchange() && world > 1;
    if use_staged && !backend.supports_staged_step() {
        log::warn!(
            "worker {worker}: backend {:?} does not implement the staged step \
             protocol; --overlap falls back to compute-then-exchange parameter \
             averaging",
            backend.name()
        );
        use_staged = false;
    }
    // Flat-layout prefix offsets of the parameter manifest — bucket
    // boundaries and gradient scatter both address through this table.
    let mut offsets = Vec::with_capacity(model.params.len() + 1);
    offsets.push(0usize);
    for p in &model.params {
        offsets.push(offsets.last().unwrap() + p.shape.numel());
    }
    let (mut fabric, mut exchanger) = if use_staged {
        let ex = GradExchanger::new(
            fabric,
            store.total_elements(),
            cfg.exchange.bucket_elems,
            cfg.exchange.overlap == OverlapMode::Stream,
        );
        (None, Some(ex))
    } else {
        (Some(fabric), None)
    };

    let fingerprint = cfg.resume_fingerprint();
    let include_momentum = cfg.exchange.include_momentum;
    let mut compute_seconds = 0.0;
    let mut exchange_seconds = 0.0;
    // Best validation top-1 error among *checkpointed* evals.  A
    // resumed run seeds it from the BEST marker so a worse post-resume
    // eval can neither displace the marker nor expose the historical
    // best step to retention pruning.
    let mut best_ckpt_top1 = match (&restore, &cfg.checkpoint_dir) {
        (Some(_), Some(dir)) => best_marker_error(dir).unwrap_or(f32::INFINITY),
        _ => f32::INFINITY,
    };

    // --- The step loop (Fig 1 + Fig 2 composed) ---
    for step in start_step..cfg.steps {
        let step_timer = Timer::start();
        let batch = loader.next_batch()?;
        let lr = cfg.schedule.lr_at(step);
        let seed = step_seed(cfg.seed, step as u64, worker as u64);

        let mut dt_exchange = 0.0;
        let mut dt_overlap = 0.0;
        let mut dt_exposed = 0.0;
        let out = match exchanger.as_mut() {
            // --- Staged protocol: backward streams gradient buckets
            // --- into the collective; the join barrier then hands the
            // --- group-averaged gradients to the SGD update, so every
            // --- replica applies the identical synchronized step. ---
            Some(ex) => {
                let before = ex.stats();
                let t_compute = Timer::start();
                let out = {
                    let mut sink = BucketSink { exchanger: ex, offsets: &offsets };
                    backend.forward_backward(
                        &batch.images,
                        &batch.labels,
                        seed,
                        &store,
                        &mut sink,
                    )?
                };
                compute_seconds += t_compute.elapsed_secs();
                let t_ex = Timer::start();
                let flat = ex.join().map_err(|e| exchange_error_context(e, worker, step))?;
                dt_exchange = t_ex.elapsed_secs();
                exchange_seconds += dt_exchange;
                let t_upd = Timer::start();
                backend.apply_update(&mut store, lr, flat)?;
                compute_seconds += t_upd.elapsed_secs();
                let after = ex.stats();
                dt_overlap = after.overlapped_seconds - before.overlapped_seconds;
                dt_exposed = after.exposed_seconds - before.exposed_seconds;
                out
            }
            None => {
                let t_compute = Timer::start();
                let out =
                    backend.train_step(&batch.images, &batch.labels, lr, seed, &mut store)?;
                compute_seconds += t_compute.elapsed_secs();

                // --- Collective exchange at the configured period
                // --- (Fig 2 for N = 2, ring all-reduce beyond) ---
                let fabric = fabric.as_mut().expect("non-staged worker keeps its fabric");
                if fabric.world_size() > 1 && (step + 1) % cfg.exchange.period == 0 {
                    let t_ex = Timer::start();
                    fabric
                        .all_reduce_average(&mut store, include_momentum)
                        .map_err(|e| exchange_error_context(e, worker, step))?;
                    dt_exchange = t_ex.elapsed_secs();
                    exchange_seconds += dt_exchange;
                }
                out
            }
        };

        if !out.loss.is_finite() {
            return Err(Error::msg(format!(
                "worker {worker}: non-finite loss {} at step {step} (lr too high?)",
                out.loss
            )));
        }

        let _ = reports.send(WorkerMsg::Step(StepRecord {
            worker,
            step,
            loss: out.loss,
            correct1: out.correct1,
            batch: batch.labels.len(),
            lr,
            step_seconds: step_timer.elapsed_secs(),
            exchange_seconds: dt_exchange,
            overlap_seconds: dt_overlap,
            exposed_seconds: dt_exposed,
        }));

        let done = step + 1;

        // --- Periodic per-worker snapshot (post-exchange: at period 1
        // --- all replicas agree here, so any file restores any run) ---
        let on_checkpoint = cfg.checkpoint_every > 0 && done % cfg.checkpoint_every == 0;
        if on_checkpoint {
            if let Some(dir) = &cfg.checkpoint_dir {
                let (sampler_epoch, sampler_next_batch) = EpochSampler::position_after(
                    cfg.data.train_examples,
                    cfg.batch_per_worker,
                    worker,
                    workers,
                    done,
                );
                let state = TrainState {
                    step: done as u64,
                    worker: worker as u32,
                    workers: workers as u32,
                    exchange_fingerprint: fingerprint,
                    sampler_epoch,
                    sampler_next_batch,
                    lr: cfg.schedule.lr_at(done),
                };
                let fname = periodic_checkpoint_name(&cfg.name, done, worker);
                save_checkpoint_v2(&dir.join(&fname), &store, &state)?;
                if worker == 0 {
                    write_marker(dir, LATEST_MARKER, &fname)?;
                    let removed =
                        prune_checkpoints(dir, &cfg.name, workers, cfg.checkpoint_keep, done)?;
                    if removed > 0 {
                        log::debug!("retention: pruned {removed} checkpoint file(s)");
                    }
                }
            }
        }

        // --- Mid-training validation (worker 0 only; the final step's
        // --- eval belongs to the trainer's summary) ---
        // Gated on a non-empty val split like the trainer's final eval:
        // a validation knob must never abort a training run that has no
        // held-out data to validate on.
        if worker == 0
            && cfg.eval_every > 0
            && done % cfg.eval_every == 0
            && done < cfg.steps
            && backend.supports_eval()
            && cfg.data.val_examples > 0
        {
            if let Some(result) =
                crate::coordinator::eval::evaluate(&cfg, backend.as_mut(), &store, 0)?
            {
                // BEST tracks the best *checkpointed* model, so only an
                // eval that lands on a checkpoint step competes — an
                // off-cadence eval has no file to point the marker at
                // and must not poison the comparison.
                if on_checkpoint && result.top1_error() < best_ckpt_top1 {
                    best_ckpt_top1 = result.top1_error();
                    if let Some(dir) = &cfg.checkpoint_dir {
                        write_marker(
                            dir,
                            BEST_MARKER,
                            &format!(
                                "{} top1_error={:.6}",
                                periodic_checkpoint_name(&cfg.name, done, 0),
                                best_ckpt_top1
                            ),
                        )?;
                    }
                }
                let _ = reports.send(WorkerMsg::Eval { step: done, result });
            }
        }
    }

    let collective = match exchanger {
        Some(ex) => ex.finish().map_err(|e| exchange_error_context(e, worker, cfg.steps))?,
        None => fabric.as_ref().expect("non-staged worker keeps its fabric").stats(),
    };
    Ok(WorkerOutcome {
        worker,
        steps: cfg.steps.saturating_sub(start_step),
        store,
        loader: loader.stats(),
        collective,
        exchange_seconds,
        compute_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Regression for the truncating XOR scheme: `step ^ (worker << 20)`
    /// made (step + 2^20, worker) collide with (step, worker + 1), and
    /// `seed as i32` dropped the seed's upper 32 bits entirely.
    #[test]
    fn step_seed_has_no_structural_collisions() {
        // The old scheme's exact collision pair.
        assert_ne!(step_seed(42, 1 << 20, 0), step_seed(42, 0, 1));
        // High seed bits must matter.
        assert_ne!(step_seed(7, 3, 0), step_seed(7 | (1 << 40), 3, 0));
        // Dense uniqueness sweep around the old 2^20 wraparound plus a
        // low-step grid: all (step, worker) pairs get distinct seeds.
        let mut seen = HashSet::new();
        for &base in &[0u64, (1 << 20) - 2] {
            for step in base..base + 64 {
                for worker in 0..8u64 {
                    assert!(
                        seen.insert(step_seed(99, step, worker)),
                        "collision at step {step}, worker {worker}"
                    );
                }
            }
        }
        // Deterministic.
        assert_eq!(step_seed(5, 6, 7), step_seed(5, 6, 7));
    }
}
