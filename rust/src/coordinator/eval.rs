//! Validation evaluation: center-crop, no flip, top-1/top-5 counts.
//!
//! Mirrors the paper's §3 measurement ("top-1 class validation error
//! rate is 42.6%, top-5 is 19.9%") on the substituted corpus, through
//! whichever [`StepBackend`] the config selects.
//!
//! The split is walked **sequentially and completely**: evaluation
//! needs no shuffle, and the final partial batch is evaluated too
//! (backends with a variable batch, i.e. the native path), so the
//! reported error rates cover the *true* example count.  Only a
//! fixed-batch compiled backend has to drop the ragged tail — and says
//! so in the log instead of silently shrinking the denominator.

use crate::backend::StepBackend;
use crate::config::TrainConfig;
use crate::data::loader::open_split;
use crate::data::preprocess::{preprocess_into, Augment};
use crate::error::Result;
use crate::params::ParamStore;
use crate::tensor::{HostTensor, Shape};

/// Aggregate eval result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub examples: usize,
    pub mean_loss: f32,
    pub top1_correct: usize,
    pub top5_correct: usize,
}

impl EvalResult {
    pub fn top1_error(&self) -> f32 {
        1.0 - self.top1_correct as f32 / self.examples.max(1) as f32
    }

    pub fn top5_error(&self) -> f32 {
        1.0 - self.top5_correct as f32 / self.examples.max(1) as f32
    }
}

/// Run the backend's eval forward over the validation split.
///
/// `max_batches = 0` means the full split, including the ragged final
/// batch when the backend accepts a variable batch size.  A nonzero
/// `max_batches` caps the number of (full-size) batches — the quick
/// spot-check mode of `tmg eval --max-batches N`.
///
/// `mean_loss` is example-weighted, so the partial batch contributes
/// in proportion to its size.
pub fn evaluate(
    cfg: &TrainConfig,
    backend: &mut dyn StepBackend,
    store: &ParamStore,
    max_batches: usize,
) -> Result<EvalResult> {
    let fixed = backend.eval_batch_size();
    let batch = fixed.unwrap_or(cfg.batch_per_worker).max(1);
    let crop_hw = backend.model().image_hw;
    let (mut dataset, mean) = open_split(&cfg.data.dir, "val", crop_hw, false)?;
    let stored_hw = dataset.height;
    let channels = dataset.channels;
    let total = dataset.len();

    let mut out = EvalResult::default();
    let mut loss_sum = 0f64;
    let mut pix_buf: Vec<u8> = Vec::new();
    let stride = channels * crop_hw * crop_hw;
    let mut start = 0usize;
    let mut batches = 0usize;
    while start < total {
        if max_batches > 0 && batches >= max_batches {
            break;
        }
        let n = (total - start).min(batch);
        if n < batch && fixed.is_some() {
            log::warn!(
                "eval: backend {:?} has a fixed batch of {batch}; dropping the ragged \
                 tail of {n} example(s) — reported rates cover {} of {total}",
                backend.name(),
                out.examples
            );
            break;
        }
        let mut images = HostTensor::zeros(Shape::of(&[n, channels, crop_hw, crop_hw]));
        let mut labels = Vec::with_capacity(n);
        let slice = images.as_mut_slice();
        for bi in 0..n {
            let label = dataset.read_into(start + bi, &mut pix_buf)?;
            preprocess_into(
                &pix_buf,
                &mean,
                stored_hw,
                crop_hw,
                Augment::center(stored_hw, crop_hw),
                &mut slice[bi * stride..(bi + 1) * stride],
            )?;
            labels.push(label as i32);
        }
        let r = backend.eval_batch(&images, &labels, store)?;
        loss_sum += r.loss as f64 * n as f64;
        out.top1_correct += r.top1 as usize;
        out.top5_correct += r.top5 as usize;
        out.examples += n;
        start += n;
        batches += 1;
    }
    out.mean_loss = if out.examples > 0 {
        (loss_sum / out.examples as f64) as f32
    } else {
        0.0
    };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rates() {
        let r = EvalResult { examples: 200, mean_loss: 1.0, top1_correct: 80, top5_correct: 150 };
        assert!((r.top1_error() - 0.6).abs() < 1e-6);
        assert!((r.top5_error() - 0.25).abs() < 1e-6);
        let empty = EvalResult::default();
        assert_eq!(empty.top1_error(), 1.0);
    }
}
