//! Validation evaluation: center-crop, no flip, top-1/top-5 counts.
//!
//! Mirrors the paper's §3 measurement ("top-1 class validation error
//! rate is 42.6%, top-5 is 19.9%") on the substituted corpus, through
//! whichever [`StepBackend`] the config selects.
//!
//! The module is split in two:
//!
//! - [`Engine`] — the reusable core: stage raw `u8` pixels through
//!   center-crop preprocessing into one long-lived f32 batch buffer,
//!   then run the backend's eval forward (counts) or per-example
//!   prediction (top-k scores) on the staged batch.  The buffer is
//!   hoisted across batches — steady state allocates nothing — and the
//!   serve hot path drives the exact same code, so `tmg serve` answers
//!   are bit-identical to `tmg eval` on the same parameters.
//! - [`evaluate`] — the split-walking wrapper: sequentially and
//!   completely walks the validation split, including the ragged final
//!   batch when the backend takes a variable batch size.  Only a
//!   fixed-batch compiled backend drops the tail — and says so in the
//!   log instead of silently shrinking the denominator.
//!
//! An empty or absent validation split is `Ok(None)`, **not** a zeroed
//! result: `EvalResult::default()` reads as 100% error, and callers
//! used to log that fiction.

use crate::backend::{EvalBatchOut, StepBackend, TopK};
use crate::config::TrainConfig;
use crate::data::loader::open_split_optional;
use crate::data::preprocess::{preprocess_into, Augment, MeanImage};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::tensor::{HostTensor, Shape};

/// Aggregate eval result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub examples: usize,
    pub mean_loss: f32,
    pub top1_correct: usize,
    pub top5_correct: usize,
}

impl EvalResult {
    pub fn top1_error(&self) -> f32 {
        1.0 - self.top1_correct as f32 / self.examples.max(1) as f32
    }

    pub fn top5_error(&self) -> f32 {
        1.0 - self.top5_correct as f32 / self.examples.max(1) as f32
    }
}

/// Preprocess-and-evaluate core shared by `tmg eval` and the serve
/// replicas.
///
/// Borrows the backend (callers own it — the trainer reuses its
/// training backend for mid-run validation; a serve replica keeps its
/// own on the thread stack) and owns the preprocessing state: the mean
/// image, the geometry, and one reusable staging buffer that grows to
/// the largest batch seen and is then recycled forever.
pub struct Engine<'b> {
    backend: &'b mut dyn StepBackend,
    mean: MeanImage,
    stored_hw: usize,
    crop_hw: usize,
    /// Staged NCHW f32 batch; lives across batches (the buffer-churn
    /// fix — the old loop allocated a fresh tensor every batch).
    buf: Vec<f32>,
    staged: usize,
}

impl<'b> Engine<'b> {
    /// Wrap a backend with preprocessing state.  The crop size comes
    /// from the backend's model; `stored_hw`/`mean` describe the corpus.
    pub fn new(
        backend: &'b mut dyn StepBackend,
        mean: MeanImage,
        stored_hw: usize,
    ) -> Result<Engine<'b>> {
        let crop_hw = backend.model().image_hw;
        if crop_hw > stored_hw {
            return Err(Error::Shape(format!(
                "crop {crop_hw} larger than stored image {stored_hw}"
            )));
        }
        if mean.channels == 0 || mean.hw != stored_hw {
            return Err(Error::Shape(format!(
                "mean image {}x{} does not match stored images ({stored_hw})",
                mean.channels, mean.hw
            )));
        }
        Ok(Engine { backend, mean, stored_hw, crop_hw, buf: Vec::new(), staged: 0 })
    }

    pub fn backend_name(&self) -> String {
        self.backend.name().to_string()
    }

    pub fn channels(&self) -> usize {
        self.mean.channels
    }

    pub fn stored_hw(&self) -> usize {
        self.stored_hw
    }

    pub fn crop_hw(&self) -> usize {
        self.crop_hw
    }

    /// Raw request payload size: one stored image, `u8` per pixel.
    pub fn input_bytes(&self) -> usize {
        self.mean.channels * self.stored_hw * self.stored_hw
    }

    /// Elements one preprocessed example occupies in the staged batch.
    fn row_elems(&self) -> usize {
        self.mean.channels * self.crop_hw * self.crop_hw
    }

    /// Open a batch of `n` examples to stage into.  Grows the buffer if
    /// this is the largest batch yet; otherwise reuses it in place.
    pub fn begin(&mut self, n: usize) {
        self.staged = n;
        self.buf.resize(n * self.row_elems(), 0.0);
    }

    /// Center-crop + mean-subtract one example's raw pixels into slot
    /// `bi` of the open batch.
    pub fn stage(&mut self, bi: usize, pixels: &[u8]) -> Result<()> {
        if bi >= self.staged {
            return Err(Error::msg(format!(
                "stage slot {bi} outside open batch of {}",
                self.staged
            )));
        }
        let stride = self.row_elems();
        let (lo, hi) = (bi * stride, (bi + 1) * stride);
        preprocess_into(
            pixels,
            &self.mean,
            self.stored_hw,
            self.crop_hw,
            Augment::center(self.stored_hw, self.crop_hw),
            &mut self.buf[lo..hi],
        )
    }

    /// Shape the staged buffer as a tensor without copying, run `f`,
    /// and reclaim the buffer afterwards — even when `f` fails.
    fn with_staged<T>(
        &mut self,
        f: impl FnOnce(&mut dyn StepBackend, &HostTensor) -> Result<T>,
    ) -> Result<T> {
        let shape =
            Shape::of(&[self.staged, self.mean.channels, self.crop_hw, self.crop_hw]);
        let images = HostTensor::from_vec(shape, std::mem::take(&mut self.buf))?;
        let r = f(self.backend, &images);
        self.buf = images.into_vec();
        r
    }

    /// Eval forward over the staged batch: mean loss + top-1/top-5
    /// correct counts against `labels`.
    pub fn eval_staged(&mut self, labels: &[i32], store: &ParamStore) -> Result<EvalBatchOut> {
        if labels.len() != self.staged {
            return Err(Error::msg(format!(
                "{} labels for a staged batch of {}",
                labels.len(),
                self.staged
            )));
        }
        self.with_staged(|backend, images| backend.eval_batch(images, labels, store))
    }

    /// Per-example top-`k` classes + softmax scores for the staged
    /// batch (the serve path; needs `supports_predict`).
    pub fn classify_staged(&mut self, store: &ParamStore, k: usize) -> Result<Vec<TopK>> {
        self.with_staged(|backend, images| backend.predict_batch(images, store, k))
    }
}

/// Run the backend's eval forward over the validation split.
///
/// `max_batches = 0` means the full split, including the ragged final
/// batch when the backend accepts a variable batch size.  A nonzero
/// `max_batches` caps the number of (full-size) batches — the quick
/// spot-check mode of `tmg eval --max-batches N`.
///
/// `mean_loss` is example-weighted, so the partial batch contributes
/// in proportion to its size.
///
/// Returns `Ok(None)` when there is nothing to evaluate: the val split
/// is absent (corpus generated with `--val 0`) or empty, or a
/// fixed-batch backend dropped every example as a ragged tail.
pub fn evaluate(
    cfg: &TrainConfig,
    backend: &mut dyn StepBackend,
    store: &ParamStore,
    max_batches: usize,
) -> Result<Option<EvalResult>> {
    let fixed = backend.eval_batch_size();
    let batch = fixed.unwrap_or(cfg.batch_per_worker).max(1);
    let crop_hw = backend.model().image_hw;
    let Some((mut dataset, mean)) = open_split_optional(&cfg.data.dir, "val", crop_hw, false)?
    else {
        return Ok(None);
    };
    let stored_hw = dataset.height;
    let total = dataset.len();
    let backend_label = backend.name().to_string();
    let mut engine = Engine::new(backend, mean, stored_hw)?;

    let mut out = EvalResult::default();
    let mut loss_sum = 0f64;
    let mut pix_buf: Vec<u8> = Vec::new();
    let mut start = 0usize;
    let mut batches = 0usize;
    while start < total {
        if max_batches > 0 && batches >= max_batches {
            break;
        }
        let n = (total - start).min(batch);
        if n < batch && fixed.is_some() {
            log::warn!(
                "eval: backend {backend_label:?} has a fixed batch of {batch}; dropping \
                 the ragged tail of {n} example(s) — reported rates cover {} of {total}",
                out.examples
            );
            break;
        }
        engine.begin(n);
        let mut labels = Vec::with_capacity(n);
        for bi in 0..n {
            let label = dataset.read_into(start + bi, &mut pix_buf)?;
            engine.stage(bi, &pix_buf)?;
            labels.push(label as i32);
        }
        let r = engine.eval_staged(&labels, store)?;
        loss_sum += r.loss as f64 * n as f64;
        out.top1_correct += r.top1 as usize;
        out.top5_correct += r.top5 as usize;
        out.examples += n;
        start += n;
        batches += 1;
    }
    if out.examples == 0 {
        return Ok(None);
    }
    out.mean_loss = (loss_sum / out.examples as f64) as f32;
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rates() {
        let r = EvalResult { examples: 200, mean_loss: 1.0, top1_correct: 80, top5_correct: 150 };
        assert!((r.top1_error() - 0.6).abs() < 1e-6);
        assert!((r.top5_error() - 0.25).abs() < 1e-6);
    }
}
