//! Validation evaluation: center-crop, no flip, top-1/top-5 counts.
//!
//! Mirrors the paper's §3 measurement ("top-1 class validation error
//! rate is 42.6%, top-5 is 19.9%") on the substituted corpus.

use crate::config::TrainConfig;
use crate::data::loader::{BatchSource, LoaderCfg, SerialLoader};
use crate::error::Result;
use crate::params::ParamStore;
use crate::runtime::literal_bridge::{
    i32_to_literal, literal_f32, literal_i32, tensor_to_literal,
};
use crate::runtime::StepExecutable;

/// Aggregate eval result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub examples: usize,
    pub mean_loss: f32,
    pub top1_correct: usize,
    pub top5_correct: usize,
}

impl EvalResult {
    pub fn top1_error(&self) -> f32 {
        1.0 - self.top1_correct as f32 / self.examples.max(1) as f32
    }

    pub fn top5_error(&self) -> f32 {
        1.0 - self.top5_correct as f32 / self.examples.max(1) as f32
    }
}

/// Run the eval executable over (a prefix of) the validation split.
///
/// `max_batches = 0` means the full split (floor to whole batches —
/// the fixed-batch compiled function cannot take a ragged tail).
pub fn evaluate(
    cfg: &TrainConfig,
    eval_exe: &StepExecutable,
    store: &ParamStore,
    crop_hw: usize,
    max_batches: usize,
) -> Result<EvalResult> {
    let batch = eval_exe.spec.batch_size;
    let lcfg = LoaderCfg {
        data_dir: &cfg.data.dir,
        split: "val",
        batch,
        crop_hw,
        worker: 0,
        workers: 1,
        seed: cfg.seed,
        train_augment: false, // center crop, no flip
        verify_shards: false,
    };
    let mut loader = SerialLoader::new(&lcfg)?;
    let total_batches = cfg.data.val_examples / batch;
    let n_batches = if max_batches == 0 {
        total_batches
    } else {
        total_batches.min(max_batches)
    };

    let mut out = EvalResult::default();
    let mut loss_sum = 0f64;
    for _ in 0..n_batches {
        let b = loader.next_batch()?;
        let mut inputs = Vec::with_capacity(2 + store.n_tensors());
        inputs.push(tensor_to_literal(&b.images)?);
        inputs.push(i32_to_literal(&b.labels)?);
        for p in &store.params {
            inputs.push(tensor_to_literal(p)?);
        }
        let outs = eval_exe.run(&inputs)?;
        loss_sum += literal_f32(&outs[0])? as f64;
        out.top1_correct += literal_i32(&outs[1])? as usize;
        out.top5_correct += literal_i32(&outs[2])? as usize;
        out.examples += b.labels.len();
    }
    out.mean_loss = if n_batches > 0 { (loss_sum / n_batches as f64) as f32 } else { 0.0 };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rates() {
        let r = EvalResult { examples: 200, mean_loss: 1.0, top1_correct: 80, top5_correct: 150 };
        assert!((r.top1_error() - 0.6).abs() < 1e-6);
        assert!((r.top5_error() - 0.25).abs() < 1e-6);
        let empty = EvalResult::default();
        assert_eq!(empty.top1_error(), 1.0);
    }
}
