//! Validation evaluation: center-crop, no flip, top-1/top-5 counts.
//!
//! Mirrors the paper's §3 measurement ("top-1 class validation error
//! rate is 42.6%, top-5 is 19.9%") on the substituted corpus, through
//! whichever [`StepBackend`] the config selects.

use crate::backend::StepBackend;
use crate::config::TrainConfig;
use crate::data::loader::{BatchSource, LoaderCfg, SerialLoader};
use crate::error::Result;
use crate::params::ParamStore;

/// Aggregate eval result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub examples: usize,
    pub mean_loss: f32,
    pub top1_correct: usize,
    pub top5_correct: usize,
}

impl EvalResult {
    pub fn top1_error(&self) -> f32 {
        1.0 - self.top1_correct as f32 / self.examples.max(1) as f32
    }

    pub fn top5_error(&self) -> f32 {
        1.0 - self.top5_correct as f32 / self.examples.max(1) as f32
    }
}

/// Run the backend's eval forward over (a prefix of) the validation
/// split.
///
/// `max_batches = 0` means the full split (floor to whole batches —
/// a fixed-batch compiled step cannot take a ragged tail, and the
/// native path keeps the same convention).
pub fn evaluate(
    cfg: &TrainConfig,
    backend: &mut dyn StepBackend,
    store: &ParamStore,
    max_batches: usize,
) -> Result<EvalResult> {
    let batch = backend.eval_batch_size().unwrap_or(cfg.batch_per_worker).max(1);
    let crop_hw = backend.model().image_hw;
    let lcfg = LoaderCfg {
        data_dir: &cfg.data.dir,
        split: "val",
        batch,
        crop_hw,
        worker: 0,
        workers: 1,
        seed: cfg.seed,
        train_augment: false, // center crop, no flip
        verify_shards: false,
    };
    let mut loader = SerialLoader::new(&lcfg)?;
    let total_batches = cfg.data.val_examples / batch;
    let n_batches = if max_batches == 0 {
        total_batches
    } else {
        total_batches.min(max_batches)
    };

    let mut out = EvalResult::default();
    let mut loss_sum = 0f64;
    for _ in 0..n_batches {
        let b = loader.next_batch()?;
        let r = backend.eval_batch(&b.images, &b.labels, store)?;
        loss_sum += r.loss as f64;
        out.top1_correct += r.top1 as usize;
        out.top5_correct += r.top5 as usize;
        out.examples += b.labels.len();
    }
    out.mean_loss = if n_batches > 0 { (loss_sum / n_batches as f64) as f32 } else { 0.0 };
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rates() {
        let r = EvalResult { examples: 200, mean_loss: 1.0, top1_correct: 80, top5_correct: 150 };
        assert!((r.top1_error() - 0.6).abs() < 1e-6);
        assert!((r.top5_error() - 0.25).abs() < 1e-6);
        let empty = EvalResult::default();
        assert_eq!(empty.top1_error(), 1.0);
    }
}
