//! Packed, register-blocked single-precision GEMM.
//!
//! Three accumulating products cover everything the AlexNet
//! forward/backward pass needs (conv-as-GEMM over im2col columns and
//! the fully-connected layers):
//!
//! - `nn`: `C += A · B`            (conv forward, FC dX)
//! - `nt`: `C += A · Bᵀ`           (FC forward, conv dW)
//! - `tn`: `C += Aᵀ · B`           (FC dW, conv dCol)
//!
//! All three run through **one microkernel**: an `MR×NR` register tile
//! with fully unrolled, independent accumulators, fed by packed operand
//! panels.  The kernel itself is an explicit SIMD routine picked once
//! per process from the [`MicroKernel`] dispatch table (AVX2+FMA, NEON,
//! or the portable safe-Rust loop — see
//! [`active_isa`](crate::backend::native::simd::active_isa) and the
//! `TMG_GEMM_ISA` override).  The `nn`/`nt`/`tn` variants differ *only* in the
//! [`pack_a_strip`]/[`pack_b_strip`] routines, which stage A row-panels
//! and B column-panels into the contiguous [`PackBuf`] workspace in
//! k-major micro-panel order (transposition is free at packing time).
//! Short panels are zero-padded to full `MR`/`NR` width, so the kernel
//! has no edge branches; padded lanes accumulate exact zeros and are
//! never written back.
//!
//! Cache blocking follows the classic GOTO/BLIS schedule: `KC`-deep
//! slices keep a packed B panel of `NC` columns L2/L3-resident while
//! `MC`-row A panels stream through it.  `C` accumulates across `KC`
//! slices, so callers still control zeroing exactly as before.
//!
//! ## Determinism contract
//!
//! Every output element is produced by a fixed instruction sequence:
//! `k` is consumed in increasing order within each `KC` slice, and the
//! slices accumulate into `C` in increasing `pc` order.  Tile
//! boundaries (row strips, column groups, `KC`/`MC`/`NC` blocks) derive
//! from the problem shape and compile-time constants only — never from
//! the lane count — and tiles write disjoint `C` regions.  The
//! `par_matmul_*` forms therefore produce **bit-identical** results to
//! the `matmul_*_ws` serial forms for any `--threads` value (the
//! `assert_eq` contract `tests/parallel_backend.rs` pins), and every
//! shape is reproducible run-to-run.  The contract is **per-ISA**: the
//! kernel choice is uniform across lanes for a run, but FMA kernels
//! legitimately round differently from the portable fallback, and the
//! summation order differs from the pre-packing scalar kernels (kept in
//! [`scalar`] for benchmarking and reference) — so cross-kernel and
//! cross-ISA comparisons are rounding-tolerant, never bitwise.
//!
//! The ReLU-sparsity zero-skip the scalar kernels carried is
//! deliberately **dropped** here: a per-multiplier branch inside the
//! microkernel defeats vectorization and register blocking, which is
//! worth far more than the skipped multiplies.  Re-examined for the
//! explicit SIMD kernels: `benches/gemm_kernels.rs` still measures the
//! skip-carrying scalar kernels against the dispatched kernel on two
//! 50%-sparse operands (`fc1-dx-sparse50`, `fc1-dw-sparse50`) to keep
//! the decision honest per-ISA.

use crate::backend::native::pool::{ComputePool, SendPtr};
use crate::backend::native::simd::MicroKernel;
use crate::util::math::{ceil_div, ceil_to};

/// Microkernel rows: A micro-panel width.
pub const MR: usize = 4;
/// Microkernel columns: B micro-panel width.
pub const NR: usize = 8;
/// k-depth of one packed slice (A and B panels are `KC` deep).
pub const KC: usize = 256;
/// Rows of one packed A panel (multiple of `MR`).
pub const MC: usize = 64;
/// Columns of one packed B panel (multiple of `NR`).
pub const NC: usize = 512;
/// B column strips (`NR` wide) per scheduling unit: one macrokernel
/// task covers `JGRP × NR = 64` output columns, coarse enough that
/// dispatch cost vanishes, fine enough that small-`m` GEMMs (FC dX at
/// small batch) still fan out across lanes.
const JGRP: usize = 8;

/// Which operands arrive transposed.  Handled entirely in the packers;
/// the microkernel always sees k-major micro-panels.
#[derive(Clone, Copy, Debug)]
enum Layout {
    /// `A[m×k] · B[k×n]`
    Nn,
    /// `A[m×k] · B[n×k]ᵀ`
    Nt,
    /// `A[k×m]ᵀ · B[k×n]`
    Tn,
}

/// One cache line of `f32`s — the allocation granule that gives
/// [`PackBuf`] its 64-byte base alignment.
#[derive(Clone, Copy, Debug)]
#[repr(C, align(64))]
struct CacheLine([f32; 16]);

/// A 64-byte-aligned, grow-only `f32` arena.  Backing the storage with
/// `Vec<CacheLine>` makes the allocator honor the alignment in safe
/// code, which is what lets the AVX2 microkernel use *aligned* vector
/// loads on the packed B panels: every `NR`-strip offset is a multiple
/// of `NR·kc` floats and every panel row advances by `NR = 8` floats
/// (32 bytes), so a 64-byte base keeps every row load-aligned.
#[derive(Debug, Default)]
struct AlignedBuf(Vec<CacheLine>);

impl AlignedBuf {
    const LINE: usize = 16;

    /// Grow to hold at least `n` floats; never shrinks.
    fn ensure(&mut self, n: usize) {
        let lines = ceil_div(n, Self::LINE);
        if self.0.len() < lines {
            self.0.resize(lines, CacheLine([0.0; 16]));
        }
        debug_assert_eq!(self.0.as_ptr() as usize % 64, 0, "pack arena lost 64-byte alignment");
    }

    fn as_mut_ptr(&mut self) -> *mut f32 {
        self.0.as_mut_ptr() as *mut f32
    }
}

/// Workspace holding the packed A row-panel (`≤ MC×KC`) and B
/// column-panel (`≤ NC×KC`, rounded up to whole `NR` strips), both in
/// 64-byte-aligned arenas (see [`AlignedBuf`]).  Grown on first use,
/// then reused forever — zero steady-state allocations.  The serial
/// kernels need one per calling lane (conv keeps one per pool lane in
/// `ConvScratch`, which inherits the alignment for free); the
/// `par_matmul_*` forms share one, packed cooperatively by the pool.
#[derive(Debug, Default)]
pub struct PackBuf {
    apack: AlignedBuf,
    bpack: AlignedBuf,
}

impl PackBuf {
    fn ensure(&mut self, m: usize, k: usize, n: usize) {
        let kc = k.min(KC);
        self.apack.ensure(ceil_to(m.min(MC), MR) * kc);
        self.bpack.ensure(ceil_to(n.min(NC), NR) * kc);
    }
}

/// Pack one `MR`-row strip of the A panel (`rows ≤ MR` valid rows
/// starting at `r0`, k-slice `pc..pc+kc`) into `out[p*MR + r]`,
/// zero-padding past `rows`.
#[allow(clippy::too_many_arguments)]
fn pack_a_strip(
    layout: Layout,
    a: &[f32],
    m: usize,
    k: usize,
    r0: usize,
    rows: usize,
    pc: usize,
    kc: usize,
    out: &mut [f32],
) {
    debug_assert!(rows >= 1 && rows <= MR && out.len() >= kc * MR);
    if rows < MR {
        out[..kc * MR].fill(0.0);
    }
    match layout {
        // op-A[r][p] = a[(r0+r)·k + pc+p]: contiguous reads per row.
        Layout::Nn | Layout::Nt => {
            for r in 0..rows {
                let arow = &a[(r0 + r) * k + pc..(r0 + r) * k + pc + kc];
                for (p, &v) in arow.iter().enumerate() {
                    out[p * MR + r] = v;
                }
            }
        }
        // op-A[r][p] = a[(pc+p)·m + r0+r]: contiguous in r — the
        // transpose is free here.
        Layout::Tn => {
            for p in 0..kc {
                let arow = &a[(pc + p) * m + r0..(pc + p) * m + r0 + rows];
                out[p * MR..p * MR + rows].copy_from_slice(arow);
            }
        }
    }
}

/// Pack one `NR`-column strip of the B panel (`cols ≤ NR` valid columns
/// starting at `j0`, k-slice `pc..pc+kc`) into `out[p*NR + j]`,
/// zero-padding past `cols`.
#[allow(clippy::too_many_arguments)]
fn pack_b_strip(
    layout: Layout,
    b: &[f32],
    k: usize,
    n: usize,
    j0: usize,
    cols: usize,
    pc: usize,
    kc: usize,
    out: &mut [f32],
) {
    debug_assert!(cols >= 1 && cols <= NR && out.len() >= kc * NR);
    if cols < NR {
        out[..kc * NR].fill(0.0);
    }
    match layout {
        // op-B[p][j] = b[(pc+p)·n + j0+j]: contiguous both sides.
        Layout::Nn | Layout::Tn => {
            for p in 0..kc {
                let brow = &b[(pc + p) * n + j0..(pc + p) * n + j0 + cols];
                out[p * NR..p * NR + cols].copy_from_slice(brow);
            }
        }
        // op-B[p][j] = b[(j0+j)·k + pc+p]: contiguous reads per column.
        Layout::Nt => {
            for j in 0..cols {
                let bcol = &b[(j0 + j) * k + pc..(j0 + j) * k + pc + kc];
                for (p, &v) in bcol.iter().enumerate() {
                    out[p * NR + j] = v;
                }
            }
        }
    }
}

/// Serial-or-pool dispatch.  Both arms run the identical unit bodies —
/// units are disjoint and independent, so the schedule can never change
/// a bit of the output.
enum Exec<'a> {
    Serial,
    Pool(&'a ComputePool),
}

impl Exec<'_> {
    fn units(&self, n_units: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        match self {
            Exec::Serial => {
                for u in 0..n_units {
                    f(0, u);
                }
            }
            Exec::Pool(p) => p.run_chunks(n_units, f),
        }
    }

    fn grid(&self, ni: usize, nj: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        match self {
            Exec::Serial => {
                for i in 0..ni {
                    for j in 0..nj {
                        f(0, i, j);
                    }
                }
            }
            Exec::Pool(p) => p.run_grid(ni, nj, f),
        }
    }
}

/// The blocked driver shared by all the public entry points.
///
/// Per (`jc`, `pc`) block: phase 1 packs the B panel (one unit per
/// `JGRP`-strip column group); per `ic` block, phase 2 packs the A
/// panel inline (too little work to be worth a dispatch) and phase 3
/// runs `kern` — the dispatched [`MicroKernel`] — over the (row strip ×
/// column group) grid.  Dispatched phases are separated by the pool's
/// completion barrier, units within a phase write disjoint regions, and
/// all boundaries are shape-derived — see the module docs for why this
/// makes serial and parallel bit-identical (per fixed ISA).
#[allow(clippy::too_many_arguments)]
fn gemm_packed(
    layout: Layout,
    exec: Exec,
    kern: MicroKernel,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    ws.ensure(m, k, n);
    let c_ptr = SendPtr::new(c.as_mut_ptr());
    let ap_ptr = SendPtr::new(ws.apack.as_mut_ptr());
    let bp_ptr = SendPtr::new(ws.bpack.as_mut_ptr());
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        let n_jstrips = ceil_div(nc, NR);
        let n_jgroups = ceil_div(n_jstrips, JGRP);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            // Phase 1: pack B — strips are disjoint bpack regions.
            exec.units(n_jgroups, &|_lane, g| {
                for s in g * JGRP..(g * JGRP + JGRP).min(n_jstrips) {
                    // SAFETY: strip s owns bpack[s·NR·kc .. (s+1)·NR·kc];
                    // the barrier below orders packing before reads.
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(bp_ptr.get().add(s * NR * kc), NR * kc)
                    };
                    pack_b_strip(layout, b, k, n, jc + s * NR, NR.min(nc - s * NR), pc, kc, out);
                }
            });
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                let n_istrips = ceil_div(mc, MR);
                // Phase 2: pack A, inline on the dispatching thread — an
                // A panel is ≤ MC×KC elements, a fraction of a percent
                // of the macrokernel work it feeds, so a pool dispatch
                // here would cost more than the copies.  The phase-3
                // dispatch below is the happens-before edge that
                // publishes these writes to the lanes.
                for s in 0..n_istrips {
                    // SAFETY: strip s owns apack[s·MR·kc .. (s+1)·MR·kc].
                    let out = unsafe {
                        std::slice::from_raw_parts_mut(ap_ptr.get().add(s * MR * kc), MR * kc)
                    };
                    pack_a_strip(layout, a, m, k, ic + s * MR, MR.min(mc - s * MR), pc, kc, out);
                }
                // Phase 3: macrokernel over the tile grid; each tile
                // owns its C rows × columns outright.
                exec.grid(n_istrips, n_jgroups, &|_lane, is, jg| {
                    // SAFETY: packed panels are read-only in this phase
                    // (the pool barrier between phases orders writes).
                    let ap = unsafe {
                        std::slice::from_raw_parts(ap_ptr.get().add(is * MR * kc), MR * kc)
                    };
                    let rows = MR.min(mc - is * MR);
                    for s in jg * JGRP..(jg * JGRP + JGRP).min(n_jstrips) {
                        let bp = unsafe {
                            std::slice::from_raw_parts(bp_ptr.get().add(s * NR * kc), NR * kc)
                        };
                        let acc = kern.run(kc, ap, bp);
                        let cols = NR.min(nc - s * NR);
                        let (r0, c0) = (ic + is * MR, jc + s * NR);
                        for r in 0..rows {
                            // SAFETY: C rows r0..r0+rows, columns
                            // c0..c0+cols belong to exactly this tile.
                            let crow = unsafe {
                                std::slice::from_raw_parts_mut(
                                    c_ptr.get().add((r0 + r) * n + c0),
                                    cols,
                                )
                            };
                            for (cv, &av) in crow.iter_mut().zip(&acc[r][..cols]) {
                                *cv += av;
                            }
                        }
                    }
                });
            }
        }
    }
}

/// `C[m×n] += A[m×k] · B[k×n]`, packed serial kernel with caller-owned
/// pack workspace (the hot-path form; lane-local on the conv path).
/// Runs the process-wide dispatched [`MicroKernel`].
pub fn matmul_nn_ws(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    matmul_nn_ws_with(MicroKernel::active(), m, k, n, a, b, c, ws);
}

/// [`matmul_nn_ws`] with an explicit [`MicroKernel`] — how tests and
/// benches pin a specific ISA without touching the process-wide
/// dispatch.
#[allow(clippy::too_many_arguments)]
pub fn matmul_nn_ws_with(
    kern: MicroKernel,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm_packed(Layout::Nn, Exec::Serial, kern, m, k, n, a, b, c, ws);
}

/// `C[m×n] += A[m×k] · B[n×k]ᵀ`, packed serial kernel with caller-owned
/// pack workspace.  Runs the process-wide dispatched [`MicroKernel`].
pub fn matmul_nt_ws(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    matmul_nt_ws_with(MicroKernel::active(), m, k, n, a, b, c, ws);
}

/// [`matmul_nt_ws`] with an explicit [`MicroKernel`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_ws_with(
    kern: MicroKernel,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm_packed(Layout::Nt, Exec::Serial, kern, m, k, n, a, b, c, ws);
}

/// `C[m×n] += A[k×m]ᵀ · B[k×n]`, packed serial kernel with caller-owned
/// pack workspace.  Runs the process-wide dispatched [`MicroKernel`].
pub fn matmul_tn_ws(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    matmul_tn_ws_with(MicroKernel::active(), m, k, n, a, b, c, ws);
}

/// [`matmul_tn_ws`] with an explicit [`MicroKernel`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_ws_with(
    kern: MicroKernel,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm_packed(Layout::Tn, Exec::Serial, kern, m, k, n, a, b, c, ws);
}

/// [`matmul_nn_ws`] with a throwaway workspace — convenience for tests
/// and reference paths; hot paths pass a reused [`PackBuf`].  These
/// no-workspace wrappers are retained public API surface: gradchecks,
/// tests, and benches call them directly (through the [`MicroKernel`]
/// dispatch table like everything else) — don't fold them into the
/// `_ws` forms.
pub fn matmul_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    matmul_nn_ws(m, k, n, a, b, c, &mut PackBuf::default());
}

/// [`matmul_nt_ws`] with a throwaway workspace; see [`matmul_nn`] for
/// why these wrappers stay.
pub fn matmul_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    matmul_nt_ws(m, k, n, a, b, c, &mut PackBuf::default());
}

/// [`matmul_tn_ws`] with a throwaway workspace; see [`matmul_nn`] for
/// why these wrappers stay.
pub fn matmul_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    matmul_tn_ws(m, k, n, a, b, c, &mut PackBuf::default());
}

/// Tile-parallel [`matmul_nn_ws`], running the pool's [`MicroKernel`];
/// bit-identical to the serial form (same kernel) for any lane count.
#[allow(clippy::too_many_arguments)]
pub fn par_matmul_nn(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    if m == 0 || n == 0 {
        // Empty products (ragged eval tails) dispatch nothing.
        return;
    }
    gemm_packed(Layout::Nn, Exec::Pool(pool), pool.kernel(), m, k, n, a, b, c, ws);
}

/// Tile-parallel [`matmul_nt_ws`], running the pool's [`MicroKernel`];
/// bit-identical to the serial form (same kernel) for any lane count.
#[allow(clippy::too_many_arguments)]
pub fn par_matmul_nt(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    if m == 0 || n == 0 {
        return;
    }
    gemm_packed(Layout::Nt, Exec::Pool(pool), pool.kernel(), m, k, n, a, b, c, ws);
}

/// Tile-parallel [`matmul_tn_ws`], running the pool's [`MicroKernel`];
/// bit-identical to the serial form (same kernel) for any lane count.
#[allow(clippy::too_many_arguments)]
pub fn par_matmul_tn(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    ws: &mut PackBuf,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    if m == 0 || n == 0 {
        return;
    }
    gemm_packed(Layout::Tn, Exec::Pool(pool), pool.kernel(), m, k, n, a, b, c, ws);
}

/// The pre-packing scalar kernels, preserved verbatim as the
/// benchmarking baseline (`benches/gemm_kernels.rs` quantifies the
/// packed kernels against them, including the ReLU-sparsity zero-skip
/// these carry) and as an independent reference for tests.  Not on any
/// hot path.
///
/// NOTE: these are *not* the `scalar` entry of the
/// [`MicroKernel`] dispatch table — that is the portable packed
/// microkernel in `simd` — and they are not dead code: benches and
/// gradchecks depend on them as an independently-ordered reference.
/// Don't "clean them up".
pub mod scalar {
    /// `C[m×n] += A[m×k] · B[k×n]` — KC/NC cache-blocked scalar loops,
    /// skipping zero multipliers.
    pub fn matmul_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        const KC: usize = 64;
        const NC: usize = 512;
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for j0 in (0..n).step_by(NC) {
                let j1 = (j0 + NC).min(n);
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let crow = &mut c[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n + j0..kk * n + j1];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }

    /// `C[m×n] += A[m×k] · B[n×k]ᵀ` — row-dot-row scalar loops.
    pub fn matmul_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *cv += acc;
            }
        }
    }

    /// `C[m×n] += A[k×m]ᵀ · B[k×n]` — outer-product scalar loops,
    /// skipping zero multipliers.
    pub fn matmul_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        for kk in 0..k {
            let arow = &a[kk * m..(kk + 1) * m];
            let brow = &b[kk * n..(kk + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::simd::Isa;
    use crate::util::math::{rel_err, transpose};
    use crate::util::Pcg32;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for t in 0..k {
                    c[i * n + j] += a[i * k + t] * b[t * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        // Inject zeros so the padded tiles and (in `scalar`) the
        // sparsity skips stay exercised.
        for (i, x) in v.iter_mut().enumerate() {
            if i % 5 == 0 {
                *x = 0.0;
            }
        }
        v
    }

    /// Rounding-tolerant comparison: the packed summation order is not
    /// the naive order, so bitwise equality would be wrong to ask for.
    /// `rel_err` floors the denominator at 1, so near-zero sums compare
    /// absolutely — no fragile absolute epsilons on long accumulations.
    fn assert_close(tag: &str, got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len(), "{tag}: length");
        for (i, (x, y)) in got.iter().zip(want).enumerate() {
            let e = rel_err(*x, *y);
            assert!(e < 1e-3, "{tag}[{i}]: {x} vs {y} (rel err {e})");
        }
    }

    #[test]
    fn packed_matches_naive_across_blocking_boundaries() {
        let mut rng = Pcg32::seeded(1);
        // Dims straddle the MR/NR tile edges, exact KC/MC/NC blocks,
        // and one-past each block edge.
        for (m, k, n) in [
            (1, 1, 1),
            (3, 7, 5),
            (MR, 1, NR),
            (5, 130, 9),
            (MC, KC, NC),
            (MC + 1, KC + 1, NC + 1),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let want = naive(m, k, n, &a, &b);

            let mut c = vec![0.0; m * n];
            matmul_nn(m, k, n, &a, &b, &mut c);
            assert_close(&format!("nn {m}x{k}x{n}"), &c, &want);

            let mut c = vec![0.0; m * n];
            matmul_nt(m, k, n, &a, &transpose(k, n, &b), &mut c);
            assert_close(&format!("nt {m}x{k}x{n}"), &c, &want);

            let mut c = vec![0.0; m * n];
            matmul_tn(m, k, n, &transpose(m, k, &a), &b, &mut c);
            assert_close(&format!("tn {m}x{k}x{n}"), &c, &want);
        }
    }

    #[test]
    fn packed_and_scalar_kernels_agree_to_rounding() {
        // The old scalar kernels are the independent reference; the
        // packed kernels reorder the sum, so rounding-level agreement
        // is the contract (and all the trajectory the bench compares).
        let mut rng = Pcg32::seeded(5);
        let (m, k, n) = (9, 70, 33);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let at = transpose(m, k, &a);
        let bt = transpose(k, n, &b);

        let (mut p, mut s) = (vec![0.0; m * n], vec![0.0; m * n]);
        matmul_nn(m, k, n, &a, &b, &mut p);
        scalar::matmul_nn(m, k, n, &a, &b, &mut s);
        assert_close("nn vs scalar", &p, &s);

        let (mut p, mut s) = (vec![0.0; m * n], vec![0.0; m * n]);
        matmul_nt(m, k, n, &a, &bt, &mut p);
        scalar::matmul_nt(m, k, n, &a, &bt, &mut s);
        assert_close("nt vs scalar", &p, &s);

        let (mut p, mut s) = (vec![0.0; m * n], vec![0.0; m * n]);
        matmul_tn(m, k, n, &at, &b, &mut p);
        scalar::matmul_tn(m, k, n, &at, &b, &mut s);
        assert_close("tn vs scalar", &p, &s);
    }

    #[test]
    fn every_available_isa_matches_naive() {
        // The whole packed pipeline (packers + blocking + accumulation)
        // under each ISA kernel the host can run, against the naive
        // triple loop.  On x86_64 CI this exercises the AVX2+FMA path;
        // the scalar entry covers the portable fallback everywhere.
        let mut rng = Pcg32::seeded(9);
        for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
            if !isa.available() {
                continue;
            }
            let kern = MicroKernel::for_isa(isa);
            let mut ws = PackBuf::default();
            for (m, k, n) in [(3, 7, 5), (MR, 1, NR), (MC + 1, KC + 1, NC + 1)] {
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let want = naive(m, k, n, &a, &b);

                let mut c = vec![0.0; m * n];
                matmul_nn_ws_with(kern, m, k, n, &a, &b, &mut c, &mut ws);
                assert_close(&format!("nn {isa} {m}x{k}x{n}"), &c, &want);

                let mut c = vec![0.0; m * n];
                matmul_nt_ws_with(kern, m, k, n, &a, &transpose(k, n, &b), &mut c, &mut ws);
                assert_close(&format!("nt {isa} {m}x{k}x{n}"), &c, &want);

                let mut c = vec![0.0; m * n];
                matmul_tn_ws_with(kern, m, k, n, &transpose(m, k, &a), &b, &mut c, &mut ws);
                assert_close(&format!("tn {isa} {m}x{k}x{n}"), &c, &want);
            }
        }
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![10.0];
        matmul_nn(1, 2, 1, &a, &b, &mut c);
        assert_eq!(c, vec![10.0 + 11.0]);
    }

    #[test]
    fn workspace_reuse_is_bit_stable() {
        // One PackBuf across differently-shaped calls (grown once,
        // reused, stale contents from larger shapes left in place)
        // changes nothing: packing overwrites every slot it reads.
        let mut rng = Pcg32::seeded(6);
        let mut ws = PackBuf::default();
        for (m, k, n) in [(30, 300, 40), (3, 2, 5), (17, 130, 11)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut fresh = vec![0.0; m * n];
            matmul_nn(m, k, n, &a, &b, &mut fresh);
            let mut reused = vec![0.0; m * n];
            matmul_nn_ws(m, k, n, &a, &b, &mut reused, &mut ws);
            assert_eq!(fresh, reused, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn degenerate_dims_are_no_ops_or_identity() {
        let mut ws = PackBuf::default();
        // m == 0 / n == 0: nothing to write, C untouched (empty).
        let mut c: Vec<f32> = vec![];
        matmul_nn_ws(0, 3, 4, &[], &[0.0; 12], &mut c, &mut ws);
        matmul_nn_ws(2, 3, 0, &[0.0; 6], &[], &mut c, &mut ws);
        // k == 0: the product is the zero matrix; accumulation keeps C.
        let mut c = vec![7.0; 6];
        matmul_nn_ws(2, 0, 3, &[], &[], &mut c, &mut ws);
        assert_eq!(c, vec![7.0; 6]);
        matmul_nt_ws(2, 0, 3, &[], &[], &mut c, &mut ws);
        matmul_tn_ws(2, 0, 3, &[], &[], &mut c, &mut ws);
        assert_eq!(c, vec![7.0; 6]);
    }

    #[test]
    fn par_variants_match_serial_bitwise() {
        // m spans 1 row, primes, and > MC; bit-equality (assert_eq,
        // not tolerance) is the contract.
        let pool = ComputePool::new(4);
        let mut rng = Pcg32::seeded(3);
        let mut ws = PackBuf::default();
        for (m, k, n) in [(1, 7, 5), (13, 11, 17), (16, 5, 9), (MC + 2, 66, 130)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let at = transpose(m, k, &a);
            let bt = transpose(k, n, &b);

            let mut serial = vec![0.5; m * n];
            let mut par = vec![0.5; m * n];
            matmul_nn(m, k, n, &a, &b, &mut serial);
            par_matmul_nn(&pool, m, k, n, &a, &b, &mut par, &mut ws);
            assert_eq!(serial, par, "nn {m}x{k}x{n}");

            let mut serial = vec![0.25; m * n];
            let mut par = vec![0.25; m * n];
            matmul_nt(m, k, n, &a, &bt, &mut serial);
            par_matmul_nt(&pool, m, k, n, &a, &bt, &mut par, &mut ws);
            assert_eq!(serial, par, "nt {m}x{k}x{n}");

            let mut serial = vec![-0.5; m * n];
            let mut par = vec![-0.5; m * n];
            matmul_tn(m, k, n, &at, &b, &mut serial);
            par_matmul_tn(&pool, m, k, n, &at, &b, &mut par, &mut ws);
            assert_eq!(serial, par, "tn {m}x{k}x{n}");
        }
    }
}
