//! Blocked single-precision matrix multiply kernels.
//!
//! Three accumulating variants cover every product the AlexNet
//! forward/backward pass needs (conv-as-GEMM over im2col columns and
//! the fully-connected layers):
//!
//! - [`matmul_nn`]: `C += A · B`            (conv forward, FC dX)
//! - [`matmul_nt`]: `C += A · Bᵀ`           (FC forward, conv dW)
//! - [`matmul_tn`]: `C += Aᵀ · B`           (FC dW, conv dCol)
//!
//! All three accumulate into `C` so callers control zeroing, and all
//! iterate in row-major-friendly order.  `matmul_nn`/`matmul_tn` skip
//! zero multipliers — after ReLU the activation/gradient operands are
//! substantially sparse, and the branch is a measurable win on the
//! backward pass.
//!
//! The `par_matmul_*` wrappers split `C` into row blocks with
//! shape-derived boundaries ([`shape_chunks`]) and run the serial
//! kernel on each block through the [`ComputePool`].  Every `C` row is
//! produced by exactly the instruction sequence the serial kernel would
//! use, so the parallel results are **bit-identical** to the serial
//! ones for any lane count — the property `tests/parallel_backend.rs`
//! pins.

use crate::backend::native::pool::{par_chunks_mut, shape_chunks, ComputePool};

/// `C[m×n] += A[m×k] · B[k×n]` — cache-blocked over `k` and `n`.
pub fn matmul_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // Block sizes chosen so a (KC × NC) panel of B stays L1/L2-resident
    // across the `i` loop.
    const KC: usize = 64;
    const NC: usize = 512;
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for j0 in (0..n).step_by(NC) {
            let j1 = (j0 + NC).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n + j0..i * n + j1];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n + j0..kk * n + j1];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// `C[m×n] += A[m×k] · B[n×k]ᵀ` — row-dot-row, no staging needed.
pub fn matmul_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv += acc;
        }
    }
}

/// `C[m×n] += A[k×m]ᵀ · B[k×n]` — outer-product accumulation.
pub fn matmul_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    matmul_tn_rows(m, 0, m, k, n, a, b, c);
}

/// The `matmul_tn` inner loops restricted to output rows `[lo, hi)`
/// (columns `lo..hi` of `A`), writing into the row-block slice
/// `c_block` of length `(hi - lo) × n`.  Per-element accumulation runs
/// over `kk` in the same order as the full kernel, so a row block is
/// bitwise what the serial kernel computes for those rows.
fn matmul_tn_rows(
    m: usize,
    lo: usize,
    hi: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c_block: &mut [f32],
) {
    debug_assert_eq!(c_block.len(), (hi - lo) * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for i in lo..hi {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c_block[(i - lo) * n..(i - lo + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Row-block-parallel [`matmul_nn`]; bitwise equal to the serial kernel.
pub fn par_matmul_nn(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    let (_, rows) = shape_chunks(m);
    par_chunks_mut(pool, c, rows * n, |ci, c_block| {
        let lo = ci * rows;
        let nrows = c_block.len() / n;
        matmul_nn(nrows, k, n, &a[lo * k..(lo + nrows) * k], b, c_block);
    });
}

/// Row-block-parallel [`matmul_nt`]; bitwise equal to the serial kernel.
pub fn par_matmul_nt(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    let (_, rows) = shape_chunks(m);
    par_chunks_mut(pool, c, rows * n, |ci, c_block| {
        let lo = ci * rows;
        let nrows = c_block.len() / n;
        matmul_nt(nrows, k, n, &a[lo * k..(lo + nrows) * k], b, c_block);
    });
}

/// Row-block-parallel [`matmul_tn`]; bitwise equal to the serial kernel.
pub fn par_matmul_tn(
    pool: &ComputePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    let (_, rows) = shape_chunks(m);
    par_chunks_mut(pool, c, rows * n, |ci, c_block| {
        let lo = ci * rows;
        let nrows = c_block.len() / n;
        matmul_tn_rows(m, lo, lo + nrows, k, n, a, b, c_block);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for t in 0..k {
                    c[i * n + j] += a[i * k + t] * b[t * n + j];
                }
            }
        }
        c
    }

    fn transpose(rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
        let mut t = vec![0.0; x.len()];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = x[r * cols + c];
            }
        }
        t
    }

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        rng.fill_normal(&mut v, 1.0);
        // Inject zeros to exercise the sparsity skips.
        for (i, x) in v.iter_mut().enumerate() {
            if i % 5 == 0 {
                *x = 0.0;
            }
        }
        v
    }

    #[test]
    fn nn_matches_naive_across_blocking_boundaries() {
        let mut rng = Pcg32::seeded(1);
        // Dims chosen to straddle the KC/NC block edges.
        for (m, k, n) in [(3, 7, 5), (2, 64, 512), (5, 65, 513), (1, 130, 1000)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c = vec![0.0; m * n];
            matmul_nn(m, k, n, &a, &b, &mut c);
            let want = naive(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn nt_and_tn_match_naive() {
        let mut rng = Pcg32::seeded(2);
        let (m, k, n) = (4, 9, 6);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let want = naive(m, k, n, &a, &b);

        let mut c = vec![0.0; m * n];
        matmul_nt(m, k, n, &a, &transpose(k, n, &b), &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }

        let mut c = vec![0.0; m * n];
        matmul_tn(m, k, n, &transpose(m, k, &a), &b, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![10.0];
        matmul_nn(1, 2, 1, &a, &b, &mut c);
        assert_eq!(c, vec![10.0 + 11.0]);
    }

    #[test]
    fn par_variants_match_serial_bitwise() {
        // m spans 1 row, prime, exactly MAX_CHUNKS, and > MAX_CHUNKS;
        // bit-equality (assert_eq, not tolerance) is the contract.
        let pool = ComputePool::new(4);
        let mut rng = Pcg32::seeded(3);
        for (m, k, n) in [(1, 7, 5), (13, 11, 17), (16, 5, 9), (33, 66, 130)] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let at = transpose(m, k, &a);
            let bt = transpose(k, n, &b);

            let mut serial = vec![0.5; m * n];
            let mut par = vec![0.5; m * n];
            matmul_nn(m, k, n, &a, &b, &mut serial);
            par_matmul_nn(&pool, m, k, n, &a, &b, &mut par);
            assert_eq!(serial, par, "nn {m}x{k}x{n}");

            let mut serial = vec![0.25; m * n];
            let mut par = vec![0.25; m * n];
            matmul_nt(m, k, n, &a, &bt, &mut serial);
            par_matmul_nt(&pool, m, k, n, &a, &bt, &mut par);
            assert_eq!(serial, par, "nt {m}x{k}x{n}");

            let mut serial = vec![-0.5; m * n];
            let mut par = vec![-0.5; m * n];
            matmul_tn(m, k, n, &at, &b, &mut serial);
            par_matmul_tn(&pool, m, k, n, &at, &b, &mut par);
            assert_eq!(serial, par, "tn {m}x{k}x{n}");
        }
    }
}
