//! A scoped intra-op compute pool for the native backend.
//!
//! One [`ComputePool`] lives inside each [`NativeBackend`] and fans the
//! hot kernels — GEMM row blocks, per-example conv/im2col work, pooling
//! planes, elementwise ReLU/dropout sweeps and the SGD parameter
//! update — across `threads` lanes: the calling thread (lane 0) plus
//! `threads - 1` persistent worker threads parked on plain
//! `std::sync::mpsc` channels.  No external crates, no spin loops: the
//! hand-off is the channels' own park/unpark.
//!
//! ## Determinism contract
//!
//! Parallel results must be **bit-identical for every thread count**
//! (the N-replica divergence invariants depend on it), so the pool
//! never lets the lane count influence the math:
//!
//! - Work is split into chunks whose boundaries are derived from the
//!   *shape only* (see [`shape_chunks`] / [`ELEMWISE_CHUNK`]), never
//!   from `lanes()`.
//! - Each chunk writes a disjoint slice of the output, or accumulates
//!   into its own chunk-indexed scratch buffer; cross-chunk reductions
//!   are then applied in fixed chunk order.
//! - Lanes pick chunks dynamically (an atomic counter, for load
//!   balance), which is safe precisely because chunk → output mapping
//!   is fixed; *which lane* computes a chunk can never matter.
//!
//! A single-lane pool therefore runs the exact chunk loop the parallel
//! one does, and `threads ∈ {1, 2, 4}` agree bitwise — the property
//! `tests/parallel_backend.rs` pins.
//!
//! ## Scoped dispatch
//!
//! [`ComputePool::run`] hands workers a borrowed closure by erasing its
//! lifetime, and blocks until every worker has reported completion
//! before returning — the borrow can never outlive the call.  Workers
//! report through a drop guard that also records whether the task was
//! unwinding, so a panicking task still signals (no deadlock) and the
//! *same* `run` call panics rather than returning partial results.
//!
//! [`NativeBackend`]: crate::backend::native::NativeBackend

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::backend::native::simd::MicroKernel;
use crate::util::math::ceil_div;

/// Elementwise-sweep chunk: fixed so boundaries depend on data length
/// only.  Big enough that dispatch cost vanishes, small enough that a
/// conv activation map splits across lanes.
pub const ELEMWISE_CHUNK: usize = 1 << 14;

/// Maximum chunk count for item-parallel loops (GEMM row blocks, conv
/// batch examples, pooling planes).  Shape-derived — deliberately *not*
/// tied to the lane count — and sized so up to 8 lanes still get ≥ 2
/// chunks each for dynamic balancing.
pub const MAX_CHUNKS: usize = 16;

/// Split `items` into at most [`MAX_CHUNKS`] contiguous chunks; returns
/// `(n_chunks, chunk_len)` (the last chunk may be short).  Pure shape
/// arithmetic: the same `items` always yields the same boundaries.
pub fn shape_chunks(items: usize) -> (usize, usize) {
    if items == 0 {
        return (0, 1);
    }
    let chunk = ceil_div(items, items.min(MAX_CHUNKS));
    (ceil_div(items, chunk), chunk)
}

/// A raw pointer that may cross thread boundaries.  Callers guarantee
/// disjoint access (each chunk/lane touches its own region) — the
/// wrapper exists only to let closures capture the base address.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

enum Msg {
    Run { task: &'static (dyn Fn(usize) + Sync), lane: usize },
    Exit,
}

/// Sends one completion token when dropped — `true` when the task is
/// unwinding — so a panicking task still unblocks the dispatcher *and*
/// fails the run that dispatched it (not just the next one).
struct DoneGuard<'a>(&'a Sender<bool>);

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let _ = self.0.send(std::thread::panicking());
    }
}

/// Waits for `n` outstanding completions when dropped, so a panic on
/// lane 0 cannot return (and free the borrowed task) while workers are
/// still inside it.
struct Drain<'a> {
    rx: &'a Receiver<bool>,
    n: usize,
}

impl Drop for Drain<'_> {
    fn drop(&mut self) {
        for _ in 0..self.n {
            if self.rx.recv().is_err() {
                // Every worker is gone; nothing holds the borrow.
                break;
            }
        }
    }
}

/// The intra-op worker pool.  See the module docs for the determinism
/// contract; `threads = 1` is a zero-thread, zero-overhead serial pool
/// that still runs the identical chunk loops.
///
/// The pool also carries the GEMM [`MicroKernel`] resolved **once** at
/// construction (runtime ISA detection plus the `TMG_GEMM_ISA`
/// override): lanes never re-detect, so the kernel — and therefore the
/// bit pattern of every GEMM — is uniform for the pool's lifetime.
pub struct ComputePool {
    lanes: usize,
    kernel: MicroKernel,
    senders: Vec<Sender<Msg>>,
    done_rx: Receiver<bool>,
    joins: Vec<JoinHandle<()>>,
}

impl ComputePool {
    /// Spawn a pool with `threads` lanes total (clamped to ≥ 1): the
    /// caller plus `threads - 1` parked workers, carrying the
    /// process-wide dispatched [`MicroKernel`].
    pub fn new(threads: usize) -> ComputePool {
        ComputePool::with_kernel(threads, MicroKernel::active())
    }

    /// [`ComputePool::new`] with an explicit [`MicroKernel`] — how the
    /// per-ISA tests and benches pin a kernel per pool instead of
    /// relying on the process-wide dispatch.
    pub fn with_kernel(threads: usize, kernel: MicroKernel) -> ComputePool {
        let lanes = threads.max(1);
        let (done_tx, done_rx) = channel::<bool>();
        let mut senders = Vec::with_capacity(lanes - 1);
        let mut joins = Vec::with_capacity(lanes - 1);
        for i in 1..lanes {
            let (tx, rx) = channel::<Msg>();
            let done = done_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("tmg-compute-{i}"))
                .spawn(move || {
                    while let Ok(Msg::Run { task, lane }) = rx.recv() {
                        let _done = DoneGuard(&done);
                        task(lane);
                    }
                })
                .expect("spawn compute-pool worker");
            senders.push(tx);
            joins.push(join);
        }
        ComputePool { lanes, kernel, senders, done_rx, joins }
    }

    /// A 1-lane pool: no threads, every helper runs inline.
    pub fn serial() -> ComputePool {
        ComputePool::new(1)
    }

    /// Total lanes (calling thread included).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The GEMM microkernel this pool dispatches (fixed at
    /// construction).
    pub fn kernel(&self) -> MicroKernel {
        self.kernel
    }

    /// Run `f(lane)` once on every lane concurrently; returns after all
    /// lanes finish.  `f` only borrows for the duration of the call.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.senders.is_empty() {
            f(0);
            return;
        }
        // SAFETY: the erased borrow is only reachable by workers until
        // their completion tokens arrive, and both the normal path and
        // the Drain guard wait for every token before this frame ends.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let mut dead = false;
        let mut sent = 0usize;
        for (i, tx) in self.senders.iter().enumerate() {
            if tx.send(Msg::Run { task, lane: i + 1 }).is_ok() {
                sent += 1;
            } else {
                dead = true;
            }
        }
        let mut drain = Drain { rx: &self.done_rx, n: sent };
        f(0);
        while drain.n > 0 {
            drain.n -= 1;
            match self.done_rx.recv() {
                Ok(panicked) => dead |= panicked,
                Err(_) => {
                    drain.n = 0;
                    dead = true;
                }
            }
        }
        drop(drain);
        assert!(!dead, "compute-pool worker panicked or exited; results are incomplete");
    }

    /// Run `f(lane, i, j)` for every cell of the `ni × nj` tile grid,
    /// each exactly once, distributed over lanes row-major through
    /// [`ComputePool::run_chunks`].  The grid shape must derive from
    /// tensor shapes only (never `lanes()`), which makes this the
    /// scheduling primitive for the packed-GEMM macrokernel: every
    /// (row-panel, column-group) tile is computed by exactly the same
    /// instruction sequence regardless of which lane picks it up.
    pub fn run_grid(&self, ni: usize, nj: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        if ni == 0 || nj == 0 {
            return;
        }
        self.run_chunks(ni * nj, &|lane, cell| f(lane, cell / nj, cell % nj));
    }

    /// Run `f(lane, chunk_idx)` for every `chunk_idx in 0..n_chunks`,
    /// each exactly once, distributed over lanes by an atomic counter.
    /// Single-lane pools (and single chunks) run inline.
    pub fn run_chunks(&self, n_chunks: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        if self.lanes == 1 || n_chunks == 1 {
            for ci in 0..n_chunks {
                f(0, ci);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        self.run(&|lane| loop {
            let ci = next.fetch_add(1, Ordering::Relaxed);
            if ci >= n_chunks {
                break;
            }
            f(lane, ci);
        });
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Exit);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Split `data` into consecutive `chunk`-sized slices and run
/// `f(chunk_idx, slice)` for each, in parallel.  Chunks are disjoint,
/// so results are independent of lane count and scheduling.
pub fn par_chunks_mut<T, F>(pool: &ComputePool, data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    if len == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let base = SendPtr::new(data.as_mut_ptr());
    pool.run_chunks(ceil_div(len, chunk), &|_lane, ci| {
        let lo = ci * chunk;
        let hi = (lo + chunk).min(len);
        // SAFETY: [lo, hi) ranges are in-bounds and pairwise disjoint
        // across chunk indices, and `data` is exclusively borrowed for
        // the duration of this call.
        let s = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        f(ci, s);
    });
}

/// Index-range variant of [`par_chunks_mut`] for kernels that stride
/// several arrays at once: runs `f(chunk_idx, lo..hi)` over fixed
/// `chunk`-sized ranges of `0..len`.
pub fn par_ranges<F>(pool: &ComputePool, len: usize, chunk: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if len == 0 {
        return;
    }
    let chunk = chunk.max(1);
    pool.run_chunks(ceil_div(len, chunk), &|_lane, ci| {
        let lo = ci * chunk;
        f(ci, lo..(lo + chunk).min(len));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn shape_chunks_boundaries() {
        assert_eq!(shape_chunks(0), (0, 1));
        assert_eq!(shape_chunks(1), (1, 1));
        assert_eq!(shape_chunks(5), (5, 1));
        assert_eq!(shape_chunks(16), (16, 1));
        assert_eq!(shape_chunks(17), (9, 2)); // ceil(17/16)=2 per chunk
        assert_eq!(shape_chunks(100), (15, 7)); // ceil(100/16)=7, ceil(100/7)=15
        // The rule never consults a thread count: same input, same split.
        assert_eq!(shape_chunks(100), shape_chunks(100));
    }

    #[test]
    fn run_executes_every_lane_once() {
        for threads in [1, 2, 4] {
            let pool = ComputePool::new(threads);
            let hits: Vec<AtomicU32> = (0..threads).map(|_| AtomicU32::new(0)).collect();
            pool.run(&|lane| {
                hits[lane].fetch_add(1, Ordering::Relaxed);
            });
            for (lane, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "lane {lane}");
            }
        }
    }

    #[test]
    fn run_grid_covers_every_cell_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ComputePool::new(threads);
            let (ni, nj) = (5, 7);
            let hits: Vec<AtomicU32> = (0..ni * nj).map(|_| AtomicU32::new(0)).collect();
            pool.run_grid(ni, nj, &|_lane, i, j| {
                assert!(i < ni && j < nj);
                hits[i * nj + j].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "t{threads}");
            // Degenerate grids dispatch nothing.
            pool.run_grid(0, 3, &|_, _, _| panic!("empty grid"));
            pool.run_grid(3, 0, &|_, _, _| panic!("empty grid"));
        }
    }

    #[test]
    fn run_chunks_covers_each_chunk_exactly_once() {
        for threads in [1, 3, 4] {
            let pool = ComputePool::new(threads);
            let n = 23;
            let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            pool.run_chunks(n, &|_lane, ci| {
                hits[ci].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn par_chunks_mut_is_lane_count_invariant() {
        // A chunk-local stateful computation (prefix-sum within the
        // chunk) must come out identical for any lane count, because
        // the chunk boundaries are fixed.
        let make = |threads: usize| {
            let pool = ComputePool::new(threads);
            let mut v: Vec<f32> = (0..1000).map(|i| (i % 17) as f32 * 0.25).collect();
            par_chunks_mut(&pool, &mut v, 64, |ci, chunk| {
                let mut acc = ci as f32;
                for x in chunk.iter_mut() {
                    acc += *x;
                    *x = acc;
                }
            });
            v
        };
        let serial = make(1);
        assert_eq!(serial, make(2));
        assert_eq!(serial, make(4));
    }

    #[test]
    fn par_ranges_covers_everything() {
        let pool = ComputePool::new(4);
        let len = 100;
        let hits: Vec<AtomicU32> = (0..len).map(|_| AtomicU32::new(0)).collect();
        par_ranges(&pool, len, 7, |_ci, r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_oversized_chunks() {
        let pool = ComputePool::new(2);
        let mut empty: Vec<f32> = vec![];
        par_chunks_mut(&pool, &mut empty, 8, |_, _| panic!("no chunks for empty data"));
        // chunk > len: a single chunk spanning everything.
        let mut v = vec![1.0f32; 5];
        par_chunks_mut(&pool, &mut v, 1000, |ci, chunk| {
            assert_eq!(ci, 0);
            assert_eq!(chunk.len(), 5);
            for x in chunk {
                *x += 1.0;
            }
        });
        assert_eq!(v, vec![2.0; 5]);
    }

    #[test]
    fn pool_drops_cleanly_and_is_reusable() {
        let pool = ComputePool::new(3);
        for _ in 0..50 {
            let total = AtomicU32::new(0);
            pool.run_chunks(8, &|_l, ci| {
                total.fetch_add(ci as u32, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 28);
        }
        drop(pool); // joins workers; a hang here fails the test run
    }
}
