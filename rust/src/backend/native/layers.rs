//! The layer zoo: im2col convolution, ReLU, max-pool, fully-connected,
//! inverted dropout and softmax cross-entropy — forward *and* backward,
//! in pure Rust over flat `f32` slices.
//!
//! Conventions shared by every kernel:
//!
//! - activations are batch-major NCHW (`[batch, channels, h, w]`) or
//!   `[batch, features]`, row-major, matching [`HostTensor`]'s layout
//!   (so the last conv output doubles as the first FC input with no
//!   reshape);
//! - weight gradients **accumulate** (the caller zeroes once per step),
//!   input gradients are overwritten;
//! - the im2col staging buffer is caller-owned and reused across
//!   examples and steps (zero steady-state allocations, same discipline
//!   as the exchange path).
//!
//! [`HostTensor`]: crate::tensor::HostTensor

use crate::backend::native::gemm::{matmul_nn, matmul_nt, matmul_tn};
use crate::util::Pcg32;

/// Geometry of one conv layer (weights `[cout, cin, k, k]`).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dShape {
    pub batch: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub in_hw: usize,
    pub out_hw: usize,
}

impl Conv2dShape {
    /// Elements of one example's input plane stack.
    pub fn in_elems(&self) -> usize {
        self.cin * self.in_hw * self.in_hw
    }

    /// Elements of one example's output plane stack.
    pub fn out_elems(&self) -> usize {
        self.cout * self.out_hw * self.out_hw
    }

    /// Elements of the per-example im2col buffer `[cin·k², out_hw²]`.
    pub fn col_elems(&self) -> usize {
        self.cin * self.k * self.k * self.out_hw * self.out_hw
    }
}

/// Geometry of one max-pool layer.
#[derive(Clone, Copy, Debug)]
pub struct PoolShape {
    pub batch: usize,
    pub channels: usize,
    pub in_hw: usize,
    pub window: usize,
    pub stride: usize,
    pub out_hw: usize,
}

/// Geometry of one fully-connected layer (weights `[dout, din]`).
#[derive(Clone, Copy, Debug)]
pub struct FcShape {
    pub batch: usize,
    pub din: usize,
    pub dout: usize,
}

/// Unfold one example `[cin, in_hw, in_hw]` into columns
/// `[cin·k², out_hw²]`; out-of-image taps (padding) become zeros.
pub fn im2col(x: &[f32], s: &Conv2dShape, col: &mut [f32]) {
    let ohw = s.out_hw * s.out_hw;
    debug_assert_eq!(x.len(), s.in_elems());
    debug_assert_eq!(col.len(), s.cin * s.k * s.k * ohw);
    for c in 0..s.cin {
        let plane = &x[c * s.in_hw * s.in_hw..(c + 1) * s.in_hw * s.in_hw];
        for ky in 0..s.k {
            for kx in 0..s.k {
                let row = ((c * s.k + ky) * s.k + kx) * ohw;
                for oy in 0..s.out_hw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    let dst = row + oy * s.out_hw;
                    if iy < 0 || iy >= s.in_hw as isize {
                        col[dst..dst + s.out_hw].fill(0.0);
                        continue;
                    }
                    let src = iy as usize * s.in_hw;
                    for ox in 0..s.out_hw {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        col[dst + ox] = if ix < 0 || ix >= s.in_hw as isize {
                            0.0
                        } else {
                            plane[src + ix as usize]
                        };
                    }
                }
            }
        }
    }
}

/// Fold columns back onto an example's input planes, **accumulating**
/// (the adjoint of [`im2col`]; padding taps are dropped).
pub fn col2im(col: &[f32], s: &Conv2dShape, dx: &mut [f32]) {
    let ohw = s.out_hw * s.out_hw;
    debug_assert_eq!(dx.len(), s.in_elems());
    for c in 0..s.cin {
        let plane = &mut dx[c * s.in_hw * s.in_hw..(c + 1) * s.in_hw * s.in_hw];
        for ky in 0..s.k {
            for kx in 0..s.k {
                let row = ((c * s.k + ky) * s.k + kx) * ohw;
                for oy in 0..s.out_hw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    if iy < 0 || iy >= s.in_hw as isize {
                        continue;
                    }
                    let src = row + oy * s.out_hw;
                    let dst = iy as usize * s.in_hw;
                    for ox in 0..s.out_hw {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        if ix >= 0 && ix < s.in_hw as isize {
                            plane[dst + ix as usize] += col[src + ox];
                        }
                    }
                }
            }
        }
    }
}

/// Batched conv forward: `y = W · im2col(x) + b` per example.
pub fn conv2d_forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    col: &mut [f32],
    s: &Conv2dShape,
) {
    let (in_n, out_n, ohw) = (s.in_elems(), s.out_elems(), s.out_hw * s.out_hw);
    let ck2 = s.cin * s.k * s.k;
    debug_assert_eq!(w.len(), s.cout * ck2);
    for bi in 0..s.batch {
        let xe = &x[bi * in_n..(bi + 1) * in_n];
        let ye = &mut y[bi * out_n..(bi + 1) * out_n];
        im2col(xe, s, col);
        ye.fill(0.0);
        matmul_nn(s.cout, ck2, ohw, w, col, ye);
        for (co, yrow) in ye.chunks_exact_mut(ohw).enumerate() {
            let bias = b[co];
            for v in yrow {
                *v += bias;
            }
        }
    }
}

/// Batched conv backward.  `dw`/`db` accumulate, `dx` is overwritten.
/// The im2col columns are recomputed from `x` rather than cached from
/// the forward pass — O(col) extra compute instead of O(batch·col)
/// extra memory.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    col: &mut [f32],
    dcol: &mut [f32],
    s: &Conv2dShape,
) {
    let (in_n, out_n, ohw) = (s.in_elems(), s.out_elems(), s.out_hw * s.out_hw);
    let ck2 = s.cin * s.k * s.k;
    for bi in 0..s.batch {
        let xe = &x[bi * in_n..(bi + 1) * in_n];
        let dye = &dy[bi * out_n..(bi + 1) * out_n];
        let dxe = &mut dx[bi * in_n..(bi + 1) * in_n];
        im2col(xe, s, col);
        for (co, dyrow) in dye.chunks_exact(ohw).enumerate() {
            db[co] += dyrow.iter().sum::<f32>();
        }
        // dW += dY · colᵀ
        matmul_nt(s.cout, ohw, ck2, dye, col, dw);
        // dcol = Wᵀ · dY, then fold back onto the input planes.
        dcol.fill(0.0);
        matmul_tn(ck2, s.cout, ohw, w, dye, dcol);
        dxe.fill(0.0);
        col2im(dcol, s, dxe);
    }
}

/// In-place ReLU.
pub fn relu_forward(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Gate a gradient through ReLU: `da *= (a > 0)`, where `a` is the
/// *post*-activation value (equivalent to the pre-activation test).
pub fn relu_backward(a: &[f32], da: &mut [f32]) {
    for (g, &v) in da.iter_mut().zip(a) {
        if v <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Batched max-pool forward; `argmax` records each output's winning
/// in-plane index for the backward scatter.
pub fn maxpool_forward(x: &[f32], y: &mut [f32], argmax: &mut [u32], s: &PoolShape) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    debug_assert_eq!(y.len(), s.batch * s.channels * out_plane);
    debug_assert_eq!(argmax.len(), y.len());
    for bc in 0..s.batch * s.channels {
        let plane = &x[bc * in_plane..(bc + 1) * in_plane];
        let yp = &mut y[bc * out_plane..(bc + 1) * out_plane];
        let ap = &mut argmax[bc * out_plane..(bc + 1) * out_plane];
        for oy in 0..s.out_hw {
            for ox in 0..s.out_hw {
                let (y0, x0) = (oy * s.stride, ox * s.stride);
                let mut best = f32::NEG_INFINITY;
                let mut best_idx = 0usize;
                for wy in 0..s.window {
                    for wx in 0..s.window {
                        let idx = (y0 + wy) * s.in_hw + (x0 + wx);
                        if plane[idx] > best {
                            best = plane[idx];
                            best_idx = idx;
                        }
                    }
                }
                yp[oy * s.out_hw + ox] = best;
                ap[oy * s.out_hw + ox] = best_idx as u32;
            }
        }
    }
}

/// Max-pool backward: route each output gradient to its argmax tap.
/// `dx` is overwritten.
pub fn maxpool_backward(dy: &[f32], argmax: &[u32], dx: &mut [f32], s: &PoolShape) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    dx.fill(0.0);
    for bc in 0..s.batch * s.channels {
        let dyp = &dy[bc * out_plane..(bc + 1) * out_plane];
        let ap = &argmax[bc * out_plane..(bc + 1) * out_plane];
        let dxp = &mut dx[bc * in_plane..(bc + 1) * in_plane];
        for (&g, &idx) in dyp.iter().zip(ap) {
            dxp[idx as usize] += g;
        }
    }
}

/// Fully-connected forward: `y[b] = W · x[b] + b` (weights `[dout, din]`).
pub fn fc_forward(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32], s: &FcShape) {
    debug_assert_eq!(x.len(), s.batch * s.din);
    debug_assert_eq!(y.len(), s.batch * s.dout);
    y.fill(0.0);
    matmul_nt(s.batch, s.din, s.dout, x, w, y);
    for yrow in y.chunks_exact_mut(s.dout) {
        for (v, bv) in yrow.iter_mut().zip(b) {
            *v += bv;
        }
    }
}

/// Fully-connected backward.  `dw`/`db` accumulate, `dx` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn fc_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    s: &FcShape,
) {
    // dW += dYᵀ · X
    matmul_tn(s.dout, s.batch, s.din, dy, x, dw);
    for dyrow in dy.chunks_exact(s.dout) {
        for (g, &v) in db.iter_mut().zip(dyrow) {
            *g += v;
        }
    }
    // dX = dY · W
    dx.fill(0.0);
    matmul_nn(s.batch, s.dout, s.din, dy, w, dx);
}

/// Inverted dropout: zero with probability `p`, scale survivors by
/// `1/(1-p)` so eval needs no correction.  The per-element scale is
/// recorded in `mask` for the backward pass.
pub fn dropout_forward(a: &mut [f32], mask: &mut [f32], p: f32, rng: &mut Pcg32) {
    debug_assert!((0.0..1.0).contains(&p));
    if p <= 0.0 {
        mask.fill(1.0);
        return;
    }
    let keep_scale = 1.0 / (1.0 - p);
    for (v, m) in a.iter_mut().zip(mask.iter_mut()) {
        if rng.next_f32() < p {
            *v = 0.0;
            *m = 0.0;
        } else {
            *v *= keep_scale;
            *m = keep_scale;
        }
    }
}

/// Dropout backward: replay the recorded scales.
pub fn dropout_backward(da: &mut [f32], mask: &[f32]) {
    for (g, &m) in da.iter_mut().zip(mask) {
        *g *= m;
    }
}

/// Softmax + mean cross-entropy over a batch of logits.
///
/// Writes the per-row softmax into `probs` and the loss gradient
/// `(softmax - onehot)/batch` into `dlogits`; returns the mean loss and
/// the top-1 correct count.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    probs: &mut [f32],
    dlogits: &mut [f32],
    s: &FcShape,
) -> (f32, i32) {
    let classes = s.dout;
    debug_assert_eq!(logits.len(), s.batch * classes);
    debug_assert_eq!(labels.len(), s.batch);
    let inv_batch = 1.0 / s.batch as f32;
    let mut loss = 0.0f64;
    let mut correct1 = 0i32;
    for (bi, &label) in labels.iter().enumerate() {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let prow = &mut probs[bi * classes..(bi + 1) * classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for (p, &v) in prow.iter_mut().zip(row) {
            *p = (v - max).exp();
            sum += *p;
        }
        let inv_sum = 1.0 / sum;
        for p in prow.iter_mut() {
            *p *= inv_sum;
        }
        let li = label as usize;
        loss -= (prow[li].max(1e-12) as f64).ln();
        if crate::util::math::argmax(row) == li {
            correct1 += 1;
        }
        let drow = &mut dlogits[bi * classes..(bi + 1) * classes];
        for (d, &p) in drow.iter_mut().zip(prow.iter()) {
            *d = p * inv_batch;
        }
        drow[li] -= inv_batch;
    }
    ((loss as f32) * inv_batch, correct1)
}

/// Is `label` within the top-`k` entries of `row` (ties resolved
/// generously, matching the usual top-k error convention)?
pub fn topk_correct(row: &[f32], label: usize, k: usize) -> bool {
    let v = row[label];
    row.iter().filter(|&&x| x > v).count() < k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: columns are the input itself.
        let s = Conv2dShape {
            batch: 1,
            cin: 2,
            cout: 1,
            k: 1,
            stride: 1,
            pad: 0,
            in_hw: 3,
            out_hw: 3,
        };
        let x: Vec<f32> = (0..18).map(|v| v as f32).collect();
        let mut col = vec![0.0; s.col_elems()];
        im2col(&x, &s, &mut col);
        assert_eq!(col, x);
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> for random x, c — the
        // defining property of an adjoint pair.
        let s = Conv2dShape {
            batch: 1,
            cin: 2,
            cout: 1,
            k: 3,
            stride: 2,
            pad: 1,
            in_hw: 5,
            out_hw: 3,
        };
        let mut rng = crate::util::Pcg32::seeded(4);
        let mut x = vec![0.0; s.in_elems()];
        let mut c = vec![0.0; s.col_elems()];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut c, 1.0);
        let mut col = vec![0.0; s.col_elems()];
        im2col(&x, &s, &mut col);
        let lhs: f64 = col.iter().zip(&c).map(|(a, b)| (a * b) as f64).sum();
        let mut folded = vec![0.0; s.in_elems()];
        col2im(&c, &s, &mut folded);
        let rhs: f64 = x.iter().zip(&folded).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn relu_and_mask() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu_forward(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut da = vec![5.0, 5.0, 5.0];
        relu_backward(&x, &mut da);
        assert_eq!(da, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn maxpool_tracks_argmax() {
        let s = PoolShape { batch: 1, channels: 1, in_hw: 4, window: 2, stride: 2, out_hw: 2 };
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0, 0.0, 0.0,
            3.0, 4.0, 0.0, 9.0,
            0.0, 0.0, 1.0, 1.0,
            7.0, 0.0, 1.0, 1.0,
        ];
        let mut y = vec![0.0; 4];
        let mut am = vec![0u32; 4];
        maxpool_forward(&x, &mut y, &mut am, &s);
        assert_eq!(y, vec![4.0, 9.0, 7.0, 1.0]);
        let mut dx = vec![0.0; 16];
        maxpool_backward(&[1.0, 1.0, 1.0, 1.0], &am, &mut dx, &s);
        assert_eq!(dx[5], 1.0); // the 4.0
        assert_eq!(dx[7], 1.0); // the 9.0
        assert_eq!(dx[12], 1.0); // the 7.0
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn fc_forward_small() {
        let s = FcShape { batch: 2, din: 3, dout: 2 };
        let x = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        let b = vec![0.5, -0.5];
        let mut y = vec![0.0; 4];
        fc_forward(&x, &w, &b, &mut y, &s);
        assert_eq!(y, vec![1.5, 3.5, 2.5, 4.5]);
    }

    #[test]
    fn dropout_expectation_and_mask_replay() {
        let mut rng = crate::util::Pcg32::seeded(8);
        let n = 20_000;
        let mut a = vec![1.0f32; n];
        let mut mask = vec![0.0f32; n];
        dropout_forward(&mut a, &mut mask, 0.5, &mut rng);
        let mean = a.iter().sum::<f32>() / n as f32;
        // Inverted dropout preserves the expectation.
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
        let mut da = vec![1.0f32; n];
        dropout_backward(&mut da, &mask);
        assert_eq!(da, a);
        // p = 0 is the identity and an all-ones mask.
        let mut b = vec![2.0f32; 4];
        let mut m2 = vec![0.0f32; 4];
        dropout_forward(&mut b, &mut m2, 0.0, &mut rng);
        assert_eq!(b, vec![2.0; 4]);
        assert_eq!(m2, vec![1.0; 4]);
    }

    #[test]
    fn softmax_uniform_logits() {
        let s = FcShape { batch: 2, din: 0, dout: 4 };
        let logits = vec![0.0; 8];
        let labels = vec![1, 3];
        let mut probs = vec![0.0; 8];
        let mut dl = vec![0.0; 8];
        let (loss, c1) = softmax_xent(&logits, &labels, &mut probs, &mut dl, &s);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        assert!(probs.iter().all(|&p| (p - 0.25).abs() < 1e-6));
        // argmax of a uniform row is index 0 => only a label-0 row counts.
        assert_eq!(c1, 0);
        // Gradient rows sum to zero.
        assert!(dl[..4].iter().sum::<f32>().abs() < 1e-6);
    }

    #[test]
    fn topk_membership() {
        let row = vec![0.1, 0.9, 0.5, 0.3];
        assert!(topk_correct(&row, 1, 1));
        assert!(!topk_correct(&row, 3, 1));
        assert!(topk_correct(&row, 3, 3));
        assert!(topk_correct(&row, 0, 4));
    }
}
