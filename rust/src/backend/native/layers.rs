//! The layer zoo: im2col convolution (grouped or plain), cross-channel
//! local response normalization, ReLU, max-pool, fully-connected,
//! inverted dropout and softmax cross-entropy — forward *and* backward,
//! in pure Rust over flat `f32` slices.
//!
//! Conventions shared by every kernel:
//!
//! - activations are batch-major NCHW (`[batch, channels, h, w]`) or
//!   `[batch, features]`, row-major, matching [`HostTensor`]'s layout
//!   (so the last conv output doubles as the first FC input with no
//!   reshape);
//! - weight gradients **accumulate** (the caller zeroes once per step),
//!   input gradients are overwritten;
//! - all staging (im2col columns, packed-GEMM panels) lives in
//!   caller-owned, reused buffers (zero steady-state allocations, same
//!   discipline as the exchange path).
//!
//! Each kernel exists in a serial form (the reference the gradient
//! checks probe) and, for the batch/plane/element-parallel hot path, a
//! `*_pool` form driven by the [`ComputePool`].  The pool forms follow
//! the pool's determinism contract: chunk boundaries come from the
//! shape alone, chunks write disjoint outputs (or chunk-owned
//! accumulators reduced in fixed order), so results are bit-identical
//! for any lane count.  Forward kernels and the FC backward are even
//! bitwise equal to their serial forms; the conv backward regroups the
//! per-example gradient sum by chunk (same values to f32 rounding).
//!
//! The conv pool path stages each example's im2col columns in a
//! caller-owned **batch-wide cache** on the forward pass and reuses
//! them verbatim on the backward pass (dW needs exactly those columns),
//! instead of re-unfolding every example a second time — the serial
//! reference forms keep recomputing so the gradient checks stay
//! self-contained.
//!
//! [`HostTensor`]: crate::tensor::HostTensor

use crate::backend::native::gemm::{
    matmul_nn, matmul_nn_ws, matmul_nt, matmul_nt_ws, matmul_tn, matmul_tn_ws, par_matmul_nn,
    par_matmul_nt, par_matmul_tn, PackBuf,
};
use crate::backend::native::pool::{
    par_chunks_mut, shape_chunks, ComputePool, ELEMWISE_CHUNK, SendPtr,
};
use crate::util::Pcg32;

/// Geometry of one conv layer (weights `[cout, cin/groups, k, k]`).
///
/// `groups > 1` splits the channels into independent filter groups
/// (the two-GPU split of Krizhevsky 2012 baked into the architecture):
/// group `g` convolves input channels `[g·cin/G, (g+1)·cin/G)` into
/// output channels `[g·cout/G, (g+1)·cout/G)`.  Both channel ranges and
/// the group's weight block are contiguous in the NCHW / `[cout, …]`
/// layouts, so every grouped kernel is the ungrouped kernel applied to
/// `G` slices — at `groups == 1` the loops degenerate to the exact
/// ungrouped call sequence (same GEMMs, same accumulation order,
/// bitwise identical).
#[derive(Clone, Copy, Debug)]
pub struct Conv2dShape {
    pub batch: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub groups: usize,
}

impl Conv2dShape {
    /// Elements of one example's input plane stack.
    pub fn in_elems(&self) -> usize {
        self.cin * self.in_hw * self.in_hw
    }

    /// Elements of one example's output plane stack.
    pub fn out_elems(&self) -> usize {
        self.cout * self.out_hw * self.out_hw
    }

    /// Elements of the per-example im2col staging: `groups` panels of
    /// `[(cin/groups)·k², out_hw²]` back to back — totalling
    /// `cin·k²·out_hw²` regardless of the group count.
    pub fn col_elems(&self) -> usize {
        self.cin * self.k * self.k * self.out_hw * self.out_hw
    }

    /// Elements of the weight tensor `[cout, cin/groups, k, k]`.
    pub fn w_elems(&self) -> usize {
        self.cout * (self.cin / self.groups) * self.k * self.k
    }

    /// The per-group sub-problem: an ungrouped conv over `cin/groups`
    /// input and `cout/groups` output channels, same geometry otherwise.
    pub fn group_shape(&self) -> Conv2dShape {
        debug_assert!(self.groups >= 1);
        debug_assert_eq!(self.cin % self.groups, 0);
        debug_assert_eq!(self.cout % self.groups, 0);
        Conv2dShape {
            cin: self.cin / self.groups,
            cout: self.cout / self.groups,
            groups: 1,
            ..*self
        }
    }
}

/// Geometry of one max-pool layer.
#[derive(Clone, Copy, Debug)]
pub struct PoolShape {
    pub batch: usize,
    pub channels: usize,
    pub in_hw: usize,
    pub window: usize,
    pub stride: usize,
    pub out_hw: usize,
}

/// Geometry of one fully-connected layer (weights `[dout, din]`).
#[derive(Clone, Copy, Debug)]
pub struct FcShape {
    pub batch: usize,
    pub din: usize,
    pub dout: usize,
}

/// Unfold one example `[cin, in_hw, in_hw]` into columns
/// `[cin·k², out_hw²]`; out-of-image taps (padding) become zeros.
pub fn im2col(x: &[f32], s: &Conv2dShape, col: &mut [f32]) {
    let ohw = s.out_hw * s.out_hw;
    debug_assert_eq!(x.len(), s.in_elems());
    debug_assert_eq!(col.len(), s.cin * s.k * s.k * ohw);
    for c in 0..s.cin {
        let plane = &x[c * s.in_hw * s.in_hw..(c + 1) * s.in_hw * s.in_hw];
        for ky in 0..s.k {
            for kx in 0..s.k {
                let row = ((c * s.k + ky) * s.k + kx) * ohw;
                for oy in 0..s.out_hw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    let dst = row + oy * s.out_hw;
                    if iy < 0 || iy >= s.in_hw as isize {
                        col[dst..dst + s.out_hw].fill(0.0);
                        continue;
                    }
                    let src = iy as usize * s.in_hw;
                    for ox in 0..s.out_hw {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        col[dst + ox] = if ix < 0 || ix >= s.in_hw as isize {
                            0.0
                        } else {
                            plane[src + ix as usize]
                        };
                    }
                }
            }
        }
    }
}

/// Fold columns back onto an example's input planes, **accumulating**
/// (the adjoint of [`im2col`]; padding taps are dropped).
pub fn col2im(col: &[f32], s: &Conv2dShape, dx: &mut [f32]) {
    let ohw = s.out_hw * s.out_hw;
    debug_assert_eq!(dx.len(), s.in_elems());
    for c in 0..s.cin {
        let plane = &mut dx[c * s.in_hw * s.in_hw..(c + 1) * s.in_hw * s.in_hw];
        for ky in 0..s.k {
            for kx in 0..s.k {
                let row = ((c * s.k + ky) * s.k + kx) * ohw;
                for oy in 0..s.out_hw {
                    let iy = (oy * s.stride + ky) as isize - s.pad as isize;
                    if iy < 0 || iy >= s.in_hw as isize {
                        continue;
                    }
                    let src = row + oy * s.out_hw;
                    let dst = iy as usize * s.in_hw;
                    for ox in 0..s.out_hw {
                        let ix = (ox * s.stride + kx) as isize - s.pad as isize;
                        if ix >= 0 && ix < s.in_hw as isize {
                            plane[dst + ix as usize] += col[src + ox];
                        }
                    }
                }
            }
        }
    }
}

/// One example of the conv forward: per group `g`,
/// `ye[g] = W[g] · im2col(xe[g]) + b[g]` over the group's contiguous
/// channel/weight slices.  `col` receives the example's columns, one
/// group panel after another (the backward pass reuses them when the
/// caller keeps a batch-wide cache).  With `groups == 1` this is the
/// plain ungrouped forward, bit for bit.
fn conv2d_forward_one(
    xe: &[f32],
    w: &[f32],
    b: &[f32],
    ye: &mut [f32],
    col: &mut [f32],
    pack: &mut PackBuf,
    s: &Conv2dShape,
) {
    let gs = s.group_shape();
    let ohw = gs.out_hw * gs.out_hw;
    let ck2 = gs.cin * gs.k * gs.k;
    let (g_in, g_out, g_col, g_w) = (gs.in_elems(), gs.out_elems(), gs.col_elems(), gs.w_elems());
    for g in 0..s.groups {
        let xg = &xe[g * g_in..(g + 1) * g_in];
        let wg = &w[g * g_w..(g + 1) * g_w];
        let colg = &mut col[g * g_col..(g + 1) * g_col];
        let yg = &mut ye[g * g_out..(g + 1) * g_out];
        im2col(xg, &gs, colg);
        yg.fill(0.0);
        matmul_nn_ws(gs.cout, ck2, ohw, wg, colg, yg, pack);
        for (co, yrow) in yg.chunks_exact_mut(ohw).enumerate() {
            let bias = b[g * gs.cout + co];
            for v in yrow {
                *v += bias;
            }
        }
    }
}

/// Batched conv forward: `y = W · im2col(x) + b` per example (serial
/// reference; the hot path is [`conv2d_forward_pool`]).
pub fn conv2d_forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    col: &mut [f32],
    s: &Conv2dShape,
) {
    let (in_n, out_n) = (s.in_elems(), s.out_elems());
    debug_assert_eq!(w.len(), s.w_elems());
    let mut pack = PackBuf::default();
    for bi in 0..s.batch {
        let xe = &x[bi * in_n..(bi + 1) * in_n];
        let ye = &mut y[bi * out_n..(bi + 1) * out_n];
        conv2d_forward_one(xe, w, b, ye, col, &mut pack, s);
    }
}

/// Batch-parallel conv forward.  Examples are independent (disjoint
/// output and column slices, lane-owned pack buffers), so this is
/// bitwise equal to [`conv2d_forward`] for any lane count.
///
/// With `col_cache: Some` (the training path) each example's im2col
/// columns land in its slice of the batch-wide cache
/// (`batch × col_elems`), where [`conv2d_backward_pool`] reuses them.
/// With `None` (eval-only forwards — no backward will follow) columns
/// are staged in the per-lane `scratch.dcols` buffers instead, which
/// are idle during the forward pass; the staging location cannot change
/// a bit of the output.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_pool(
    pool: &ComputePool,
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    col_cache: Option<&mut [f32]>,
    scratch: &mut ConvScratch,
    s: &Conv2dShape,
) {
    let (in_n, out_n, col_n) = (s.in_elems(), s.out_elems(), s.col_elems());
    debug_assert_eq!(w.len(), s.w_elems());
    debug_assert!(scratch.packs.len() >= pool.lanes());
    let (n_chunks, per) = shape_chunks(s.batch);
    let y_ptr = SendPtr::new(y.as_mut_ptr());
    let cache_ptr = col_cache.map(|cc| {
        debug_assert_eq!(cc.len(), s.batch * col_n);
        SendPtr::new(cc.as_mut_ptr())
    });
    debug_assert!(cache_ptr.is_some() || scratch.dcols.len() >= pool.lanes());
    debug_assert!(cache_ptr.is_some() || scratch.dcols.iter().all(|d| d.len() >= col_n));
    let pack_ptr = SendPtr::new(scratch.packs.as_mut_ptr());
    let dcol_ptr = SendPtr::new(scratch.dcols.as_mut_ptr());
    pool.run_chunks(n_chunks, &|lane, ci| {
        // SAFETY: packs[lane]/dcols[lane] are exclusive to this lane,
        // and each example's output and cache slices are touched by
        // exactly one chunk.
        let pack = unsafe { &mut *pack_ptr.get().add(lane) };
        for bi in ci * per..((ci + 1) * per).min(s.batch) {
            let xe = &x[bi * in_n..(bi + 1) * in_n];
            let ye =
                unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(bi * out_n), out_n) };
            let col = match cache_ptr {
                Some(p) => unsafe {
                    std::slice::from_raw_parts_mut(p.get().add(bi * col_n), col_n)
                },
                None => unsafe {
                    let d = &mut *dcol_ptr.get().add(lane);
                    std::slice::from_raw_parts_mut(d.as_mut_ptr(), col_n)
                },
            };
            conv2d_forward_one(xe, w, b, ye, col, pack, s);
        }
    });
}

/// One example of the conv backward, driven by the example's im2col
/// columns (`col` — cached from the forward pass on the pool path,
/// freshly recomputed on the serial reference path).  `dw`/`db`
/// accumulate into the caller's target (the global gradient serially, a
/// chunk accumulator in the pool path), `dxe` is overwritten.
#[allow(clippy::too_many_arguments)]
fn conv2d_backward_cols(
    col: &[f32],
    w: &[f32],
    dye: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dxe: &mut [f32],
    dcol: &mut [f32],
    pack: &mut PackBuf,
    s: &Conv2dShape,
) {
    let gs = s.group_shape();
    let ohw = gs.out_hw * gs.out_hw;
    let ck2 = gs.cin * gs.k * gs.k;
    let (g_in, g_out, g_col, g_w) = (gs.in_elems(), gs.out_elems(), gs.col_elems(), gs.w_elems());
    for g in 0..s.groups {
        let colg = &col[g * g_col..(g + 1) * g_col];
        let wg = &w[g * g_w..(g + 1) * g_w];
        let dyg = &dye[g * g_out..(g + 1) * g_out];
        let dwg = &mut dw[g * g_w..(g + 1) * g_w];
        let dbg = &mut db[g * gs.cout..(g + 1) * gs.cout];
        let dxg = &mut dxe[g * g_in..(g + 1) * g_in];
        for (co, dyrow) in dyg.chunks_exact(ohw).enumerate() {
            dbg[co] += dyrow.iter().sum::<f32>();
        }
        // dW[g] += dY[g] · col[g]ᵀ
        matmul_nt_ws(gs.cout, ohw, ck2, dyg, colg, dwg, pack);
        // dcol = W[g]ᵀ · dY[g], then fold back onto the group's planes.
        let dcolg = &mut dcol[..g_col];
        dcolg.fill(0.0);
        matmul_tn_ws(ck2, gs.cout, ohw, wg, dyg, dcolg, pack);
        dxg.fill(0.0);
        col2im(dcolg, &gs, dxg);
    }
}

/// Batched conv backward (serial reference).  `dw`/`db` accumulate,
/// `dx` is overwritten.  This form recomputes the im2col columns from
/// `x` so the gradient checks stay self-contained; the pool form reuses
/// the forward pass's cached columns instead.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    col: &mut [f32],
    dcol: &mut [f32],
    s: &Conv2dShape,
) {
    let (in_n, out_n) = (s.in_elems(), s.out_elems());
    let mut pack = PackBuf::default();
    for bi in 0..s.batch {
        let xe = &x[bi * in_n..(bi + 1) * in_n];
        let dye = &dy[bi * out_n..(bi + 1) * out_n];
        let dxe = &mut dx[bi * in_n..(bi + 1) * in_n];
        im2col(xe, s, col);
        conv2d_backward_cols(col, w, dye, dw, db, dxe, dcol, &mut pack, s);
    }
}

/// Lane- and chunk-indexed scratch for the batch-parallel conv path:
/// per-lane column-gradient staging (`dcols`, shared across layers at
/// the largest size), per-lane packed-GEMM panels (`packs`, grown on
/// first use inside the kernels), and per-chunk gradient accumulators
/// (`gw`/`gb`).  The chunk accumulators are what make the parallel
/// weight-gradient sum lane-count-invariant: chunk `ci` always holds
/// exactly the same examples, and the final reduction walks chunks in
/// index order.  (Forward im2col columns are *not* staged here any
/// more — they live in the caller's batch-wide cache so the backward
/// pass can reuse them.)
#[derive(Debug, Default)]
pub struct ConvScratch {
    pub dcols: Vec<Vec<f32>>,
    /// Per-lane GEMM pack workspaces; [`PackBuf`]'s own 64-byte-aligned
    /// arenas, so the SIMD microkernels get aligned panels on the conv
    /// path too.
    pub packs: Vec<PackBuf>,
    pub gw: Vec<Vec<f32>>,
    pub gb: Vec<Vec<f32>>,
}

impl ConvScratch {
    /// Size for `lanes` column-gradient buffers of `col_elems`, `lanes`
    /// pack workspaces and `n_chunks` gradient accumulators of the
    /// largest conv layer's `max_w`/`max_b`.
    pub fn ensure(
        &mut self,
        lanes: usize,
        n_chunks: usize,
        col_elems: usize,
        max_w: usize,
        max_b: usize,
    ) {
        resize_bufs(&mut self.dcols, lanes, col_elems);
        if self.packs.len() < lanes {
            self.packs.resize_with(lanes, PackBuf::default);
        }
        resize_bufs(&mut self.gw, n_chunks, max_w);
        resize_bufs(&mut self.gb, n_chunks, max_b);
    }
}

fn resize_bufs(bufs: &mut Vec<Vec<f32>>, n: usize, len: usize) {
    bufs.resize_with(n, Vec::new);
    for b in bufs.iter_mut() {
        if b.len() != len {
            *b = vec![0.0; len];
        }
    }
}

/// Batch-parallel conv backward, fed by the forward pass's `col_cache`
/// (each example's im2col columns, written by
/// [`conv2d_forward_pool`] — never recomputed here).  Phase 1
/// partitions the batch into shape-fixed chunks, each accumulating its
/// examples (in batch order) into its own `gw`/`gb` buffer while
/// writing disjoint `dx` slices; phase 2 reduces the chunk accumulators
/// into `dw`/`db` in chunk order.  Bit-identical for any lane count.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_pool(
    pool: &ComputePool,
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    col_cache: &[f32],
    scratch: &mut ConvScratch,
    s: &Conv2dShape,
) {
    let (in_n, out_n, col_n) = (s.in_elems(), s.out_elems(), s.col_elems());
    let (n_chunks, per) = shape_chunks(s.batch);
    let (w_len, b_len) = (w.len(), db.len());
    debug_assert_eq!(col_cache.len(), s.batch * col_n);
    debug_assert!(scratch.dcols.len() >= pool.lanes());
    debug_assert!(scratch.packs.len() >= pool.lanes());
    debug_assert!(scratch.gw.len() >= n_chunks);
    debug_assert!(scratch.gw.iter().all(|g| g.len() >= w_len));
    {
        let dx_ptr = SendPtr::new(dx.as_mut_ptr());
        let dcol_ptr = SendPtr::new(scratch.dcols.as_mut_ptr());
        let pack_ptr = SendPtr::new(scratch.packs.as_mut_ptr());
        let gw_ptr = SendPtr::new(scratch.gw.as_mut_ptr());
        let gb_ptr = SendPtr::new(scratch.gb.as_mut_ptr());
        pool.run_chunks(n_chunks, &|lane, ci| {
            // SAFETY: dcols/packs are lane-owned, gw/gb chunk-owned, and
            // dx example slices disjoint across the batch partition.
            let dcol = unsafe { &mut *dcol_ptr.get().add(lane) };
            let pack = unsafe { &mut *pack_ptr.get().add(lane) };
            let gw = unsafe { &mut *gw_ptr.get().add(ci) };
            let gb = unsafe { &mut *gb_ptr.get().add(ci) };
            let dcol = &mut dcol[..col_n];
            let gw = &mut gw[..w_len];
            let gb = &mut gb[..b_len];
            gw.fill(0.0);
            gb.fill(0.0);
            for bi in ci * per..((ci + 1) * per).min(s.batch) {
                let col = &col_cache[bi * col_n..(bi + 1) * col_n];
                let dye = &dy[bi * out_n..(bi + 1) * out_n];
                let dxe = unsafe {
                    std::slice::from_raw_parts_mut(dx_ptr.get().add(bi * in_n), in_n)
                };
                conv2d_backward_cols(col, w, dye, gw, gb, dxe, dcol, pack, s);
            }
        });
    }
    let gw_chunks = &scratch.gw;
    par_chunks_mut(pool, dw, ELEMWISE_CHUNK, |ci, dchunk| {
        let lo = ci * ELEMWISE_CHUNK;
        let len = dchunk.len();
        for gw in &gw_chunks[..n_chunks] {
            for (d, g) in dchunk.iter_mut().zip(&gw[lo..lo + len]) {
                *d += g;
            }
        }
    });
    for gb in &scratch.gb[..n_chunks] {
        for (d, g) in db.iter_mut().zip(gb) {
            *d += g;
        }
    }
}

/// Geometry + constants of one cross-channel LRN layer (NCHW).
///
/// Matches python/compile/kernels/ref.py::lrn_ref:
/// `y_c = x_c / (bias + (alpha/n) · Σ_{|c'-c| ≤ radius} x_{c'}²)^beta`
/// with `n = 2·radius + 1` and the window clipped at the channel edges.
#[derive(Clone, Copy, Debug)]
pub struct LrnShape {
    pub batch: usize,
    pub channels: usize,
    pub hw: usize,
    pub radius: usize,
    pub bias: f32,
    pub alpha: f32,
    pub beta: f32,
}

impl LrnShape {
    /// Elements of one example (input and output shapes are equal).
    pub fn elems(&self) -> usize {
        self.channels * self.hw * self.hw
    }

    /// The `alpha/n` window-normalized coefficient.
    fn alpha_over_n(&self) -> f32 {
        self.alpha / (2 * self.radius + 1) as f32
    }

    /// Window sum of squares around channel `c` at in-plane offset `p`.
    #[inline]
    fn sq_window(&self, xe: &[f32], c: usize, p: usize) -> f32 {
        let plane = self.hw * self.hw;
        let lo = c.saturating_sub(self.radius);
        let hi = (c + self.radius).min(self.channels - 1);
        let mut sum = 0.0f32;
        for cc in lo..=hi {
            let v = xe[cc * plane + p];
            sum += v * v;
        }
        sum
    }
}

/// One example of the LRN forward.
fn lrn_forward_one(xe: &[f32], ye: &mut [f32], s: &LrnShape) {
    let plane = s.hw * s.hw;
    let a = s.alpha_over_n();
    for p in 0..plane {
        for c in 0..s.channels {
            let base = s.bias + a * s.sq_window(xe, c, p);
            ye[c * plane + p] = xe[c * plane + p] / base.powf(s.beta);
        }
    }
}

/// One example of the LRN backward, differentiating the reference
/// formula at the saved input `xe` (the scale denominators are
/// recomputed from it, exactly like the Python reference's vjp):
///
/// with `base_c = bias + a·Σ_W x²` and `y_c = x_c · base_c^{-β}`,
///
/// `dx_m = dy_m · base_m^{-β}
///         − 2aβ · x_m · Σ_{c ∈ W(m)} dy_c · y_c / base_c`.
fn lrn_backward_one(xe: &[f32], ye: &[f32], dye: &[f32], dxe: &mut [f32], s: &LrnShape) {
    let plane = s.hw * s.hw;
    let a = s.alpha_over_n();
    let two_ab = 2.0 * a * s.beta;
    for p in 0..plane {
        for m in 0..s.channels {
            let base_m = s.bias + a * s.sq_window(xe, m, p);
            let lo = m.saturating_sub(s.radius);
            let hi = (m + s.radius).min(s.channels - 1);
            let mut corr = 0.0f32;
            for c in lo..=hi {
                let base_c = s.bias + a * s.sq_window(xe, c, p);
                corr += dye[c * plane + p] * ye[c * plane + p] / base_c;
            }
            dxe[m * plane + p] =
                dye[m * plane + p] * base_m.powf(-s.beta) - two_ab * xe[m * plane + p] * corr;
        }
    }
}

/// Batched LRN forward (serial reference).
pub fn lrn_forward(x: &[f32], y: &mut [f32], s: &LrnShape) {
    let n = s.elems();
    debug_assert_eq!(x.len(), s.batch * n);
    debug_assert_eq!(y.len(), s.batch * n);
    for bi in 0..s.batch {
        lrn_forward_one(&x[bi * n..(bi + 1) * n], &mut y[bi * n..(bi + 1) * n], s);
    }
}

/// Batch-parallel [`lrn_forward`].  Every output element is a pure
/// function of its own example's channel window and examples land in
/// disjoint chunks, so this is bitwise equal to the serial form for any
/// lane count.
pub fn lrn_forward_pool(pool: &ComputePool, x: &[f32], y: &mut [f32], s: &LrnShape) {
    let n = s.elems();
    debug_assert_eq!(x.len(), s.batch * n);
    debug_assert_eq!(y.len(), s.batch * n);
    let (n_chunks, per) = shape_chunks(s.batch);
    let y_ptr = SendPtr::new(y.as_mut_ptr());
    pool.run_chunks(n_chunks, &|_lane, ci| {
        for bi in ci * per..((ci + 1) * per).min(s.batch) {
            let xe = &x[bi * n..(bi + 1) * n];
            // SAFETY: example bi's output slice belongs to exactly one
            // chunk.
            let ye = unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(bi * n), n) };
            lrn_forward_one(xe, ye, s);
        }
    });
}

/// Batched LRN backward (serial reference).  `x`/`y` are the saved
/// layer input and output; `dx` is overwritten.
pub fn lrn_backward(x: &[f32], y: &[f32], dy: &[f32], dx: &mut [f32], s: &LrnShape) {
    let n = s.elems();
    debug_assert_eq!(dy.len(), s.batch * n);
    debug_assert_eq!(dx.len(), s.batch * n);
    for bi in 0..s.batch {
        lrn_backward_one(
            &x[bi * n..(bi + 1) * n],
            &y[bi * n..(bi + 1) * n],
            &dy[bi * n..(bi + 1) * n],
            &mut dx[bi * n..(bi + 1) * n],
            s,
        );
    }
}

/// Batch-parallel [`lrn_backward`] (disjoint `dx` example slices;
/// bitwise equal to the serial form for any lane count).
pub fn lrn_backward_pool(
    pool: &ComputePool,
    x: &[f32],
    y: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    s: &LrnShape,
) {
    let n = s.elems();
    debug_assert_eq!(dy.len(), s.batch * n);
    debug_assert_eq!(dx.len(), s.batch * n);
    let (n_chunks, per) = shape_chunks(s.batch);
    let dx_ptr = SendPtr::new(dx.as_mut_ptr());
    pool.run_chunks(n_chunks, &|_lane, ci| {
        for bi in ci * per..((ci + 1) * per).min(s.batch) {
            let xe = &x[bi * n..(bi + 1) * n];
            let ye = &y[bi * n..(bi + 1) * n];
            let dye = &dy[bi * n..(bi + 1) * n];
            // SAFETY: example bi's dx slice belongs to exactly one chunk.
            let dxe = unsafe { std::slice::from_raw_parts_mut(dx_ptr.get().add(bi * n), n) };
            lrn_backward_one(xe, ye, dye, dxe, s);
        }
    });
}

/// In-place ReLU.
pub fn relu_forward(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Element-parallel [`relu_forward`] (bitwise equal: elementwise op,
/// fixed chunk boundaries).
pub fn relu_forward_pool(pool: &ComputePool, x: &mut [f32]) {
    par_chunks_mut(pool, x, ELEMWISE_CHUNK, |_ci, chunk| relu_forward(chunk));
}

/// Gate a gradient through ReLU: `da *= (a > 0)`, where `a` is the
/// *post*-activation value (equivalent to the pre-activation test).
pub fn relu_backward(a: &[f32], da: &mut [f32]) {
    for (g, &v) in da.iter_mut().zip(a) {
        if v <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Element-parallel [`relu_backward`].
pub fn relu_backward_pool(pool: &ComputePool, a: &[f32], da: &mut [f32]) {
    debug_assert_eq!(a.len(), da.len());
    par_chunks_mut(pool, da, ELEMWISE_CHUNK, |ci, chunk| {
        let lo = ci * ELEMWISE_CHUNK;
        relu_backward(&a[lo..lo + chunk.len()], chunk);
    });
}

/// One (batch, channel) plane of the max-pool forward.
fn maxpool_plane_forward(plane: &[f32], yp: &mut [f32], ap: &mut [u32], s: &PoolShape) {
    for oy in 0..s.out_hw {
        for ox in 0..s.out_hw {
            let (y0, x0) = (oy * s.stride, ox * s.stride);
            let mut best = f32::NEG_INFINITY;
            let mut best_idx = 0usize;
            for wy in 0..s.window {
                for wx in 0..s.window {
                    let idx = (y0 + wy) * s.in_hw + (x0 + wx);
                    if plane[idx] > best {
                        best = plane[idx];
                        best_idx = idx;
                    }
                }
            }
            yp[oy * s.out_hw + ox] = best;
            ap[oy * s.out_hw + ox] = best_idx as u32;
        }
    }
}

/// Batched max-pool forward; `argmax` records each output's winning
/// in-plane index for the backward scatter.
pub fn maxpool_forward(x: &[f32], y: &mut [f32], argmax: &mut [u32], s: &PoolShape) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    debug_assert_eq!(y.len(), s.batch * s.channels * out_plane);
    debug_assert_eq!(argmax.len(), y.len());
    for bc in 0..s.batch * s.channels {
        let plane = &x[bc * in_plane..(bc + 1) * in_plane];
        let yp = &mut y[bc * out_plane..(bc + 1) * out_plane];
        let ap = &mut argmax[bc * out_plane..(bc + 1) * out_plane];
        maxpool_plane_forward(plane, yp, ap, s);
    }
}

/// Plane-parallel [`maxpool_forward`] (planes are independent; bitwise
/// equal for any lane count).
pub fn maxpool_forward_pool(
    pool: &ComputePool,
    x: &[f32],
    y: &mut [f32],
    argmax: &mut [u32],
    s: &PoolShape,
) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    let planes = s.batch * s.channels;
    debug_assert_eq!(y.len(), planes * out_plane);
    debug_assert_eq!(argmax.len(), y.len());
    let (n_chunks, per) = shape_chunks(planes);
    let y_ptr = SendPtr::new(y.as_mut_ptr());
    let a_ptr = SendPtr::new(argmax.as_mut_ptr());
    pool.run_chunks(n_chunks, &|_lane, ci| {
        for bc in ci * per..((ci + 1) * per).min(planes) {
            let plane = &x[bc * in_plane..(bc + 1) * in_plane];
            // SAFETY: plane bc's output/argmax slices belong to exactly
            // one chunk.
            let yp = unsafe {
                std::slice::from_raw_parts_mut(y_ptr.get().add(bc * out_plane), out_plane)
            };
            let ap = unsafe {
                std::slice::from_raw_parts_mut(a_ptr.get().add(bc * out_plane), out_plane)
            };
            maxpool_plane_forward(plane, yp, ap, s);
        }
    });
}

/// One plane of the max-pool backward: zero, then route each output
/// gradient to its argmax tap.
fn maxpool_plane_backward(dyp: &[f32], ap: &[u32], dxp: &mut [f32]) {
    dxp.fill(0.0);
    for (&g, &idx) in dyp.iter().zip(ap) {
        dxp[idx as usize] += g;
    }
}

/// Max-pool backward: route each output gradient to its argmax tap.
/// `dx` is overwritten.
pub fn maxpool_backward(dy: &[f32], argmax: &[u32], dx: &mut [f32], s: &PoolShape) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    for bc in 0..s.batch * s.channels {
        let dyp = &dy[bc * out_plane..(bc + 1) * out_plane];
        let ap = &argmax[bc * out_plane..(bc + 1) * out_plane];
        let dxp = &mut dx[bc * in_plane..(bc + 1) * in_plane];
        maxpool_plane_backward(dyp, ap, dxp);
    }
}

/// Plane-parallel [`maxpool_backward`] (disjoint `dx` planes; bitwise
/// equal for any lane count).
pub fn maxpool_backward_pool(
    pool: &ComputePool,
    dy: &[f32],
    argmax: &[u32],
    dx: &mut [f32],
    s: &PoolShape,
) {
    let in_plane = s.in_hw * s.in_hw;
    let out_plane = s.out_hw * s.out_hw;
    let planes = s.batch * s.channels;
    let (n_chunks, per) = shape_chunks(planes);
    let dx_ptr = SendPtr::new(dx.as_mut_ptr());
    pool.run_chunks(n_chunks, &|_lane, ci| {
        for bc in ci * per..((ci + 1) * per).min(planes) {
            let dyp = &dy[bc * out_plane..(bc + 1) * out_plane];
            let ap = &argmax[bc * out_plane..(bc + 1) * out_plane];
            // SAFETY: plane bc's dx slice belongs to exactly one chunk.
            let dxp = unsafe {
                std::slice::from_raw_parts_mut(dx_ptr.get().add(bc * in_plane), in_plane)
            };
            maxpool_plane_backward(dyp, ap, dxp);
        }
    });
}

/// Fully-connected forward: `y[b] = W · x[b] + b` (weights `[dout, din]`).
pub fn fc_forward(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32], s: &FcShape) {
    debug_assert_eq!(x.len(), s.batch * s.din);
    debug_assert_eq!(y.len(), s.batch * s.dout);
    y.fill(0.0);
    matmul_nt(s.batch, s.din, s.dout, x, w, y);
    for yrow in y.chunks_exact_mut(s.dout) {
        for (v, bv) in yrow.iter_mut().zip(b) {
            *v += bv;
        }
    }
}

/// Tile-parallel [`fc_forward`] (bitwise equal: the packed GEMM's tile
/// grid is lane-count-invariant, and serial == parallel by the gemm
/// module's contract).  `ws` holds the shared packed panels.
pub fn fc_forward_pool(
    pool: &ComputePool,
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    ws: &mut PackBuf,
    s: &FcShape,
) {
    debug_assert_eq!(x.len(), s.batch * s.din);
    debug_assert_eq!(y.len(), s.batch * s.dout);
    y.fill(0.0);
    par_matmul_nt(pool, s.batch, s.din, s.dout, x, w, y, ws);
    for yrow in y.chunks_exact_mut(s.dout) {
        for (v, bv) in yrow.iter_mut().zip(b) {
            *v += bv;
        }
    }
}

/// Fully-connected backward.  `dw`/`db` accumulate, `dx` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn fc_backward(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    s: &FcShape,
) {
    // dW += dYᵀ · X
    matmul_tn(s.dout, s.batch, s.din, dy, x, dw);
    for dyrow in dy.chunks_exact(s.dout) {
        for (g, &v) in db.iter_mut().zip(dyrow) {
            *g += v;
        }
    }
    // dX = dY · W
    dx.fill(0.0);
    matmul_nn(s.batch, s.dout, s.din, dy, w, dx);
}

/// Tile-parallel [`fc_backward`] (bitwise equal to the serial form:
/// both GEMMs run the identical packed tile loops; `db` stays serial —
/// it is `dout` elements).  `ws` holds the shared packed panels.
#[allow(clippy::too_many_arguments)]
pub fn fc_backward_pool(
    pool: &ComputePool,
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
    ws: &mut PackBuf,
    s: &FcShape,
) {
    // dW += dYᵀ · X
    par_matmul_tn(pool, s.dout, s.batch, s.din, dy, x, dw, ws);
    for dyrow in dy.chunks_exact(s.dout) {
        for (g, &v) in db.iter_mut().zip(dyrow) {
            *g += v;
        }
    }
    // dX = dY · W
    dx.fill(0.0);
    par_matmul_nn(pool, s.batch, s.dout, s.din, dy, w, dx, ws);
}

/// Counter-style dropout RNG: one independent PCG stream per
/// (layer salt, chunk), so an element's draw depends only on its
/// position — never on how many lanes swept the array.
fn dropout_chunk_rng(seed: u64, salt: u64, chunk: usize) -> Pcg32 {
    Pcg32::new(seed ^ (salt + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15), chunk as u64)
}

/// Inverted dropout: zero with probability `p`, scale survivors by
/// `1/(1-p)` so eval needs no correction.  The per-element scale is
/// recorded in `mask` for the backward pass.  Randomness is drawn from
/// a per-chunk stream keyed by `(seed, salt, chunk)` — chunk
/// boundaries are fixed ([`ELEMWISE_CHUNK`]), making the mask
/// deterministic for any lane count.
pub fn dropout_forward(
    pool: &ComputePool,
    a: &mut [f32],
    mask: &mut [f32],
    p: f32,
    seed: u64,
    salt: u64,
) {
    debug_assert!((0.0..1.0).contains(&p));
    debug_assert_eq!(a.len(), mask.len());
    if p <= 0.0 {
        mask.fill(1.0);
        return;
    }
    let keep_scale = 1.0 / (1.0 - p);
    let mask_ptr = SendPtr::new(mask.as_mut_ptr());
    par_chunks_mut(pool, a, ELEMWISE_CHUNK, |ci, achunk| {
        let lo = ci * ELEMWISE_CHUNK;
        // SAFETY: the mask chunk mirrors the disjoint activation chunk.
        let mchunk =
            unsafe { std::slice::from_raw_parts_mut(mask_ptr.get().add(lo), achunk.len()) };
        let mut rng = dropout_chunk_rng(seed, salt, ci);
        for (v, m) in achunk.iter_mut().zip(mchunk) {
            if rng.next_f32() < p {
                *v = 0.0;
                *m = 0.0;
            } else {
                *v *= keep_scale;
                *m = keep_scale;
            }
        }
    });
}

/// Dropout backward: replay the recorded scales.
pub fn dropout_backward(pool: &ComputePool, da: &mut [f32], mask: &[f32]) {
    debug_assert_eq!(da.len(), mask.len());
    par_chunks_mut(pool, da, ELEMWISE_CHUNK, |ci, chunk| {
        let lo = ci * ELEMWISE_CHUNK;
        let len = chunk.len();
        for (g, &m) in chunk.iter_mut().zip(&mask[lo..lo + len]) {
            *g *= m;
        }
    });
}

/// Softmax + mean cross-entropy over a batch of logits.
///
/// Writes the per-row softmax into `probs` and the loss gradient
/// `(softmax - onehot)/batch` into `dlogits`; returns the mean loss and
/// the top-1 correct count.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    probs: &mut [f32],
    dlogits: &mut [f32],
    s: &FcShape,
) -> (f32, i32) {
    let classes = s.dout;
    debug_assert_eq!(logits.len(), s.batch * classes);
    debug_assert_eq!(labels.len(), s.batch);
    let inv_batch = 1.0 / s.batch as f32;
    let mut loss = 0.0f64;
    let mut correct1 = 0i32;
    for (bi, &label) in labels.iter().enumerate() {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let prow = &mut probs[bi * classes..(bi + 1) * classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for (p, &v) in prow.iter_mut().zip(row) {
            *p = (v - max).exp();
            sum += *p;
        }
        let inv_sum = 1.0 / sum;
        for p in prow.iter_mut() {
            *p *= inv_sum;
        }
        let li = label as usize;
        loss -= (prow[li].max(1e-12) as f64).ln();
        if crate::util::math::argmax(row) == li {
            correct1 += 1;
        }
        let drow = &mut dlogits[bi * classes..(bi + 1) * classes];
        for (d, &p) in drow.iter_mut().zip(prow.iter()) {
            *d = p * inv_batch;
        }
        drow[li] -= inv_batch;
    }
    ((loss as f32) * inv_batch, correct1)
}

/// Is `label` within the top-`k` entries of `row` (ties resolved
/// generously, matching the usual top-k error convention)?
pub fn topk_correct(row: &[f32], label: usize, k: usize) -> bool {
    let v = row[label];
    row.iter().filter(|&&x| x > v).count() < k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: columns are the input itself.
        let s = Conv2dShape {
            batch: 1,
            cin: 2,
            cout: 1,
            k: 1,
            stride: 1,
            pad: 0,
            in_hw: 3,
            out_hw: 3,
            groups: 1,
        };
        let x: Vec<f32> = (0..18).map(|v| v as f32).collect();
        let mut col = vec![0.0; s.col_elems()];
        im2col(&x, &s, &mut col);
        assert_eq!(col, x);
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> for random x, c — the
        // defining property of an adjoint pair.
        let s = Conv2dShape {
            batch: 1,
            cin: 2,
            cout: 1,
            k: 3,
            stride: 2,
            pad: 1,
            in_hw: 5,
            out_hw: 3,
            groups: 1,
        };
        let mut rng = crate::util::Pcg32::seeded(4);
        let mut x = vec![0.0; s.in_elems()];
        let mut c = vec![0.0; s.col_elems()];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut c, 1.0);
        let mut col = vec![0.0; s.col_elems()];
        im2col(&x, &s, &mut col);
        let lhs: f64 = col.iter().zip(&c).map(|(a, b)| (a * b) as f64).sum();
        let mut folded = vec![0.0; s.in_elems()];
        col2im(&c, &s, &mut folded);
        let rhs: f64 = x.iter().zip(&folded).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn grouped_conv_is_two_stacked_half_convs() {
        // A groups=2 conv must equal two independent ungrouped convs
        // over the channel halves, bit for bit (slice-wise dispatch).
        let s = Conv2dShape {
            batch: 2,
            cin: 4,
            cout: 6,
            k: 3,
            stride: 1,
            pad: 1,
            in_hw: 5,
            out_hw: 5,
            groups: 2,
        };
        let gs = s.group_shape();
        let mut rng = crate::util::Pcg32::seeded(11);
        let mut x = vec![0.0; s.batch * s.in_elems()];
        let mut w = vec![0.0; s.w_elems()];
        let mut b = vec![0.0; s.cout];
        rng.fill_normal(&mut x, 1.0);
        rng.fill_normal(&mut w, 0.5);
        rng.fill_normal(&mut b, 0.1);
        let mut y = vec![0.0; s.batch * s.out_elems()];
        let mut col = vec![0.0; s.col_elems()];
        conv2d_forward(&x, &w, &b, &mut y, &mut col, &s);
        // Reference: run each group as its own ungrouped batched conv.
        let (g_in, g_out, g_w) = (gs.in_elems(), gs.out_elems(), gs.w_elems());
        let mut gcol = vec![0.0; gs.col_elems()];
        for g in 0..s.groups {
            let mut xg = vec![0.0; s.batch * g_in];
            for bi in 0..s.batch {
                xg[bi * g_in..(bi + 1) * g_in].copy_from_slice(
                    &x[bi * s.in_elems() + g * g_in..bi * s.in_elems() + (g + 1) * g_in],
                );
            }
            let wg = &w[g * g_w..(g + 1) * g_w];
            let bg = &b[g * gs.cout..(g + 1) * gs.cout];
            let mut yg = vec![0.0; s.batch * g_out];
            conv2d_forward(&xg, wg, bg, &mut yg, &mut gcol, &gs);
            for bi in 0..s.batch {
                assert_eq!(
                    &yg[bi * g_out..(bi + 1) * g_out],
                    &y[bi * s.out_elems() + g * g_out..bi * s.out_elems() + (g + 1) * g_out],
                    "group {g} example {bi}"
                );
            }
        }
    }

    #[test]
    fn lrn_forward_matches_hand_formula() {
        // 3 channels, radius 1: check one element against the formula.
        let s = LrnShape {
            batch: 1,
            channels: 3,
            hw: 1,
            radius: 1,
            bias: 2.0,
            alpha: 0.3,
            beta: 0.75,
        };
        let x = vec![1.0f32, -2.0, 3.0];
        let mut y = vec![0.0f32; 3];
        lrn_forward(&x, &mut y, &s);
        let a = 0.3f32 / 3.0;
        // Channel 1 sees the full window {1, -2, 3}.
        let want = -2.0 / (2.0 + a * (1.0 + 4.0 + 9.0)).powf(0.75);
        assert!((y[1] - want).abs() < 1e-6, "{} vs {want}", y[1]);
        // Channel 0's window clips to {1, -2}.
        let want0 = 1.0 / (2.0 + a * (1.0 + 4.0)).powf(0.75);
        assert!((y[0] - want0).abs() < 1e-6, "{} vs {want0}", y[0]);
    }

    #[test]
    fn lrn_zero_alpha_is_a_pure_scale() {
        // alpha = 0 collapses LRN to y = x / bias^beta.
        let s = LrnShape {
            batch: 2,
            channels: 4,
            hw: 3,
            radius: 2,
            bias: 4.0,
            alpha: 0.0,
            beta: 0.5,
        };
        let mut rng = crate::util::Pcg32::seeded(5);
        let mut x = vec![0.0; s.batch * s.elems()];
        rng.fill_normal(&mut x, 1.0);
        let mut y = vec![0.0; x.len()];
        lrn_forward(&x, &mut y, &s);
        for (v, o) in x.iter().zip(&y) {
            assert!((o - v / 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn relu_and_mask() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu_forward(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
        let mut da = vec![5.0, 5.0, 5.0];
        relu_backward(&x, &mut da);
        assert_eq!(da, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn maxpool_tracks_argmax() {
        let s = PoolShape { batch: 1, channels: 1, in_hw: 4, window: 2, stride: 2, out_hw: 2 };
        #[rustfmt::skip]
        let x = vec![
            1.0, 2.0, 0.0, 0.0,
            3.0, 4.0, 0.0, 9.0,
            0.0, 0.0, 1.0, 1.0,
            7.0, 0.0, 1.0, 1.0,
        ];
        let mut y = vec![0.0; 4];
        let mut am = vec![0u32; 4];
        maxpool_forward(&x, &mut y, &mut am, &s);
        assert_eq!(y, vec![4.0, 9.0, 7.0, 1.0]);
        let mut dx = vec![0.0; 16];
        maxpool_backward(&[1.0, 1.0, 1.0, 1.0], &am, &mut dx, &s);
        assert_eq!(dx[5], 1.0); // the 4.0
        assert_eq!(dx[7], 1.0); // the 9.0
        assert_eq!(dx[12], 1.0); // the 7.0
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn fc_forward_small() {
        let s = FcShape { batch: 2, din: 3, dout: 2 };
        let x = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3]
        let b = vec![0.5, -0.5];
        let mut y = vec![0.0; 4];
        fc_forward(&x, &w, &b, &mut y, &s);
        assert_eq!(y, vec![1.5, 3.5, 2.5, 4.5]);
    }

    #[test]
    fn dropout_expectation_and_mask_replay() {
        let pool = ComputePool::serial();
        let n = 20_000;
        let mut a = vec![1.0f32; n];
        let mut mask = vec![0.0f32; n];
        dropout_forward(&pool, &mut a, &mut mask, 0.5, 8, 0);
        let mean = a.iter().sum::<f32>() / n as f32;
        // Inverted dropout preserves the expectation.
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
        let mut da = vec![1.0f32; n];
        dropout_backward(&pool, &mut da, &mask);
        assert_eq!(da, a);
        // p = 0 is the identity and an all-ones mask.
        let mut b = vec![2.0f32; 4];
        let mut m2 = vec![0.0f32; 4];
        dropout_forward(&pool, &mut b, &mut m2, 0.0, 8, 0);
        assert_eq!(b, vec![2.0; 4]);
        assert_eq!(m2, vec![1.0; 4]);
    }

    #[test]
    fn dropout_mask_is_lane_count_invariant() {
        // Spans multiple ELEMWISE_CHUNK boundaries so several chunk
        // streams are in play; layers (salts) must differ.
        let n = 2 * ELEMWISE_CHUNK + 137;
        let run = |threads: usize, salt: u64| {
            let pool = ComputePool::new(threads);
            let mut a = vec![1.0f32; n];
            let mut mask = vec![0.0f32; n];
            dropout_forward(&pool, &mut a, &mut mask, 0.5, 42, salt);
            (a, mask)
        };
        let (a1, m1) = run(1, 0);
        for threads in [2, 4] {
            let (at, mt) = run(threads, 0);
            assert_eq!(a1, at, "{threads} lanes changed activations");
            assert_eq!(m1, mt, "{threads} lanes changed the mask");
        }
        let (_, other_layer) = run(1, 1);
        assert_ne!(m1, other_layer, "layer salt must decorrelate masks");
    }

    #[test]
    fn softmax_uniform_logits() {
        let s = FcShape { batch: 2, din: 0, dout: 4 };
        let logits = vec![0.0; 8];
        let labels = vec![1, 3];
        let mut probs = vec![0.0; 8];
        let mut dl = vec![0.0; 8];
        let (loss, c1) = softmax_xent(&logits, &labels, &mut probs, &mut dl, &s);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        assert!(probs.iter().all(|&p| (p - 0.25).abs() < 1e-6));
        // argmax of a uniform row is index 0 => only a label-0 row counts.
        assert_eq!(c1, 0);
        // Gradient rows sum to zero.
        assert!(dl[..4].iter().sum::<f32>().abs() < 1e-6);
    }

    #[test]
    fn topk_membership() {
        let row = vec![0.1, 0.9, 0.5, 0.3];
        assert!(topk_correct(&row, 1, 1));
        assert!(!topk_correct(&row, 3, 1));
        assert!(topk_correct(&row, 3, 3));
        assert!(topk_correct(&row, 0, 4));
    }
}
