//! Runtime-dispatched SIMD microkernels for the packed GEMM.
//!
//! PR 5's 4×8 register-blocked microkernel was written to be
//! auto-vectorizer friendly, but nothing *pinned* that: a compiler mood
//! swing could silently drop the hot loop to scalar throughput.  This
//! module makes the vector code explicit:
//!
//! - [`kernel_avx2`] — x86_64 AVX2+FMA: each of the `MR = 4` rows keeps
//!   one `f32x8` accumulator (`NR = 8` columns), fed by broadcast-A ×
//!   aligned-load-B `_mm256_fmadd_ps` down the packed panel depth.
//! - [`kernel_neon`] — aarch64 NEON: two `f32x4` accumulators per row,
//!   `vdupq`-broadcast A × `vfmaq_f32`.  NEON is baseline on aarch64,
//!   so a `cfg` gate (no runtime probe) suffices.
//! - [`kernel_portable`] — the original safe-Rust loop, retained as the
//!   fallback for every other target and as the cross-check reference.
//!
//! ## Dispatch
//!
//! The ISA is resolved **once per process** ([`active_isa`], an
//! `OnceLock`): `TMG_GEMM_ISA=avx2|neon|scalar` overrides detection
//! (unknown or unavailable values warn and fall back to scalar — never
//! a crash), otherwise `is_x86_feature_detected!` / `cfg(target_arch)`
//! pick the best available kernel.  The result is logged at first use,
//! stored in every [`ComputePool`](crate::backend::native::pool::ComputePool)
//! at construction, and threaded into `TrainSummary` and the bench
//! JSON, so every run records what it actually executed.
//!
//! ## Determinism
//!
//! For a **fixed ISA**, every output element is produced by a fixed
//! instruction sequence, so the serial==parallel bitwise contract of
//! [`gemm`](crate::backend::native::gemm) holds per-ISA (the kernel
//! choice is uniform across lanes for a run).  *Across* ISAs results
//! legitimately differ in the last bits: FMA fuses each multiply-add
//! into a single rounding step, where the portable kernel rounds the
//! product and the sum separately.  Cross-ISA comparisons are therefore
//! rounding-tolerant (`rel_err`), never bitwise.

use std::sync::OnceLock;

use crate::backend::native::gemm::{MR, NR};

/// Signature shared by every microkernel: accumulate the full `MR×NR`
/// register tile over a `kc`-deep packed micro-panel pair.
///
/// The pointer is `unsafe fn` because the SIMD variants require their
/// CPU features to be present and (for AVX2) `bp` to be 32-byte
/// aligned; [`MicroKernel::run`] is the checked wrapper.
pub type KernelFn = unsafe fn(usize, &[f32], &[f32]) -> [[f32; NR]; MR];

/// The instruction sets a microkernel can be compiled for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// x86_64 AVX2 + FMA (f32x8).
    Avx2,
    /// aarch64 NEON (f32x4), baseline on that architecture.
    Neon,
    /// The portable safe-Rust kernel; always available.
    Scalar,
}

impl Isa {
    /// Best ISA the host supports, probed at runtime.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return Isa::Avx2;
            }
            Isa::Scalar
        }
        #[cfg(target_arch = "aarch64")]
        {
            Isa::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            Isa::Scalar
        }
    }

    /// Whether this ISA can actually run on the current host.
    pub fn available(self) -> bool {
        match self {
            Isa::Avx2 => Isa::detect() == Isa::Avx2,
            Isa::Neon => cfg!(target_arch = "aarch64"),
            Isa::Scalar => true,
        }
    }

    /// Parse a `TMG_GEMM_ISA` value; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            "scalar" => Some(Isa::Scalar),
            _ => None,
        }
    }

    /// Canonical lowercase name (the `TMG_GEMM_ISA` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Resolve an override request against what the host supports.
///
/// `None`, `""`, and `"auto"` mean "use [`Isa::detect`]".  Unknown
/// names and ISAs the host cannot run warn and fall back to
/// [`Isa::Scalar`] — an override must never turn into a crash (CI
/// forces `scalar` on hosts whose real ISA varies).
pub fn resolve_isa(requested: Option<&str>) -> Isa {
    let req = match requested {
        None => return Isa::detect(),
        Some(r) if r.is_empty() || r.eq_ignore_ascii_case("auto") => return Isa::detect(),
        Some(r) => r,
    };
    match Isa::parse(req) {
        Some(isa) if isa.available() => isa,
        Some(isa) => {
            log::warn!("TMG_GEMM_ISA={req}: {isa} is not available on this host; using scalar");
            Isa::Scalar
        }
        None => {
            log::warn!("TMG_GEMM_ISA={req}: unknown (expected avx2|neon|scalar); using scalar");
            Isa::Scalar
        }
    }
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

/// The process-wide dispatched ISA: `TMG_GEMM_ISA` resolved through
/// [`resolve_isa`] exactly once (first pool construction, typically)
/// and logged, so the choice is stable and recorded for the whole run.
pub fn active_isa() -> Isa {
    *ACTIVE.get_or_init(|| {
        let requested = std::env::var("TMG_GEMM_ISA").ok();
        let isa = resolve_isa(requested.as_deref());
        match requested {
            Some(r) => log::info!(
                "gemm microkernel: {isa} (TMG_GEMM_ISA={r}, detected {})",
                Isa::detect()
            ),
            None => log::info!("gemm microkernel: {isa} (auto-detected)"),
        }
        isa
    })
}

/// A resolved microkernel: the dispatch-table entry the packed GEMM
/// driver calls.  `Copy` — pools and callers hold it by value, so the
/// kernel choice can never change mid-run.
#[derive(Clone, Copy, Debug)]
pub struct MicroKernel {
    isa: Isa,
    func: KernelFn,
}

impl MicroKernel {
    /// Kernel for `isa`, downgrading anything the host cannot run to
    /// the portable kernel (callers that care route through
    /// [`resolve_isa`] first, which warns on the downgrade).
    pub fn for_isa(isa: Isa) -> MicroKernel {
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 if isa.available() => MicroKernel { isa, func: kernel_avx2 },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => MicroKernel { isa, func: kernel_neon },
            _ => MicroKernel { isa: Isa::Scalar, func: kernel_portable },
        }
    }

    /// The process-wide kernel ([`active_isa`] resolution).
    pub fn active() -> MicroKernel {
        MicroKernel::for_isa(active_isa())
    }

    /// Which ISA this kernel actually executes.
    pub fn isa(self) -> Isa {
        self.isa
    }

    /// Run the microkernel over one packed micro-panel pair.
    #[inline(always)]
    pub fn run(self, kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
        debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
        // SAFETY: `for_isa` only hands out kernels whose CPU features
        // were verified present, panels are packed to full MR/NR width,
        // and `PackBuf`'s 64-byte allocation keeps every `bp` panel row
        // 32-byte aligned for the AVX2 aligned loads.
        unsafe { (self.func)(kc, ap, bp) }
    }
}

/// The portable safe-Rust microkernel — PR 5's auto-vectorizer-friendly
/// loop, kept verbatim as the [`Isa::Scalar`] dispatch target and the
/// reference the SIMD kernels are cross-checked against.  `MR×NR`
/// independent accumulators, constant inner bounds, no branches.
#[inline(always)]
fn kernel_portable(kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let av = &ap[p * MR..p * MR + MR];
        let bv = &bp[p * NR..p * NR + NR];
        for r in 0..MR {
            let a = av[r];
            for j in 0..NR {
                acc[r][j] += a * bv[j];
            }
        }
    }
    acc
}

/// AVX2+FMA microkernel: four `_mm256` row accumulators fed by
/// broadcast-A × aligned-load-B fused multiply-adds.
///
/// # Safety
///
/// AVX2 and FMA must be available (guaranteed by
/// [`MicroKernel::for_isa`]); `ap.len() >= kc*MR`, `bp.len() >= kc*NR`;
/// `bp` must be 32-byte aligned — guaranteed by the 64-byte-aligned
/// `PackBuf` arena, since every `NR`-strip offset is a multiple of
/// 32 floats and every panel row advances by `NR = 8` floats (32 B).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kernel_avx2(kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    debug_assert_eq!(bp.as_ptr() as usize % 32, 0, "bp panel must be 32-byte aligned");
    let mut c0 = _mm256_setzero_ps();
    let mut c1 = _mm256_setzero_ps();
    let mut c2 = _mm256_setzero_ps();
    let mut c3 = _mm256_setzero_ps();
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let bv = _mm256_load_ps(b);
        c0 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a), bv, c0);
        c1 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(1)), bv, c1);
        c2 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(2)), bv, c2);
        c3 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(3)), bv, c3);
        a = a.add(MR);
        b = b.add(NR);
    }
    let mut acc = [[0.0f32; NR]; MR];
    _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
    _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
    _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
    _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    acc
}

/// NEON microkernel: two `f32x4` accumulators per row (covering
/// `NR = 8` columns), `vdupq`-broadcast A × `vfmaq_f32`.
///
/// # Safety
///
/// aarch64 with NEON (baseline — the `cfg` gate is the guarantee);
/// `ap.len() >= kc*MR`, `bp.len() >= kc*NR`.  `vld1q_f32` needs only
/// element alignment, which slices of `f32` always have.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn kernel_neon(kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    use std::arch::aarch64::*;
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut c00 = vdupq_n_f32(0.0);
    let mut c01 = vdupq_n_f32(0.0);
    let mut c10 = vdupq_n_f32(0.0);
    let mut c11 = vdupq_n_f32(0.0);
    let mut c20 = vdupq_n_f32(0.0);
    let mut c21 = vdupq_n_f32(0.0);
    let mut c30 = vdupq_n_f32(0.0);
    let mut c31 = vdupq_n_f32(0.0);
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = vld1q_f32(b);
        let b1 = vld1q_f32(b.add(4));
        let a0 = vdupq_n_f32(*a);
        let a1 = vdupq_n_f32(*a.add(1));
        let a2 = vdupq_n_f32(*a.add(2));
        let a3 = vdupq_n_f32(*a.add(3));
        c00 = vfmaq_f32(c00, a0, b0);
        c01 = vfmaq_f32(c01, a0, b1);
        c10 = vfmaq_f32(c10, a1, b0);
        c11 = vfmaq_f32(c11, a1, b1);
        c20 = vfmaq_f32(c20, a2, b0);
        c21 = vfmaq_f32(c21, a2, b1);
        c30 = vfmaq_f32(c30, a3, b0);
        c31 = vfmaq_f32(c31, a3, b1);
        a = a.add(MR);
        b = b.add(NR);
    }
    let mut acc = [[0.0f32; NR]; MR];
    vst1q_f32(acc[0].as_mut_ptr(), c00);
    vst1q_f32(acc[0].as_mut_ptr().add(4), c01);
    vst1q_f32(acc[1].as_mut_ptr(), c10);
    vst1q_f32(acc[1].as_mut_ptr().add(4), c11);
    vst1q_f32(acc[2].as_mut_ptr(), c20);
    vst1q_f32(acc[2].as_mut_ptr().add(4), c21);
    vst1q_f32(acc[3].as_mut_ptr(), c30);
    vst1q_f32(acc[3].as_mut_ptr().add(4), c31);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::gemm::KC;
    use crate::util::math::rel_err;
    use crate::util::Pcg32;

    #[test]
    fn parse_round_trips_canonical_names() {
        for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("sse9"), None);
    }

    #[test]
    fn unknown_or_unavailable_override_falls_back_to_scalar() {
        // The satellite contract: a bad override warns and degrades, it
        // never panics and never picks an ISA the host can't run.
        assert_eq!(resolve_isa(Some("avx512")), Isa::Scalar);
        assert_eq!(resolve_isa(Some("fastest-please")), Isa::Scalar);
        assert_eq!(resolve_isa(Some("scalar")), Isa::Scalar);
        assert_eq!(resolve_isa(None), Isa::detect());
        assert_eq!(resolve_isa(Some("")), Isa::detect());
        assert_eq!(resolve_isa(Some("auto")), Isa::detect());
        // An ISA that parses but belongs to the other architecture.
        let foreign = if cfg!(target_arch = "aarch64") { "avx2" } else { "neon" };
        assert_eq!(resolve_isa(Some(foreign)), Isa::Scalar);
    }

    #[test]
    fn for_isa_downgrades_unavailable_to_scalar() {
        for isa in [Isa::Avx2, Isa::Neon, Isa::Scalar] {
            let kern = MicroKernel::for_isa(isa);
            if isa.available() {
                assert_eq!(kern.isa(), isa, "available ISA must dispatch itself");
            } else {
                assert_eq!(kern.isa(), Isa::Scalar, "unavailable ISA must degrade");
            }
        }
        assert!(Isa::detect().available());
    }

    /// A random f32 block whose returned range starts 32-byte aligned,
    /// mimicking the `PackBuf` guarantee the AVX2 kernel relies on.
    fn aligned_panel(rng: &mut Pcg32, len: usize) -> (Vec<f32>, usize) {
        let mut v = vec![0.0f32; len + 8];
        rng.fill_normal(&mut v, 1.0);
        let off = v.as_ptr().align_offset(32);
        assert!(off + len <= v.len());
        (v, off)
    }

    #[test]
    fn every_available_kernel_matches_portable_to_rounding() {
        // FMA fuses each multiply-add into one rounding step, so SIMD
        // accumulators drift from the portable kernel by a few ULPs per
        // element.  `rel_err` (denominator floored at 1) stays below
        // 1e-5 for kc ≤ KC panels of unit-normal data — orders of
        // magnitude above the fused-vs-unfused gap, far below any real
        // indexing defect (which shows up as O(1) error).
        let mut rng = Pcg32::seeded(42);
        for kc in [1, 3, KC] {
            let (ap, aoff) = aligned_panel(&mut rng, kc * MR);
            let (bp, boff) = aligned_panel(&mut rng, kc * NR);
            let a = &ap[aoff..aoff + kc * MR];
            let b = &bp[boff..boff + kc * NR];
            let want = MicroKernel::for_isa(Isa::Scalar).run(kc, a, b);
            for isa in [Isa::Avx2, Isa::Neon] {
                if !isa.available() {
                    continue;
                }
                let got = MicroKernel::for_isa(isa).run(kc, a, b);
                for r in 0..MR {
                    for j in 0..NR {
                        let e = rel_err(got[r][j], want[r][j]);
                        assert!(
                            e < 1e-5,
                            "{isa} kc={kc} [{r}][{j}]: {} vs {} (rel err {e})",
                            got[r][j],
                            want[r][j]
                        );
                    }
                }
            }
        }
    }
}
