//! From [`ArchDesc`] to an executable layer plan + parameter manifest.
//!
//! [`NetPlan::from_arch`] walks the architecture description with
//! *exactly* the arithmetic of `ArchDesc::{forward_macs,
//! param_elements}` (sim/flops.rs), so the analytic model, the native
//! compute path and the [`ModelSpec`] parameter manifest can never
//! drift apart — a cross-check test pins all three together for every
//! arch in the family.
//!
//! Parameters are emitted in network order (`conv1.w, conv1.b, …,
//! fc1.w, …, out.w, out.b`) with He-normal init recipes
//! (`std = sqrt(2/fan_in)`), expressed through the same
//! [`ParamManifestSpec`] records the AOT manifest uses, so
//! [`ParamStore`](crate::params::ParamStore), checkpoints and the
//! collective exchange all operate on native parameters unchanged.

use crate::backend::native::gemm::PackBuf;
use crate::backend::native::layers::{Conv2dShape, ConvScratch, FcShape, LrnShape, PoolShape};
use crate::backend::native::pool::shape_chunks;
use crate::runtime::artifact::{ModelSpec, ParamManifestSpec};
use crate::sim::flops::ArchDesc;
use crate::tensor::Shape;

/// One node-to-node operation of the compiled plan.  ReLU (and, for
/// hidden FC layers, dropout) is fused into the producing op; `param`
/// is the index of the op's weight tensor in the store (bias follows).
#[derive(Clone, Copy, Debug)]
pub enum PlanOp {
    /// Convolution + ReLU; `cache` indexes the workspace buffer holding
    /// this layer's batch-wide im2col columns (written by the forward
    /// pass, reused by the backward pass).  The shape carries the
    /// layer's channel-group count (weights `cout × (cin/groups) × k²`).
    ConvRelu { shape: Conv2dShape, param: usize, cache: usize },
    /// Cross-channel local response normalization.  Parameter-free; the
    /// backward pass recomputes the scale denominators from the saved
    /// input node (both the input and output activations are workspace
    /// nodes, so no extra buffers are needed).
    Lrn { shape: LrnShape },
    /// Max-pool; `arg` indexes the workspace argmax buffer.
    Pool { shape: PoolShape, arg: usize },
    /// Hidden fully-connected + ReLU + dropout; `mask` indexes the
    /// workspace dropout-mask buffer.
    FcRelu { shape: FcShape, param: usize, mask: usize },
    /// Final fully-connected layer producing logits.
    FcOut { shape: FcShape, param: usize },
}

/// The executable form of an [`ArchDesc`].
#[derive(Clone, Debug)]
pub struct NetPlan {
    pub name: String,
    pub image_hw: usize,
    pub in_channels: usize,
    pub classes: usize,
    /// Ops in execution order; op `i` maps activation node `i` to `i+1`.
    pub ops: Vec<PlanOp>,
    /// Per-example element count of each activation node (`ops.len()+1`
    /// entries; node 0 is the input image).
    pub node_elems: Vec<usize>,
    pub n_pools: usize,
    pub n_masks: usize,
    /// Largest per-example im2col buffer any conv layer needs.
    pub col_elems: usize,
    pub params: Vec<ParamManifestSpec>,
}

fn weight(name: String, dims: &[usize], fan_in: usize) -> ParamManifestSpec {
    ParamManifestSpec {
        name,
        shape: Shape::of(dims),
        init: "normal".into(),
        std: (2.0 / fan_in as f32).sqrt(),
        bias_value: 0.0,
    }
}

fn bias(name: String, dim: usize) -> ParamManifestSpec {
    ParamManifestSpec {
        name,
        shape: Shape::of(&[dim]),
        init: "zeros".into(),
        std: 0.0,
        bias_value: 0.0,
    }
}

impl NetPlan {
    /// Compile an architecture description into a layer plan.  Shapes
    /// carry `batch: 1`; the workspace scales them at run time.
    pub fn from_arch(arch: &ArchDesc) -> NetPlan {
        let mut ops = Vec::new();
        let mut params = Vec::new();
        let mut node_elems = vec![arch.in_channels * arch.image_hw * arch.image_hw];
        let mut cin = arch.in_channels;
        let mut hw = arch.image_hw;
        let mut n_pools = 0;
        let mut col_elems = 0;
        for (l, c) in arch.convs.iter().enumerate() {
            assert!(c.groups >= 1, "conv{}: groups must be >= 1", l + 1);
            assert_eq!(cin % c.groups, 0, "conv{}: groups must divide cin {cin}", l + 1);
            assert_eq!(c.cout % c.groups, 0, "conv{}: groups must divide cout {}", l + 1, c.cout);
            let conv_hw = (hw + 2 * c.pad - c.kernel) / c.stride + 1;
            let param = params.len();
            params.push(weight(
                format!("conv{}.w", l + 1),
                &[c.cout, cin / c.groups, c.kernel, c.kernel],
                (cin / c.groups) * c.kernel * c.kernel,
            ));
            params.push(bias(format!("conv{}.b", l + 1), c.cout));
            let shape = Conv2dShape {
                batch: 1,
                cin,
                cout: c.cout,
                k: c.kernel,
                stride: c.stride,
                pad: c.pad,
                in_hw: hw,
                out_hw: conv_hw,
                groups: c.groups,
            };
            col_elems = col_elems.max(shape.col_elems());
            ops.push(PlanOp::ConvRelu { shape, param, cache: l });
            node_elems.push(c.cout * conv_hw * conv_hw);
            hw = conv_hw;
            if let Some(lrn) = c.lrn {
                ops.push(PlanOp::Lrn {
                    shape: LrnShape {
                        batch: 1,
                        channels: c.cout,
                        hw,
                        radius: lrn.radius,
                        bias: lrn.bias,
                        alpha: lrn.alpha,
                        beta: lrn.beta,
                    },
                });
                node_elems.push(c.cout * hw * hw);
            }
            if c.pool {
                let pooled = (hw - arch.pool_window) / arch.pool_stride + 1;
                ops.push(PlanOp::Pool {
                    shape: PoolShape {
                        batch: 1,
                        channels: c.cout,
                        in_hw: hw,
                        window: arch.pool_window,
                        stride: arch.pool_stride,
                        out_hw: pooled,
                    },
                    arg: n_pools,
                });
                node_elems.push(c.cout * pooled * pooled);
                n_pools += 1;
                hw = pooled;
            }
            cin = c.cout;
        }
        let mut feat = cin * hw * hw;
        let mut n_masks = 0;
        for (j, &d) in arch.fc_dims.iter().enumerate() {
            let param = params.len();
            params.push(weight(format!("fc{}.w", j + 1), &[d, feat], feat));
            params.push(bias(format!("fc{}.b", j + 1), d));
            ops.push(PlanOp::FcRelu {
                shape: FcShape { batch: 1, din: feat, dout: d },
                param,
                mask: n_masks,
            });
            node_elems.push(d);
            n_masks += 1;
            feat = d;
        }
        let param = params.len();
        params.push(weight("out.w".into(), &[arch.num_classes, feat], feat));
        params.push(bias("out.b".into(), arch.num_classes));
        ops.push(PlanOp::FcOut {
            shape: FcShape { batch: 1, din: feat, dout: arch.num_classes },
            param,
        });
        node_elems.push(arch.num_classes);

        NetPlan {
            name: arch.name.to_string(),
            image_hw: arch.image_hw,
            in_channels: arch.in_channels,
            classes: arch.num_classes,
            ops,
            node_elems,
            n_pools,
            n_masks,
            col_elems,
            params,
        }
    }

    /// Prefix offsets of each parameter tensor in the flat gradient /
    /// parameter layout: `params.len() + 1` entries, entry `i` is
    /// where tensor `i` starts, the last entry is the total element
    /// count.  The bucketed exchange and the staged update both
    /// address the flat buffer through this table, so bucket
    /// boundaries derive only from the layout.
    pub fn param_offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.params.len() + 1);
        out.push(0);
        let mut off = 0;
        for p in &self.params {
            off += p.shape.numel();
            out.push(off);
        }
        out
    }

    /// The manifest-compatible model description of this plan.
    pub fn model_spec(&self) -> ModelSpec {
        ModelSpec {
            name: self.name.clone(),
            image_hw: self.image_hw,
            in_channels: self.in_channels,
            num_classes: self.classes,
            params: self.params.clone(),
        }
    }
}

/// Derive the manifest-compatible [`ModelSpec`] of an architecture —
/// what the XLA path reads from `manifest.json`, computed instead.
pub fn model_spec_of(arch: &ArchDesc) -> ModelSpec {
    NetPlan::from_arch(arch).model_spec()
}

/// Reusable per-step buffers: activations + gradients per node, pool
/// argmaxes, dropout masks, per-conv-layer batch-wide im2col caches
/// (written forward, reused backward), the conv pool-path scratch
/// (per-lane pack/column staging + per-chunk gradient accumulators),
/// the shared FC packed-GEMM workspace and parameter gradients.  Sized
/// once per (batch, lanes); zero allocations afterwards (the pack
/// buffers grow to their fixed panel sizes on first use).
#[derive(Debug, Default)]
pub struct Workspace {
    pub batch: usize,
    pub lanes: usize,
    pub acts: Vec<Vec<f32>>,
    pub dacts: Vec<Vec<f32>>,
    pub pool_arg: Vec<Vec<u32>>,
    pub masks: Vec<Vec<f32>>,
    pub probs: Vec<f32>,
    /// Batch-wide im2col columns, one buffer per conv layer
    /// (`batch × col_elems(layer)`), indexed by `PlanOp::ConvRelu::cache`.
    pub col_cache: Vec<Vec<f32>>,
    pub conv: ConvScratch,
    /// Shared packed panels for the tile-parallel FC GEMMs.
    pub gemm: PackBuf,
    pub grads: Vec<Vec<f32>>,
}

impl Workspace {
    /// (Re)allocate for `batch` examples of `plan` computed over
    /// `lanes` pool lanes; no-op when already sized.
    ///
    /// Buffers are sized to the *exact* batch (every kernel takes whole
    /// buffers whose length encodes the batch), so a batch change —
    /// e.g. a ragged final eval batch between training steps —
    /// reallocates the workspace, including the conv column caches.
    /// That is a deliberate simplicity trade; interleaving eval batches
    /// of a different size with training pays an allocation round-trip
    /// per switch.
    ///
    /// `train` controls the batch-wide conv column caches: only a
    /// training step's backward pass reuses them, so eval-only sizing
    /// skips the `batch × Σ col_elems` allocation entirely (eval
    /// forwards stage columns in the per-lane scratch instead).  A
    /// matching-size call never downgrades: once the caches exist for
    /// this batch, an eval-mode call leaves them in place.
    pub fn ensure(&mut self, plan: &NetPlan, batch: usize, lanes: usize, train: bool) {
        let n_convs =
            plan.ops.iter().filter(|op| matches!(op, PlanOp::ConvRelu { .. })).count();
        let cache_ok = !train || self.col_cache.len() == n_convs;
        if self.batch == batch
            && self.lanes == lanes
            && self.acts.len() == plan.node_elems.len()
            && cache_ok
        {
            return;
        }
        self.batch = batch;
        self.lanes = lanes;
        self.acts = plan.node_elems.iter().map(|&n| vec![0.0; batch * n]).collect();
        self.dacts = plan.node_elems.iter().map(|&n| vec![0.0; batch * n]).collect();
        self.pool_arg = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::Pool { shape, .. } => {
                    Some(vec![0u32; batch * shape.channels * shape.out_hw * shape.out_hw])
                }
                _ => None,
            })
            .collect();
        self.masks = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::FcRelu { shape, .. } => Some(vec![0.0f32; batch * shape.dout]),
                _ => None,
            })
            .collect();
        self.probs = vec![0.0; batch * plan.classes];
        // Per-conv-layer batch-wide im2col caches, in cache-index order
        // (from_arch assigns `cache` in op order).  Train-only: an
        // eval-sized workspace never pays for them.
        self.col_cache = if train {
            plan.ops
                .iter()
                .filter_map(|op| match op {
                    PlanOp::ConvRelu { shape, .. } => {
                        Some(vec![0.0f32; batch * shape.col_elems()])
                    }
                    _ => None,
                })
                .collect()
        } else {
            Vec::new()
        };
        // Conv scratch: a column-gradient buffer and pack workspace per
        // lane, one gradient accumulator per batch chunk, all at the
        // largest conv layer.
        let (n_chunks, _) = shape_chunks(batch);
        let max_w = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::ConvRelu { shape, .. } => Some(shape.w_elems()),
                _ => None,
            })
            .max()
            .unwrap_or(0);
        let max_b = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::ConvRelu { shape, .. } => Some(shape.cout),
                _ => None,
            })
            .max()
            .unwrap_or(0);
        self.conv.ensure(lanes.max(1), n_chunks, plan.col_elems, max_w, max_b);
        self.grads = plan.params.iter().map(|p| vec![0.0; p.shape.numel()]).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::flops::{alexnet, alexnet_micro, alexnet_tiny, alexnet_tiny_faithful};

    #[test]
    fn plan_mirrors_flops_param_count() {
        for arch in [alexnet_micro(), alexnet_tiny(), alexnet_tiny_faithful(), alexnet()] {
            let plan = NetPlan::from_arch(&arch);
            let total: usize = plan.params.iter().map(|p| p.shape.numel()).sum();
            assert_eq!(total as u64, arch.param_elements(), "{}", arch.name);
            assert_eq!(plan.model_spec().total_param_elements(), total);
        }
    }

    #[test]
    fn micro_plan_geometry() {
        let plan = NetPlan::from_arch(&alexnet_micro());
        // conv1 -> pool -> conv2 -> fc1 -> out
        assert_eq!(plan.ops.len(), 5);
        assert_eq!(plan.node_elems[0], 3 * 32 * 32);
        assert_eq!(plan.node_elems[1], 8 * 16 * 16); // conv1: (32+4-5)/2+1
        assert_eq!(plan.node_elems[2], 8 * 7 * 7); // pool: (16-3)/2+1
        assert_eq!(plan.node_elems[3], 16 * 7 * 7); // conv2, pad 1
        assert_eq!(plan.node_elems[4], 64);
        assert_eq!(plan.node_elems[5], 10);
        assert_eq!(plan.n_pools, 1);
        assert_eq!(plan.n_masks, 1);
        assert_eq!(plan.params.len(), 8);
        assert_eq!(plan.params[0].name, "conv1.w");
        assert_eq!(plan.params[7].name, "out.b");
    }

    #[test]
    fn faithful_plan_carries_groups_and_lrn() {
        let plan = NetPlan::from_arch(&alexnet_tiny_faithful());
        // conv1 relu lrn pool | conv2 relu lrn pool | conv3 | conv4 |
        // conv5 pool | fc1 | fc2 | out
        let lrns: Vec<&LrnShape> = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::Lrn { shape } => Some(shape),
                _ => None,
            })
            .collect();
        assert_eq!(lrns.len(), 2);
        assert_eq!((lrns[0].channels, lrns[0].hw), (32, 32)); // after conv1
        assert_eq!((lrns[1].channels, lrns[1].hw), (64, 15)); // after conv2
        assert_eq!(lrns[0].radius, 2);
        assert_eq!((lrns[0].bias, lrns[0].alpha, lrns[0].beta), (2.0, 1e-4, 0.75));
        // LRN nodes preserve the producing conv's activation size.
        assert_eq!(plan.node_elems[1], 32 * 32 * 32);
        assert_eq!(plan.node_elems[2], 32 * 32 * 32);
        // Grouped conv weights are [cout, cin/groups, k, k] with the
        // matching He fan-in.
        let conv2 = &plan.params[2];
        assert_eq!(conv2.name, "conv2.w");
        assert_eq!(conv2.shape.dims(), &[64, 16, 3, 3]);
        let fan_in = 16 * 3 * 3;
        assert!((conv2.std - (2.0f32 / fan_in as f32).sqrt()).abs() < 1e-7);
        let shapes: Vec<usize> = plan
            .ops
            .iter()
            .filter_map(|op| match op {
                PlanOp::ConvRelu { shape, .. } => Some(shape.groups),
                _ => None,
            })
            .collect();
        assert_eq!(shapes, vec![1, 2, 1, 2, 2]);
    }

    #[test]
    #[should_panic(expected = "groups must divide cin")]
    fn from_arch_rejects_indivisible_groups() {
        let mut arch = alexnet_micro();
        arch.convs[0].groups = 2; // cin = 3 is not divisible
        let _ = NetPlan::from_arch(&arch);
    }

    #[test]
    fn param_offsets_are_prefix_sums() {
        let plan = NetPlan::from_arch(&alexnet_micro());
        let offs = plan.param_offsets();
        assert_eq!(offs.len(), plan.params.len() + 1);
        assert_eq!(offs[0], 0);
        let total: usize = plan.params.iter().map(|p| p.shape.numel()).sum();
        assert_eq!(*offs.last().unwrap(), total);
        for (i, p) in plan.params.iter().enumerate() {
            assert_eq!(offs[i + 1] - offs[i], p.shape.numel(), "{}", p.name);
        }
    }

    #[test]
    fn workspace_sizes_follow_plan() {
        let plan = NetPlan::from_arch(&alexnet_micro());
        let mut ws = Workspace::default();
        ws.ensure(&plan, 4, 2, true);
        assert_eq!(ws.acts.len(), plan.node_elems.len());
        assert_eq!(ws.acts[0].len(), 4 * 3 * 32 * 32);
        assert_eq!(ws.pool_arg.len(), 1);
        assert_eq!(ws.masks.len(), 1);
        assert_eq!(ws.grads.len(), 8);
        // Per-conv-layer im2col caches: batch × that layer's columns.
        assert_eq!(ws.col_cache.len(), 2);
        assert_eq!(ws.col_cache[0].len(), 4 * 3 * 5 * 5 * 16 * 16); // conv1
        assert_eq!(ws.col_cache[1].len(), 4 * 8 * 3 * 3 * 7 * 7); // conv2
        // Conv scratch: a dcol buffer + pack workspace per lane, one
        // grad accumulator per batch chunk (batch 4 -> 4 chunks), at
        // conv-max sizes.
        assert_eq!(ws.conv.dcols.len(), 2);
        assert_eq!(ws.conv.packs.len(), 2);
        assert_eq!(ws.conv.dcols[0].len(), plan.col_elems);
        assert_eq!(ws.conv.gw.len(), 4);
        assert_eq!(ws.conv.gw[0].len(), 16 * 8 * 3 * 3); // conv2 weights
        assert_eq!(ws.conv.gb[0].len(), 16);
        let before = ws.acts[0].as_ptr();
        ws.ensure(&plan, 4, 2, true); // no-op: buffers are stable
        assert_eq!(before, ws.acts[0].as_ptr());
        // An eval-mode call at the same size never downgrades: the
        // caches stay in place for the next training step.
        ws.ensure(&plan, 4, 2, false);
        assert_eq!(before, ws.acts[0].as_ptr());
        assert_eq!(ws.col_cache.len(), 2);
        ws.ensure(&plan, 2, 2, true);
        assert_eq!(ws.acts[0].len(), 2 * 3 * 32 * 32);
    }

    #[test]
    fn eval_only_workspace_skips_the_column_caches() {
        let plan = NetPlan::from_arch(&alexnet_micro());
        let mut ws = Workspace::default();
        ws.ensure(&plan, 4, 2, false);
        assert!(ws.col_cache.is_empty(), "eval sizing must not pay for caches");
        // Per-lane staging for eval forwards is still there.
        assert_eq!(ws.conv.dcols.len(), 2);
        assert_eq!(ws.conv.dcols[0].len(), plan.col_elems);
        // First training step at the same batch upgrades in place.
        ws.ensure(&plan, 4, 2, true);
        assert_eq!(ws.col_cache.len(), 2);
        assert_eq!(ws.col_cache[0].len(), 4 * 3 * 5 * 5 * 16 * 16);
    }
}
