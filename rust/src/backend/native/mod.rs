//! The native CPU step backend: real AlexNet forward/backward in pure
//! Rust, no artifacts, no PJRT.
//!
//! This is the reproduction's Caffe-style reference path (Jia et al.,
//! 2014): im2col + packed register-blocked SGEMM convolutions (grouped
//! or plain, the columns staged once per step and reused by the
//! backward pass), ReLU, cross-channel local response normalization,
//! max-pool, fully-connected layers with inverted dropout, softmax
//! cross-entropy and the SGD-momentum update — the same math the
//! paper's Theano functions computed per GPU, driven by the same
//! [`ArchDesc`](crate::sim::flops::ArchDesc) the analytic FLOP model
//! uses.  Because parameters live in the ordinary
//! [`ParamStore`](crate::params::ParamStore), the collective exchange,
//! checkpointing and divergence invariants all operate on *real*
//! gradients with this backend.
//!
//! Every kernel of the step runs over the backend's intra-op
//! [`ComputePool`] (packed-GEMM tiles, conv batch chunks, pooling
//! planes, elementwise sweeps, the SGD update).  The pool's
//! determinism contract ([`pool`]) keeps the math bit-identical for
//! any `--threads` value, so intra-op parallelism composes with the
//! inter-replica divergence invariants unchanged.

pub mod gemm;
pub mod layers;
pub mod model;
pub mod pool;
pub mod simd;

use crate::backend::{EvalBatchOut, GradSink, StepBackend, TopK, TrainStepOut};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::runtime::ModelSpec;
use crate::sim::flops::{arch_by_name, known_arch_names, ArchDesc};
use crate::tensor::HostTensor;

use self::layers::{
    conv2d_backward_pool, conv2d_forward_pool, dropout_backward, dropout_forward, fc_backward_pool,
    fc_forward_pool, lrn_backward_pool, lrn_forward_pool, maxpool_backward_pool,
    maxpool_forward_pool, relu_backward_pool, relu_forward_pool, softmax_xent, topk_correct,
    Conv2dShape, FcShape, LrnShape, PoolShape,
};
use self::model::{NetPlan, PlanOp, Workspace};
use self::pool::{par_ranges, ComputePool, ELEMWISE_CHUNK, SendPtr};

/// AlexNet's momentum coefficient (paper §2, Krizhevsky et al. 2012).
pub const MOMENTUM: f32 = 0.9;

/// Pure-Rust CPU implementation of [`StepBackend`].
pub struct NativeBackend {
    plan: NetPlan,
    model: ModelSpec,
    ws: Workspace,
    /// Intra-op worker pool shared by every kernel of this backend's
    /// step (packed-GEMM tiles, conv batch chunks, elementwise sweeps,
    /// the SGD update).  Deterministic: results are bit-identical for
    /// any lane count (see [`pool`]).
    pool: ComputePool,
    /// Dropout probability on hidden FC layers (paper: 0.5; 0 disables,
    /// which the gradient-check tests rely on).
    pub dropout: f32,
    /// SGD momentum coefficient.
    pub momentum: f32,
}

impl NativeBackend {
    /// Single-threaded backend (an intra-op pool of one lane).
    pub fn new(arch: &ArchDesc, dropout: f32) -> NativeBackend {
        NativeBackend::with_threads(arch, dropout, 1)
    }

    /// Backend with an intra-op compute pool of `threads` lanes
    /// (clamped to ≥ 1).  The thread count changes wall-clock only,
    /// never the math.
    pub fn with_threads(arch: &ArchDesc, dropout: f32, threads: usize) -> NativeBackend {
        let plan = NetPlan::from_arch(arch);
        let model = plan.model_spec();
        NativeBackend {
            plan,
            model,
            ws: Workspace::default(),
            pool: ComputePool::new(threads),
            dropout,
            momentum: MOMENTUM,
        }
    }

    /// Lanes of the intra-op pool (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.lanes()
    }

    /// Resolve the model named by the config to an architecture, with
    /// the config's per-worker intra-op thread budget.
    pub fn from_config(cfg: &crate::config::TrainConfig) -> Result<NativeBackend> {
        let arch = arch_by_name(&cfg.model).ok_or_else(|| {
            Error::msg(format!(
                "model {:?} is not a known architecture for the native backend \
                 (known models: {})",
                cfg.model,
                known_arch_names().join(", ")
            ))
        })?;
        Ok(NativeBackend::with_threads(&arch, cfg.dropout, cfg.threads_per_worker()))
    }

    /// Validate a batch against the plan and size the workspace
    /// (`train` additionally sizes the batch-wide conv column caches
    /// the backward pass reuses; eval skips them).
    fn admit_batch(&mut self, images: &HostTensor, labels: &[i32], train: bool) -> Result<usize> {
        let dims = images.shape().dims();
        let want = [self.plan.in_channels, self.plan.image_hw, self.plan.image_hw];
        if dims.len() != 4 || dims[1..] != want {
            return Err(Error::Shape(format!(
                "native backend expects images [B, {}, {}, {}], got {}",
                want[0],
                want[1],
                want[2],
                images.shape()
            )));
        }
        let batch = dims[0];
        if labels.len() != batch {
            return Err(Error::Shape(format!(
                "batch of {batch} images with {} labels",
                labels.len()
            )));
        }
        for &l in labels {
            if l < 0 || l as usize >= self.plan.classes {
                return Err(Error::msg(format!(
                    "label {l} out of range for {} classes",
                    self.plan.classes
                )));
            }
        }
        let lanes = self.pool.lanes();
        self.ws.ensure(&self.plan, batch, lanes, train);
        Ok(batch)
    }

    /// Forward pass over all nodes.  `drop_seed = None` skips dropout;
    /// `Some` keys the per-chunk dropout streams (see
    /// `layers::dropout_forward`).  `train` steers each conv layer's
    /// im2col columns into its batch-wide cache for the backward pass
    /// to reuse; eval-only forwards (`false`) stage them in per-lane
    /// scratch and never touch (or allocate) the caches.
    fn forward(
        &mut self,
        images: &HostTensor,
        store: &ParamStore,
        drop_seed: Option<u64>,
        train: bool,
    ) {
        let batch = self.ws.batch;
        let pool = &self.pool;
        let dropout = self.dropout;
        let ws = &mut self.ws;
        ws.acts[0].copy_from_slice(images.as_slice());
        for (i, op) in self.plan.ops.iter().enumerate() {
            let (lo, hi) = ws.acts.split_at_mut(i + 1);
            let x = lo[i].as_slice();
            let y = hi[0].as_mut_slice();
            match op {
                PlanOp::ConvRelu { shape, param, cache } => {
                    let s = Conv2dShape { batch, ..*shape };
                    // Training: the layer's im2col columns land in its
                    // batch-wide cache for the backward pass to reuse.
                    let cols = if train {
                        Some(ws.col_cache[*cache].as_mut_slice())
                    } else {
                        None
                    };
                    conv2d_forward_pool(
                        pool,
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        cols,
                        &mut ws.conv,
                        &s,
                    );
                    relu_forward_pool(pool, y);
                }
                PlanOp::Lrn { shape } => {
                    let s = LrnShape { batch, ..*shape };
                    lrn_forward_pool(pool, x, y, &s);
                }
                PlanOp::Pool { shape, arg } => {
                    let s = PoolShape { batch, ..*shape };
                    maxpool_forward_pool(pool, x, y, &mut ws.pool_arg[*arg], &s);
                }
                PlanOp::FcRelu { shape, param, mask } => {
                    let s = FcShape { batch, ..*shape };
                    fc_forward_pool(
                        pool,
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        &mut ws.gemm,
                        &s,
                    );
                    relu_forward_pool(pool, y);
                    if let Some(seed) = drop_seed {
                        dropout_forward(
                            pool,
                            y,
                            &mut ws.masks[*mask],
                            dropout,
                            seed,
                            *mask as u64,
                        );
                    }
                }
                PlanOp::FcOut { shape, param } => {
                    let s = FcShape { batch, ..*shape };
                    fc_forward_pool(
                        pool,
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        &mut ws.gemm,
                        &s,
                    );
                }
            }
        }
    }

    /// Backward pass; parameter gradients accumulate into `ws.grads`
    /// (zeroed here), starting from the loss gradient already staged in
    /// the last `dacts` node by `softmax_xent`.
    ///
    /// With a `sink`, each parameter gradient is announced the moment
    /// its op's backward call finishes — bias then weight, last layer
    /// first — which is exactly descending manifest order, the contract
    /// [`GradSink`] documents.
    fn backward(&mut self, store: &ParamStore, mut sink: Option<&mut dyn GradSink>) -> Result<()> {
        let batch = self.ws.batch;
        let pool = &self.pool;
        let dropout = self.dropout;
        let ws = &mut self.ws;
        for g in &mut ws.grads {
            g.fill(0.0);
        }
        for (i, op) in self.plan.ops.iter().enumerate().rev() {
            let (lo, hi) = ws.dacts.split_at_mut(i + 1);
            let dx = lo[i].as_mut_slice();
            let dy = hi[0].as_mut_slice();
            let x = ws.acts[i].as_slice();
            let a = ws.acts[i + 1].as_slice();
            let finished = match op {
                PlanOp::ConvRelu { shape, param, cache } => {
                    let s = Conv2dShape { batch, ..*shape };
                    relu_backward_pool(pool, a, dy);
                    let (gw, gb) = grads_pair(&mut ws.grads, *param);
                    // Reuses the forward pass's cached im2col columns —
                    // no second unfold of the batch.
                    conv2d_backward_pool(
                        pool,
                        store.params[*param].as_slice(),
                        dy,
                        gw,
                        gb,
                        dx,
                        &ws.col_cache[*cache],
                        &mut ws.conv,
                        &s,
                    );
                    Some(*param)
                }
                PlanOp::Lrn { shape } => {
                    // Parameter-free; the scale denominators are
                    // recomputed from the saved input node `x` (the
                    // saved output `a` feeds the cross-channel term).
                    let s = LrnShape { batch, ..*shape };
                    lrn_backward_pool(pool, x, a, dy, dx, &s);
                    None
                }
                PlanOp::Pool { shape, arg } => {
                    let s = PoolShape { batch, ..*shape };
                    maxpool_backward_pool(pool, dy, &ws.pool_arg[*arg], dx, &s);
                    None
                }
                PlanOp::FcRelu { shape, param, mask } => {
                    let s = FcShape { batch, ..*shape };
                    // Dropout only ran forward when active; a stale
                    // mask must not gate the gradient.
                    if dropout > 0.0 {
                        dropout_backward(pool, dy, &ws.masks[*mask]);
                    }
                    relu_backward_pool(pool, a, dy);
                    let (gw, gb) = grads_pair(&mut ws.grads, *param);
                    fc_backward_pool(
                        pool,
                        x,
                        store.params[*param].as_slice(),
                        dy,
                        gw,
                        gb,
                        dx,
                        &mut ws.gemm,
                        &s,
                    );
                    Some(*param)
                }
                PlanOp::FcOut { shape, param } => {
                    let s = FcShape { batch, ..*shape };
                    let (gw, gb) = grads_pair(&mut ws.grads, *param);
                    fc_backward_pool(
                        pool,
                        x,
                        store.params[*param].as_slice(),
                        dy,
                        gw,
                        gb,
                        dx,
                        &mut ws.gemm,
                        &s,
                    );
                    Some(*param)
                }
            };
            if let (Some(param), Some(s)) = (finished, sink.as_deref_mut()) {
                s.grad_ready(param + 1, &ws.grads[param + 1])?;
                s.grad_ready(param, &ws.grads[param])?;
            }
        }
        Ok(())
    }

    /// SGD with momentum from the workspace gradients (the fused
    /// `train_step` path).
    fn apply_ws_update(&self, store: &mut ParamStore, lr: f32) {
        for ((p, m), g) in
            store.params.iter_mut().zip(store.momenta.iter_mut()).zip(&self.ws.grads)
        {
            sgd_update_tensor(
                &self.pool,
                self.momentum,
                lr,
                p.as_mut_slice(),
                m.as_mut_slice(),
                g.as_slice(),
            );
        }
    }
}

/// One tensor's SGD-momentum update: `m ← μ·m − lr·g; p ← p + m`,
/// parallel over fixed element ranges (elementwise, so chunking cannot
/// change the result).  One function shared by the fused and staged
/// step paths, so their arithmetic is identical bit for bit.
fn sgd_update_tensor(
    pool: &ComputePool,
    momentum: f32,
    lr: f32,
    ps: &mut [f32],
    ms: &mut [f32],
    gs: &[f32],
) {
    debug_assert_eq!(ps.len(), gs.len());
    debug_assert_eq!(ms.len(), gs.len());
    let p_ptr = SendPtr::new(ps.as_mut_ptr());
    let m_ptr = SendPtr::new(ms.as_mut_ptr());
    par_ranges(pool, gs.len(), ELEMWISE_CHUNK, |_ci, r| {
        let (lo, len) = (r.start, r.len());
        // SAFETY: ranges are disjoint; each touches only its own
        // span of the param/momentum tensors.
        let pr = unsafe { std::slice::from_raw_parts_mut(p_ptr.get().add(lo), len) };
        let mr = unsafe { std::slice::from_raw_parts_mut(m_ptr.get().add(lo), len) };
        for ((pv, mv), gv) in pr.iter_mut().zip(mr).zip(&gs[lo..lo + len]) {
            *mv = momentum * *mv - lr * gv;
            *pv += *mv;
        }
    });
}

/// Split the gradient list into the (weight, bias) pair at `param`.
fn grads_pair(grads: &mut [Vec<f32>], param: usize) -> (&mut [f32], &mut [f32]) {
    let (lo, hi) = grads.split_at_mut(param + 1);
    (lo[param].as_mut_slice(), hi[0].as_mut_slice())
}

impl StepBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn model(&self) -> &ModelSpec {
        &self.model
    }

    fn train_step(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        lr: f32,
        step_seed: i32,
        store: &mut ParamStore,
    ) -> Result<TrainStepOut> {
        let batch = self.admit_batch(images, labels, true)?;
        let drop_seed = (self.dropout > 0.0).then_some(step_seed as u32 as u64);
        self.forward(images, store, drop_seed, true);
        let n = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        let (loss, correct1) = softmax_xent(
            self.ws.acts[n].as_slice(),
            labels,
            &mut self.ws.probs,
            self.ws.dacts[n].as_mut_slice(),
            &s,
        );
        self.backward(store, None)?;
        self.apply_ws_update(store, lr);
        Ok(TrainStepOut { loss, correct1 })
    }

    fn supports_staged_step(&self) -> bool {
        true
    }

    fn forward_backward(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        step_seed: i32,
        store: &ParamStore,
        sink: &mut dyn GradSink,
    ) -> Result<TrainStepOut> {
        let batch = self.admit_batch(images, labels, true)?;
        let drop_seed = (self.dropout > 0.0).then_some(step_seed as u32 as u64);
        self.forward(images, store, drop_seed, true);
        let n = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        let (loss, correct1) = softmax_xent(
            self.ws.acts[n].as_slice(),
            labels,
            &mut self.ws.probs,
            self.ws.dacts[n].as_mut_slice(),
            &s,
        );
        self.backward(store, Some(sink))?;
        Ok(TrainStepOut { loss, correct1 })
    }

    fn apply_update(&mut self, store: &mut ParamStore, lr: f32, flat_grads: &[f32]) -> Result<()> {
        let offsets = self.plan.param_offsets();
        let total = *offsets.last().unwrap();
        if flat_grads.len() != total || store.params.len() + 1 != offsets.len() {
            return Err(Error::Shape(format!(
                "apply_update: {} gradient values over {} tensors, plan wants {total} over {}",
                flat_grads.len(),
                store.params.len(),
                offsets.len() - 1
            )));
        }
        for (i, (p, m)) in store.params.iter_mut().zip(store.momenta.iter_mut()).enumerate() {
            sgd_update_tensor(
                &self.pool,
                self.momentum,
                lr,
                p.as_mut_slice(),
                m.as_mut_slice(),
                &flat_grads[offsets[i]..offsets[i + 1]],
            );
        }
        Ok(())
    }

    fn supports_eval(&self) -> bool {
        true
    }

    fn eval_batch(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        store: &ParamStore,
    ) -> Result<EvalBatchOut> {
        let batch = self.admit_batch(images, labels, false)?;
        self.forward(images, store, None, false);
        let n = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        // dlogits land in the (otherwise unused) last gradient node.
        let (loss, top1) = softmax_xent(
            self.ws.acts[n].as_slice(),
            labels,
            &mut self.ws.probs,
            self.ws.dacts[n].as_mut_slice(),
            &s,
        );
        let logits = self.ws.acts[n].as_slice();
        let classes = self.plan.classes;
        let mut top5 = 0i32;
        for (bi, &label) in labels.iter().enumerate() {
            let row = &logits[bi * classes..(bi + 1) * classes];
            if topk_correct(row, label as usize, 5) {
                top5 += 1;
            }
        }
        Ok(EvalBatchOut { loss, top1, top5 })
    }

    fn supports_predict(&self) -> bool {
        true
    }

    fn predict_batch(
        &mut self,
        images: &HostTensor,
        store: &ParamStore,
        k: usize,
    ) -> Result<Vec<TopK>> {
        let dims = images.shape().dims();
        let n = if dims.len() == 4 { dims[0] } else { 0 };
        // Prediction has no labels; zeros satisfy the admission check
        // and the (discarded) loss arithmetic.
        let labels = vec![0i32; n];
        let batch = self.admit_batch(images, &labels, false)?;
        self.forward(images, store, None, false);
        let last = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        softmax_xent(
            self.ws.acts[last].as_slice(),
            &labels,
            &mut self.ws.probs,
            self.ws.dacts[last].as_mut_slice(),
            &s,
        );
        let classes = self.plan.classes;
        let k = k.clamp(1, classes);
        let logits = self.ws.acts[last].as_slice();
        let mut out = Vec::with_capacity(batch);
        let mut order: Vec<usize> = Vec::with_capacity(classes);
        for bi in 0..batch {
            let row = &logits[bi * classes..(bi + 1) * classes];
            let prow = &self.ws.probs[bi * classes..(bi + 1) * classes];
            // Rank on the logits (ties toward the lower index) so the
            // head of the list is exactly eval_batch's argmax top-1;
            // report the softmax probabilities of the ranked classes.
            order.clear();
            order.extend(0..classes);
            order.sort_unstable_by(|&a, &b| {
                row[b]
                    .partial_cmp(&row[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            out.push(order[..k].iter().map(|&c| (c, prow[c])).collect());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::flops::alexnet_micro;
    use crate::tensor::Shape;
    use crate::util::Pcg32;

    fn random_batch(batch: usize, classes: usize, seed: u64) -> (HostTensor, Vec<i32>) {
        let mut rng = Pcg32::seeded(seed);
        let images = HostTensor::rand_normal(Shape::of(&[batch, 3, 32, 32]), &mut rng, 1.0);
        let labels = (0..batch).map(|_| rng.below(classes as u32) as i32).collect();
        (images, labels)
    }

    #[test]
    fn step_is_deterministic_and_updates_params() {
        let arch = alexnet_micro();
        let (images, labels) = random_batch(4, arch.num_classes, 3);
        let run = || {
            let mut b = NativeBackend::new(&arch, 0.5);
            let mut store = ParamStore::init(&b.model().params, 7);
            let o1 = b.train_step(&images, &labels, 0.01, 11, &mut store).unwrap();
            let o2 = b.train_step(&images, &labels, 0.01, 12, &mut store).unwrap();
            (o1.loss, o2.loss, store)
        };
        let (l1a, l2a, sa) = run();
        let (l1b, l2b, sb) = run();
        assert_eq!(l1a, l1b);
        assert_eq!(l2a, l2b);
        assert_eq!(sa.max_divergence(&sb), 0.0);
        // And the update moved the parameters.
        let fresh = ParamStore::init(&sa.specs, 7);
        assert!(sa.param_divergence(&fresh) > 0.0);
    }

    /// Test sink: scatters emitted gradients into a flat layout buffer
    /// and asserts the descending-contiguous emission contract.
    struct CollectSink {
        flat: Vec<f32>,
        offsets: Vec<usize>,
        next: usize,
    }

    impl GradSink for CollectSink {
        fn grad_ready(&mut self, param: usize, grad: &[f32]) -> Result<()> {
            let (lo, hi) = (self.offsets[param], self.offsets[param + 1]);
            assert_eq!(hi - lo, grad.len(), "param {param} length");
            assert_eq!(hi, self.next, "param {param} emitted out of order");
            self.flat[lo..hi].copy_from_slice(grad);
            self.next = lo;
            Ok(())
        }
    }

    #[test]
    fn staged_step_matches_fused_bit_for_bit() {
        // forward_backward + apply_update from the emitted gradients is
        // the N = 1 degenerate case of the overlapped exchange; it must
        // reproduce train_step exactly (params + momenta), dropout on.
        let arch = alexnet_micro();
        let (images, labels) = random_batch(4, arch.num_classes, 21);
        let mut fused = NativeBackend::new(&arch, 0.5);
        let mut store_f = ParamStore::init(&fused.model().params, 7);
        let mut staged = NativeBackend::new(&arch, 0.5);
        let mut store_s = ParamStore::init(&staged.model().params, 7);
        assert!(staged.supports_staged_step());
        for step in 0..3 {
            let of = fused.train_step(&images, &labels, 0.01, step, &mut store_f).unwrap();
            let offsets = staged.plan.param_offsets();
            let total = *offsets.last().unwrap();
            let mut sink = CollectSink { flat: vec![0.0; total], offsets, next: total };
            let os =
                staged.forward_backward(&images, &labels, step, &store_s, &mut sink).unwrap();
            assert_eq!(sink.next, 0, "every gradient must be emitted");
            staged.apply_update(&mut store_s, 0.01, &sink.flat).unwrap();
            assert_eq!(of.loss, os.loss, "step {step}");
            assert_eq!(of.correct1, os.correct1);
        }
        assert_eq!(store_f.max_divergence(&store_s), 0.0);
        // A wrong-length gradient buffer is rejected.
        assert!(staged.apply_update(&mut store_s, 0.01, &[0.0; 3]).is_err());
    }

    /// Micro geometry with the faithful model's structure: groups=2 on
    /// conv2 and LRN after conv1 — the cheapest full-step exercise of
    /// the grouped + LRN plan ops.
    fn micro_faithful() -> crate::sim::flops::ArchDesc {
        let mut arch = alexnet_micro();
        arch.convs[0].lrn = Some(crate::sim::flops::LrnSpec::krizhevsky());
        arch.convs[1].groups = 2;
        arch
    }

    #[test]
    fn grouped_lrn_step_is_deterministic_and_learns() {
        let arch = micro_faithful();
        let (images, labels) = random_batch(8, arch.num_classes, 17);
        let run = || {
            let mut b = NativeBackend::new(&arch, 0.0);
            let mut store = ParamStore::init(&b.model().params, 7);
            let first = b.train_step(&images, &labels, 0.02, 0, &mut store).unwrap().loss;
            let mut last = first;
            for step in 1..35 {
                last = b.train_step(&images, &labels, 0.02, step, &mut store).unwrap().loss;
                assert!(last.is_finite(), "loss diverged at step {step}");
            }
            (first, last, store)
        };
        let (first, last, sa) = run();
        assert!(last < 0.5 * first, "grouped+LRN overfit failed: {first} -> {last}");
        let (_, _, sb) = run();
        assert_eq!(sa.max_divergence(&sb), 0.0);
        // Every parameter (including the grouped conv's) moved.
        let fresh = ParamStore::init(&sa.specs, 7);
        for (i, (old, new)) in fresh.params.iter().zip(&sa.params).enumerate() {
            let moved = crate::util::math::max_abs_diff(old.as_slice(), new.as_slice());
            assert!(moved > 0.0, "param {} ({}) did not move", i, sa.specs[i].name);
        }
    }

    #[test]
    fn grouped_lrn_staged_matches_fused() {
        // The staged protocol must hold unchanged with parameter-free
        // LRN ops interleaved (they emit nothing; the sink still sees
        // descending-contiguous emission).
        let arch = micro_faithful();
        let (images, labels) = random_batch(4, arch.num_classes, 23);
        let mut fused = NativeBackend::new(&arch, 0.5);
        let mut store_f = ParamStore::init(&fused.model().params, 7);
        let mut staged = NativeBackend::new(&arch, 0.5);
        let mut store_s = ParamStore::init(&staged.model().params, 7);
        for step in 0..2 {
            let of = fused.train_step(&images, &labels, 0.01, step, &mut store_f).unwrap();
            let offsets = staged.plan.param_offsets();
            let total = *offsets.last().unwrap();
            let mut sink = CollectSink { flat: vec![0.0; total], offsets, next: total };
            let os =
                staged.forward_backward(&images, &labels, step, &store_s, &mut sink).unwrap();
            assert_eq!(sink.next, 0, "every gradient must be emitted");
            staged.apply_update(&mut store_s, 0.01, &sink.flat).unwrap();
            assert_eq!(of.loss, os.loss, "step {step}");
        }
        assert_eq!(store_f.max_divergence(&store_s), 0.0);
    }

    #[test]
    fn overfits_one_batch() {
        // The canonical sanity check: repeated steps on one minibatch
        // must drive the loss down hard (dropout off for determinism).
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 1);
        let (images, labels) = random_batch(8, arch.num_classes, 5);
        let first = b.train_step(&images, &labels, 0.02, 0, &mut store).unwrap().loss;
        let mut last = first;
        for step in 1..30 {
            last = b.train_step(&images, &labels, 0.02, step, &mut store).unwrap().loss;
            assert!(last.is_finite(), "loss diverged at step {step}");
        }
        assert!(
            last < 0.5 * first,
            "one-batch overfit failed: {first} -> {last}"
        );
    }

    #[test]
    fn gradients_reach_every_layer_with_dropout_off() {
        // Regression: a zeroed (never-written) dropout mask must not
        // gate the backward pass when dropout is disabled — conv1's
        // weights have to move, not just the output layer's.
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 4);
        let before = store.clone();
        let (images, labels) = random_batch(4, arch.num_classes, 6);
        b.train_step(&images, &labels, 0.05, 0, &mut store).unwrap();
        for (i, (old, new)) in before.params.iter().zip(&store.params).enumerate() {
            let moved = crate::util::math::max_abs_diff(old.as_slice(), new.as_slice());
            assert!(moved > 0.0, "param {} ({}) did not move", i, store.specs[i].name);
        }
    }

    #[test]
    fn eval_counts_are_consistent() {
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.5);
        let store = ParamStore::init(&b.model().params, 2);
        let (images, labels) = random_batch(8, arch.num_classes, 9);
        let e = b.eval_batch(&images, &labels, &store).unwrap();
        assert!(e.loss.is_finite());
        assert!(e.top1 >= 0 && e.top1 <= 8);
        assert!(e.top5 >= e.top1 && e.top5 <= 8);
        // Eval is dropout-free, hence repeatable bit-for-bit.
        let e2 = b.eval_batch(&images, &labels, &store).unwrap();
        assert_eq!(e.loss, e2.loss);
    }

    #[test]
    fn predict_matches_eval_counts() {
        // predict_batch ranks on the logits with eval_batch's tie-break
        // (first max wins), so row heads must reproduce the top-1 count
        // and label membership in the top-5 must reproduce top-5.
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.5);
        let store = ParamStore::init(&b.model().params, 2);
        let (images, labels) = random_batch(8, arch.num_classes, 9);
        let e = b.eval_batch(&images, &labels, &store).unwrap();
        let p = b.predict_batch(&images, &store, 5).unwrap();
        assert_eq!(p.len(), 8);
        let mut top1 = 0i32;
        let mut top5 = 0i32;
        for (row, &label) in p.iter().zip(&labels) {
            assert_eq!(row.len(), 5);
            // Descending scores, probabilities in (0, 1].
            for w in row.windows(2) {
                assert!(w[0].1 >= w[1].1, "scores not descending: {row:?}");
            }
            assert!(row.iter().all(|&(_, s)| s > 0.0 && s <= 1.0));
            if row[0].0 == label as usize {
                top1 += 1;
            }
            if row.iter().any(|&(c, _)| c == label as usize) {
                top5 += 1;
            }
        }
        assert_eq!(top1, e.top1);
        assert_eq!(top5, e.top5);
        // Eval-mode forward: repeatable bit-for-bit, k clamped to the
        // class count.
        let p2 = b.predict_batch(&images, &store, 5).unwrap();
        assert_eq!(p, p2);
        let pk = b.predict_batch(&images, &store, 10_000).unwrap();
        assert_eq!(pk[0].len(), arch.num_classes);
        let p1 = b.predict_batch(&images, &store, 0).unwrap();
        assert_eq!(p1[0].len(), 1);
        assert_eq!(p1[0][0].0, p[0][0].0);
    }

    #[test]
    fn rejects_bad_batches() {
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 1);
        let wrong = HostTensor::zeros(Shape::of(&[2, 3, 16, 16]));
        assert!(b.train_step(&wrong, &[0, 1], 0.01, 0, &mut store).is_err());
        let (images, _) = random_batch(2, arch.num_classes, 1);
        assert!(b.train_step(&images, &[0], 0.01, 0, &mut store).is_err());
        assert!(b.train_step(&images, &[0, 99], 0.01, 0, &mut store).is_err());
    }
}
