//! The native CPU step backend: real AlexNet forward/backward in pure
//! Rust, no artifacts, no PJRT.
//!
//! This is the reproduction's Caffe-style reference path (Jia et al.,
//! 2014): im2col + blocked-SGEMM convolutions, ReLU, max-pool,
//! fully-connected layers with inverted dropout, softmax cross-entropy
//! and the SGD-momentum update — the same math the paper's Theano
//! functions computed per GPU, driven by the same
//! [`ArchDesc`](crate::sim::flops::ArchDesc) the analytic FLOP model
//! uses.  Because parameters live in the ordinary
//! [`ParamStore`](crate::params::ParamStore), the collective exchange,
//! checkpointing and divergence invariants all operate on *real*
//! gradients with this backend.

pub mod gemm;
pub mod layers;
pub mod model;

use crate::backend::{EvalBatchOut, StepBackend, TrainStepOut};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::runtime::ModelSpec;
use crate::sim::flops::{arch_by_name, ArchDesc};
use crate::tensor::HostTensor;
use crate::util::Pcg32;

use self::layers::{
    conv2d_backward, conv2d_forward, dropout_backward, dropout_forward, fc_backward, fc_forward,
    maxpool_backward, maxpool_forward, relu_backward, relu_forward, softmax_xent, topk_correct,
    Conv2dShape, FcShape, PoolShape,
};
use self::model::{NetPlan, PlanOp, Workspace};

/// AlexNet's momentum coefficient (paper §2, Krizhevsky et al. 2012).
pub const MOMENTUM: f32 = 0.9;

/// Pure-Rust CPU implementation of [`StepBackend`].
pub struct NativeBackend {
    plan: NetPlan,
    model: ModelSpec,
    ws: Workspace,
    /// Dropout probability on hidden FC layers (paper: 0.5; 0 disables,
    /// which the gradient-check tests rely on).
    pub dropout: f32,
    /// SGD momentum coefficient.
    pub momentum: f32,
}

impl NativeBackend {
    pub fn new(arch: &ArchDesc, dropout: f32) -> NativeBackend {
        let plan = NetPlan::from_arch(arch);
        let model = plan.model_spec();
        NativeBackend { plan, model, ws: Workspace::default(), dropout, momentum: MOMENTUM }
    }

    /// Resolve the model named by the config to an architecture.
    pub fn from_config(cfg: &crate::config::TrainConfig) -> Result<NativeBackend> {
        let arch = arch_by_name(&cfg.model).ok_or_else(|| {
            Error::msg(format!(
                "model {:?} is not a known architecture for the native backend \
                 (want alexnet, alexnet-tiny or alexnet-micro)",
                cfg.model
            ))
        })?;
        Ok(NativeBackend::new(&arch, cfg.dropout))
    }

    /// Validate a batch against the plan and size the workspace.
    fn admit_batch(&mut self, images: &HostTensor, labels: &[i32]) -> Result<usize> {
        let dims = images.shape().dims();
        let want = [self.plan.in_channels, self.plan.image_hw, self.plan.image_hw];
        if dims.len() != 4 || dims[1..] != want {
            return Err(Error::Shape(format!(
                "native backend expects images [B, {}, {}, {}], got {}",
                want[0],
                want[1],
                want[2],
                images.shape()
            )));
        }
        let batch = dims[0];
        if labels.len() != batch {
            return Err(Error::Shape(format!(
                "batch of {batch} images with {} labels",
                labels.len()
            )));
        }
        for &l in labels {
            if l < 0 || l as usize >= self.plan.classes {
                return Err(Error::msg(format!(
                    "label {l} out of range for {} classes",
                    self.plan.classes
                )));
            }
        }
        self.ws.ensure(&self.plan, batch);
        Ok(batch)
    }

    /// Forward pass over all nodes.  `drop_rng = None` is eval mode
    /// (dropout skipped); `Some` is train mode.
    fn forward(&mut self, images: &HostTensor, store: &ParamStore, mut drop_rng: Option<Pcg32>) {
        let batch = self.ws.batch;
        self.ws.acts[0].copy_from_slice(images.as_slice());
        for (i, op) in self.plan.ops.iter().enumerate() {
            let (lo, hi) = self.ws.acts.split_at_mut(i + 1);
            let x = lo[i].as_slice();
            let y = hi[0].as_mut_slice();
            match op {
                PlanOp::ConvRelu { shape, param } => {
                    let s = Conv2dShape { batch, ..*shape };
                    // The staging buffer is shared across layers at the
                    // largest size; each layer uses its prefix.
                    let col = &mut self.ws.col[..s.col_elems()];
                    conv2d_forward(
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        col,
                        &s,
                    );
                    relu_forward(y);
                }
                PlanOp::Pool { shape, arg } => {
                    let s = PoolShape { batch, ..*shape };
                    maxpool_forward(x, y, &mut self.ws.pool_arg[*arg], &s);
                }
                PlanOp::FcRelu { shape, param, mask } => {
                    let s = FcShape { batch, ..*shape };
                    fc_forward(
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        &s,
                    );
                    relu_forward(y);
                    if let Some(rng) = drop_rng.as_mut() {
                        dropout_forward(y, &mut self.ws.masks[*mask], self.dropout, rng);
                    }
                }
                PlanOp::FcOut { shape, param } => {
                    let s = FcShape { batch, ..*shape };
                    fc_forward(
                        x,
                        store.params[*param].as_slice(),
                        store.params[*param + 1].as_slice(),
                        y,
                        &s,
                    );
                }
            }
        }
    }

    /// Backward pass; parameter gradients accumulate into `ws.grads`
    /// (zeroed here), starting from the loss gradient already staged in
    /// the last `dacts` node by `softmax_xent`.
    fn backward(&mut self, store: &ParamStore) {
        let batch = self.ws.batch;
        for g in &mut self.ws.grads {
            g.fill(0.0);
        }
        for (i, op) in self.plan.ops.iter().enumerate().rev() {
            let (lo, hi) = self.ws.dacts.split_at_mut(i + 1);
            let dx = lo[i].as_mut_slice();
            let dy = hi[0].as_mut_slice();
            let x = self.ws.acts[i].as_slice();
            let a = self.ws.acts[i + 1].as_slice();
            match op {
                PlanOp::ConvRelu { shape, param } => {
                    let s = Conv2dShape { batch, ..*shape };
                    relu_backward(a, dy);
                    let (gw, gb) = grads_pair(&mut self.ws.grads, *param);
                    let col = &mut self.ws.col[..s.col_elems()];
                    let dcol = &mut self.ws.dcol[..s.col_elems()];
                    conv2d_backward(
                        x,
                        store.params[*param].as_slice(),
                        dy,
                        gw,
                        gb,
                        dx,
                        col,
                        dcol,
                        &s,
                    );
                }
                PlanOp::Pool { shape, arg } => {
                    let s = PoolShape { batch, ..*shape };
                    maxpool_backward(dy, &self.ws.pool_arg[*arg], dx, &s);
                }
                PlanOp::FcRelu { shape, param, mask } => {
                    let s = FcShape { batch, ..*shape };
                    // Dropout only ran forward when active; a stale
                    // mask must not gate the gradient.
                    if self.dropout > 0.0 {
                        dropout_backward(dy, &self.ws.masks[*mask]);
                    }
                    relu_backward(a, dy);
                    let (gw, gb) = grads_pair(&mut self.ws.grads, *param);
                    fc_backward(x, store.params[*param].as_slice(), dy, gw, gb, dx, &s);
                }
                PlanOp::FcOut { shape, param } => {
                    let s = FcShape { batch, ..*shape };
                    let (gw, gb) = grads_pair(&mut self.ws.grads, *param);
                    fc_backward(x, store.params[*param].as_slice(), dy, gw, gb, dx, &s);
                }
            }
        }
    }

    /// SGD with momentum: `m ← μ·m − lr·g; p ← p + m`.
    fn apply_update(&self, store: &mut ParamStore, lr: f32) {
        for ((p, m), g) in
            store.params.iter_mut().zip(store.momenta.iter_mut()).zip(&self.ws.grads)
        {
            for ((pv, mv), gv) in p.as_mut_slice().iter_mut().zip(m.as_mut_slice()).zip(g) {
                *mv = self.momentum * *mv - lr * gv;
                *pv += *mv;
            }
        }
    }
}

/// Split the gradient list into the (weight, bias) pair at `param`.
fn grads_pair(grads: &mut [Vec<f32>], param: usize) -> (&mut [f32], &mut [f32]) {
    let (lo, hi) = grads.split_at_mut(param + 1);
    (lo[param].as_mut_slice(), hi[0].as_mut_slice())
}

impl StepBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn model(&self) -> &ModelSpec {
        &self.model
    }

    fn train_step(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        lr: f32,
        step_seed: i32,
        store: &mut ParamStore,
    ) -> Result<TrainStepOut> {
        let batch = self.admit_batch(images, labels)?;
        let drop_rng = (self.dropout > 0.0).then(|| Pcg32::new(step_seed as u32 as u64, 0xD0D0));
        self.forward(images, store, drop_rng);
        let n = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        let (loss, correct1) = softmax_xent(
            self.ws.acts[n].as_slice(),
            labels,
            &mut self.ws.probs,
            self.ws.dacts[n].as_mut_slice(),
            &s,
        );
        self.backward(store);
        self.apply_update(store, lr);
        Ok(TrainStepOut { loss, correct1 })
    }

    fn supports_eval(&self) -> bool {
        true
    }

    fn eval_batch(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        store: &ParamStore,
    ) -> Result<EvalBatchOut> {
        let batch = self.admit_batch(images, labels)?;
        self.forward(images, store, None);
        let n = self.plan.ops.len();
        let s = FcShape { batch, din: 0, dout: self.plan.classes };
        // dlogits land in the (otherwise unused) last gradient node.
        let (loss, top1) = softmax_xent(
            self.ws.acts[n].as_slice(),
            labels,
            &mut self.ws.probs,
            self.ws.dacts[n].as_mut_slice(),
            &s,
        );
        let logits = self.ws.acts[n].as_slice();
        let classes = self.plan.classes;
        let mut top5 = 0i32;
        for (bi, &label) in labels.iter().enumerate() {
            let row = &logits[bi * classes..(bi + 1) * classes];
            if topk_correct(row, label as usize, 5) {
                top5 += 1;
            }
        }
        Ok(EvalBatchOut { loss, top1, top5 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::flops::alexnet_micro;
    use crate::tensor::Shape;

    fn random_batch(batch: usize, classes: usize, seed: u64) -> (HostTensor, Vec<i32>) {
        let mut rng = Pcg32::seeded(seed);
        let images = HostTensor::rand_normal(Shape::of(&[batch, 3, 32, 32]), &mut rng, 1.0);
        let labels = (0..batch).map(|_| rng.below(classes as u32) as i32).collect();
        (images, labels)
    }

    #[test]
    fn step_is_deterministic_and_updates_params() {
        let arch = alexnet_micro();
        let (images, labels) = random_batch(4, arch.num_classes, 3);
        let run = || {
            let mut b = NativeBackend::new(&arch, 0.5);
            let mut store = ParamStore::init(&b.model().params, 7);
            let o1 = b.train_step(&images, &labels, 0.01, 11, &mut store).unwrap();
            let o2 = b.train_step(&images, &labels, 0.01, 12, &mut store).unwrap();
            (o1.loss, o2.loss, store)
        };
        let (l1a, l2a, sa) = run();
        let (l1b, l2b, sb) = run();
        assert_eq!(l1a, l1b);
        assert_eq!(l2a, l2b);
        assert_eq!(sa.max_divergence(&sb), 0.0);
        // And the update moved the parameters.
        let fresh = ParamStore::init(&sa.specs, 7);
        assert!(sa.param_divergence(&fresh) > 0.0);
    }

    #[test]
    fn overfits_one_batch() {
        // The canonical sanity check: repeated steps on one minibatch
        // must drive the loss down hard (dropout off for determinism).
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 1);
        let (images, labels) = random_batch(8, arch.num_classes, 5);
        let first = b.train_step(&images, &labels, 0.02, 0, &mut store).unwrap().loss;
        let mut last = first;
        for step in 1..30 {
            last = b.train_step(&images, &labels, 0.02, step, &mut store).unwrap().loss;
            assert!(last.is_finite(), "loss diverged at step {step}");
        }
        assert!(
            last < 0.5 * first,
            "one-batch overfit failed: {first} -> {last}"
        );
    }

    #[test]
    fn gradients_reach_every_layer_with_dropout_off() {
        // Regression: a zeroed (never-written) dropout mask must not
        // gate the backward pass when dropout is disabled — conv1's
        // weights have to move, not just the output layer's.
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 4);
        let before = store.clone();
        let (images, labels) = random_batch(4, arch.num_classes, 6);
        b.train_step(&images, &labels, 0.05, 0, &mut store).unwrap();
        for (i, (old, new)) in before.params.iter().zip(&store.params).enumerate() {
            let moved = crate::util::math::max_abs_diff(old.as_slice(), new.as_slice());
            assert!(moved > 0.0, "param {} ({}) did not move", i, store.specs[i].name);
        }
    }

    #[test]
    fn eval_counts_are_consistent() {
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.5);
        let store = ParamStore::init(&b.model().params, 2);
        let (images, labels) = random_batch(8, arch.num_classes, 9);
        let e = b.eval_batch(&images, &labels, &store).unwrap();
        assert!(e.loss.is_finite());
        assert!(e.top1 >= 0 && e.top1 <= 8);
        assert!(e.top5 >= e.top1 && e.top5 <= 8);
        // Eval is dropout-free, hence repeatable bit-for-bit.
        let e2 = b.eval_batch(&images, &labels, &store).unwrap();
        assert_eq!(e.loss, e2.loss);
    }

    #[test]
    fn rejects_bad_batches() {
        let arch = alexnet_micro();
        let mut b = NativeBackend::new(&arch, 0.0);
        let mut store = ParamStore::init(&b.model().params, 1);
        let wrong = HostTensor::zeros(Shape::of(&[2, 3, 16, 16]));
        assert!(b.train_step(&wrong, &[0, 1], 0.01, 0, &mut store).is_err());
        let (images, _) = random_batch(2, arch.num_classes, 1);
        assert!(b.train_step(&images, &[0], 0.01, 0, &mut store).is_err());
        assert!(b.train_step(&images, &[0, 99], 0.01, 0, &mut store).is_err());
    }
}
