//! The AOT-artifact step backend: compiled HLO driven through PJRT.
//!
//! This wraps the original training path — `make artifacts` lowers the
//! JAX model to HLO text once, and each worker compiles + executes it
//! via [`RuntimeClient`] — behind the [`StepBackend`] trait, so the
//! coordinator no longer knows which substrate computes a step.

use crate::backend::{EvalBatchOut, StepBackend, TrainStepOut};
use crate::config::TrainConfig;
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::runtime::literal_bridge::{
    f32_scalar, i32_scalar, i32_to_literal, literal_f32, literal_i32, literal_to_tensor,
    tensor_to_literal,
};
use crate::runtime::{Manifest, ModelSpec, RuntimeClient, StepExecutable};
use crate::tensor::HostTensor;

/// Compiled train and/or eval executables for one model.
pub struct XlaBackend {
    model: ModelSpec,
    /// Absent when loaded eval-only (see [`XlaBackend::load_eval`]).
    step: Option<StepExecutable>,
    eval: Option<StepExecutable>,
}

impl XlaBackend {
    /// Load + compile the manifest artifacts a training job needs
    /// (train required, eval optional).  `tag` is the artifact backend
    /// label (e.g. `refconv`, `cudnn_r2`).
    pub fn load(cfg: &TrainConfig, tag: &str) -> Result<XlaBackend> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let model = manifest.model(&cfg.model)?.clone();
        let name = format!("train_{}_{}_b{}", cfg.model, tag, cfg.batch_per_worker);
        let client = RuntimeClient::cpu()?;
        let step = Some(client.load_step(manifest.artifact(&name)?)?);
        let eval = match manifest.eval_artifact_for(&cfg.model) {
            Some(spec) => Some(client.load_step(spec)?),
            None => None,
        };
        Ok(XlaBackend { model, step, eval })
    }

    /// Load + compile only the eval artifact — checkpoint evaluation
    /// must not require (or pay for compiling) the train executable.
    pub fn load_eval(cfg: &TrainConfig) -> Result<XlaBackend> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let model = manifest.model(&cfg.model)?.clone();
        let spec = manifest.eval_artifact_for(&cfg.model).ok_or_else(|| {
            Error::msg(format!("no eval artifact for model {:?}", cfg.model))
        })?;
        let client = RuntimeClient::cpu()?;
        let eval = Some(client.load_step(spec)?);
        Ok(XlaBackend { model, step: None, eval })
    }
}

impl StepBackend for XlaBackend {
    fn name(&self) -> &str {
        "xla"
    }

    fn model(&self) -> &ModelSpec {
        &self.model
    }

    fn train_step(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        lr: f32,
        step_seed: i32,
        store: &mut ParamStore,
    ) -> Result<TrainStepOut> {
        let exe = self.step.as_ref().ok_or_else(|| {
            Error::msg(format!(
                "XLA backend for {:?} was loaded eval-only; no train executable",
                self.model.name
            ))
        })?;
        let n_params = store.n_tensors();
        // The ABI input list: images, labels, lr, seed, params, momenta.
        let mut inputs = Vec::with_capacity(4 + 2 * n_params);
        inputs.push(tensor_to_literal(images)?);
        inputs.push(i32_to_literal(labels)?);
        inputs.push(f32_scalar(lr));
        inputs.push(i32_scalar(step_seed));
        for p in &store.params {
            inputs.push(tensor_to_literal(p)?);
        }
        for m in &store.momenta {
            inputs.push(tensor_to_literal(m)?);
        }
        let outputs = exe.run(&inputs)?;
        let loss = literal_f32(&outputs[0])?;
        let correct1 = literal_i32(&outputs[1])?;
        let mut new_params = Vec::with_capacity(n_params);
        let mut new_momenta = Vec::with_capacity(n_params);
        for (i, lit) in outputs[2..2 + n_params].iter().enumerate() {
            new_params.push(literal_to_tensor(lit, store.specs[i].shape.clone())?);
        }
        for (i, lit) in outputs[2 + n_params..].iter().enumerate() {
            new_momenta.push(literal_to_tensor(lit, store.specs[i].shape.clone())?);
        }
        store.update_from(new_params, new_momenta)?;
        Ok(TrainStepOut { loss, correct1 })
    }

    fn supports_eval(&self) -> bool {
        self.eval.is_some()
    }

    fn eval_batch_size(&self) -> Option<usize> {
        self.eval.as_ref().map(|e| e.spec.batch_size)
    }

    fn eval_batch(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        store: &ParamStore,
    ) -> Result<EvalBatchOut> {
        let exe = self.eval.as_ref().ok_or_else(|| {
            Error::msg(format!("no eval artifact for model {:?}", self.model.name))
        })?;
        let mut inputs = Vec::with_capacity(2 + store.n_tensors());
        inputs.push(tensor_to_literal(images)?);
        inputs.push(i32_to_literal(labels)?);
        for p in &store.params {
            inputs.push(tensor_to_literal(p)?);
        }
        let outs = exe.run(&inputs)?;
        Ok(EvalBatchOut {
            loss: literal_f32(&outs[0])?,
            top1: literal_i32(&outs[1])?,
            top5: literal_i32(&outs[2])?,
        })
    }
}
