//! Step backends: *what* computes a train/eval step, behind one trait.
//!
//! The coordinator (worker threads, trainer, evaluator) is
//! backend-agnostic: it drives a [`StepBackend`] that maps
//! `(batch, lr, seed, params+momenta) → (loss, top-1, updated state)`.
//! Two implementations exist:
//!
//! - [`native::NativeBackend`] — real AlexNet forward/backward in pure
//!   Rust (im2col + blocked SGEMM, ReLU, max-pool, FC + dropout,
//!   softmax cross-entropy, SGD momentum).  Runs anywhere, no
//!   artifacts; the reproduction's reference path.
//! - [`xla::XlaBackend`] — the AOT-compiled HLO path through PJRT
//!   (`make artifacts`), the original device-speed substrate.
//!
//! [`build_backend`] resolves a config to a backend:
//! `backend = "native"` selects the CPU path directly; any other value
//! names an artifact backend tag (`refconv`, `cudnn_r2`, …) and loads
//! the XLA path, **falling back to native** with a warning when the
//! artifacts or PJRT bindings are unavailable — `tmg train` always
//! trains.

pub mod native;
pub mod xla;

pub use self::native::NativeBackend;
pub use self::xla::XlaBackend;

use crate::config::TrainConfig;
use crate::error::Result;
use crate::params::ParamStore;
use crate::runtime::ModelSpec;
use crate::sim::flops::arch_by_name;
use crate::tensor::HostTensor;

/// Scalar results of one training step (state updates go through the
/// `ParamStore` the step mutated).
#[derive(Clone, Copy, Debug)]
pub struct TrainStepOut {
    pub loss: f32,
    pub correct1: i32,
}

/// Scalar results of one evaluation forward pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalBatchOut {
    pub loss: f32,
    pub top1: i32,
    pub top5: i32,
}

/// One example's ranked predictions: `(class, softmax probability)`
/// pairs, descending by score.
pub type TopK = Vec<(usize, f32)>;

/// Observer of per-parameter gradient readiness during backward.
///
/// The staged step protocol calls [`GradSink::grad_ready`] once per
/// parameter tensor, as soon as that tensor's gradient is final —
/// in strictly *descending* manifest order (backward emits the last
/// layer first, and within a layer bias before weight), so the
/// finished region of the flat gradient layout grows contiguously from
/// the end.  That ordering is what lets the bucketed collective ship
/// fixed layout-derived buckets while backward is still running.
pub trait GradSink {
    /// `param` indexes the model's parameter manifest; `grad` is that
    /// tensor's finished gradient for this step.
    fn grad_ready(&mut self, param: usize, grad: &[f32]) -> Result<()>;
}

/// One replica's compute substrate.
///
/// Implementations own their scratch state (workspaces, compiled
/// executables) but **not** the parameters: those live in the caller's
/// [`ParamStore`] so the collective exchange, checkpointing and
/// divergence checks see every backend identically.
pub trait StepBackend: Send {
    /// Short backend label for logs.
    fn name(&self) -> &str;

    /// The model this backend computes (shapes, classes, param
    /// manifest — what `ParamStore::init` needs).
    fn model(&self) -> &ModelSpec;

    /// One SGD-momentum training step: forward, backward, update
    /// `store` in place.
    fn train_step(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        lr: f32,
        step_seed: i32,
        store: &mut ParamStore,
    ) -> Result<TrainStepOut>;

    /// Whether this backend implements the staged step protocol
    /// ([`StepBackend::forward_backward`] + [`StepBackend::apply_update`]).
    /// Backends that keep the monolithic [`StepBackend::train_step`]
    /// (the XLA path — its AOT executable fuses the whole step) answer
    /// `false` and the coordinator falls back to compute-then-exchange.
    fn supports_staged_step(&self) -> bool {
        false
    }

    /// Staged step, part 1: forward + backward only.  Emits every
    /// parameter gradient through `sink` the moment it is final
    /// (descending manifest order — see [`GradSink`]); does **not**
    /// touch params or momenta.  The default adapter refuses, keeping
    /// monolithic backends valid without changes.
    fn forward_backward(
        &mut self,
        _images: &HostTensor,
        _labels: &[i32],
        _step_seed: i32,
        _store: &ParamStore,
        _sink: &mut dyn GradSink,
    ) -> Result<TrainStepOut> {
        Err(crate::error::Error::msg(format!(
            "backend {:?} does not implement the staged step protocol",
            self.name()
        )))
    }

    /// Staged step, part 2: the SGD-momentum update from a flat buffer
    /// of (group-averaged) gradients in manifest layout order.  Must be
    /// arithmetically identical to the update inside
    /// [`StepBackend::train_step`], so the staged path at N = 1 is
    /// bit-equal to the fused one.
    fn apply_update(
        &mut self,
        _store: &mut ParamStore,
        _lr: f32,
        _flat_grads: &[f32],
    ) -> Result<()> {
        Err(crate::error::Error::msg(format!(
            "backend {:?} does not implement the staged step protocol",
            self.name()
        )))
    }

    /// Whether [`StepBackend::eval_batch`] is available (the XLA path
    /// needs a separate eval artifact).
    fn supports_eval(&self) -> bool;

    /// Fixed evaluation batch size, if this backend compiled one in.
    fn eval_batch_size(&self) -> Option<usize> {
        None
    }

    /// Evaluation forward pass: mean loss + top-1/top-5 correct counts.
    fn eval_batch(
        &mut self,
        images: &HostTensor,
        labels: &[i32],
        store: &ParamStore,
    ) -> Result<EvalBatchOut>;

    /// Whether [`StepBackend::predict_batch`] is available.  The AOT
    /// XLA eval artifact only returns aggregate counts, so the serving
    /// path needs a backend that can expose per-example scores.
    fn supports_predict(&self) -> bool {
        false
    }

    /// Eval-mode forward returning each example's top-`k` classes with
    /// softmax probabilities.  Ranking happens on the logits with ties
    /// broken toward the lower class index, so the first entry of every
    /// row is exactly the `argmax` that [`StepBackend::eval_batch`]
    /// counts as top-1 — the serve path and `tmg eval` agree bit for
    /// bit on the same parameters.  Default: unsupported.
    fn predict_batch(
        &mut self,
        _images: &HostTensor,
        _store: &ParamStore,
        _k: usize,
    ) -> Result<Vec<TopK>> {
        Err(crate::error::Error::msg(format!(
            "backend {:?} does not support per-example prediction",
            self.name()
        )))
    }
}

/// Which substrate a config's `backend` string selects.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// The pure-Rust CPU path.
    Native,
    /// The AOT-artifact path, with the artifact backend tag.
    Xla(String),
}

impl BackendChoice {
    pub fn parse(s: &str) -> BackendChoice {
        match s {
            "native" | "cpu" => BackendChoice::Native,
            // Bare "xla" means "whatever reference artifacts exist".
            "xla" => BackendChoice::Xla("refconv".into()),
            tag => BackendChoice::Xla(tag.to_string()),
        }
    }
}

/// Build the backend a config asks for (see module docs for the
/// native-fallback rule).
pub fn build_backend(cfg: &TrainConfig) -> Result<Box<dyn StepBackend>> {
    match BackendChoice::parse(&cfg.backend) {
        BackendChoice::Native => Ok(Box::new(NativeBackend::from_config(cfg)?)),
        BackendChoice::Xla(tag) => match XlaBackend::load(cfg, &tag) {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => match arch_by_name(&cfg.model) {
                Some(arch) => {
                    log::warn!(
                        "XLA backend {tag:?} unavailable ({e}); \
                         falling back to the native CPU backend"
                    );
                    Ok(Box::new(NativeBackend::with_threads(
                        &arch,
                        cfg.dropout,
                        cfg.threads_per_worker(),
                    )))
                }
                None => Err(e),
            },
        },
    }
}

/// Build a backend for checkpoint evaluation only: the XLA path loads
/// just the eval artifact (no train executable is required or
/// compiled), with the same native fallback rule as [`build_backend`].
pub fn build_eval_backend(cfg: &TrainConfig) -> Result<Box<dyn StepBackend>> {
    match BackendChoice::parse(&cfg.backend) {
        BackendChoice::Native => Ok(Box::new(NativeBackend::from_config(cfg)?)),
        BackendChoice::Xla(_) => match XlaBackend::load_eval(cfg) {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => match arch_by_name(&cfg.model) {
                Some(arch) => {
                    log::warn!(
                        "XLA eval unavailable ({e}); evaluating on the native CPU backend"
                    );
                    Ok(Box::new(NativeBackend::with_threads(
                        &arch,
                        cfg.dropout,
                        cfg.threads_per_worker(),
                    )))
                }
                None => Err(e),
            },
        },
    }
}

/// Resolve just the model description a config trains — without
/// building executables or workspaces.  Same fallback rule as
/// [`build_backend`]: *any* failure to resolve the model through the
/// manifest (missing file, model not listed) falls back to the
/// architecture table when it knows the name.
pub fn resolve_model(cfg: &TrainConfig) -> Result<ModelSpec> {
    match BackendChoice::parse(&cfg.backend) {
        BackendChoice::Native => native_model(cfg),
        BackendChoice::Xla(_) => {
            let from_manifest = crate::runtime::Manifest::load(&cfg.artifacts_dir)
                .ok()
                .and_then(|m| m.model(&cfg.model).ok().cloned());
            match from_manifest {
                Some(m) => Ok(m),
                None => native_model(cfg),
            }
        }
    }
}

fn native_model(cfg: &TrainConfig) -> Result<ModelSpec> {
    let arch = arch_by_name(&cfg.model).ok_or_else(|| {
        crate::error::Error::msg(format!(
            "model {:?} is not a known architecture (known models: {})",
            cfg.model,
            crate::sim::flops::known_arch_names().join(", ")
        ))
    })?;
    Ok(native::model::model_spec_of(&arch))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parsing() {
        assert_eq!(BackendChoice::parse("native"), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("cpu"), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("xla"), BackendChoice::Xla("refconv".into()));
        assert_eq!(BackendChoice::parse("cudnn_r2"), BackendChoice::Xla("cudnn_r2".into()));
    }

    #[test]
    fn build_falls_back_to_native_without_artifacts() {
        // Default config names an artifact backend but points at a
        // nonexistent artifacts dir — the factory must hand back the
        // native path rather than a dead end.
        let mut cfg = TrainConfig::default();
        cfg.backend = "refconv".into();
        cfg.artifacts_dir = std::path::PathBuf::from("/nonexistent/artifacts");
        let b = build_backend(&cfg).unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.model().num_classes, 100); // alexnet-tiny default
        // The eval-only factory applies the same rule.
        let e = build_eval_backend(&cfg).unwrap();
        assert_eq!(e.name(), "native");
        assert!(e.supports_eval());
    }

    #[test]
    fn unknown_model_still_errors() {
        let mut cfg = TrainConfig::default();
        cfg.backend = "refconv".into();
        cfg.model = "resnet".into();
        cfg.artifacts_dir = std::path::PathBuf::from("/nonexistent/artifacts");
        assert!(build_backend(&cfg).is_err());
        cfg.backend = "native".into();
        assert!(build_backend(&cfg).is_err());
    }

    #[test]
    fn resolve_model_matches_backend() {
        let mut cfg = TrainConfig::default();
        cfg.backend = "native".into();
        cfg.model = "alexnet-micro".into();
        let m = resolve_model(&cfg).unwrap();
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.image_hw, 32);
        // Underscore spelling resolves to the same arch.
        cfg.model = "alexnet_micro".into();
        assert_eq!(resolve_model(&cfg).unwrap().total_param_elements(), m.total_param_elements());
    }
}
