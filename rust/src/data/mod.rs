//! Data pipeline: the paper's ImageNet substrate, substituted per
//! DESIGN.md with a deterministic synthetic corpus that exercises the
//! identical code path — disk shards -> host read -> preprocessing
//! (mean subtraction, random crop, horizontal flip; paper footnote 2)
//! -> staged device batch — with a real, hideable loading cost.
//!
//! [`loader`] implements Fig 1: a loading thread prefetches and
//! preprocesses minibatch *k+1* while the trainer consumes minibatch
//! *k*, handing over through a bounded (depth-1) channel = the paper's
//! double-buffered shared-GPU staging variable.

pub mod loader;
pub mod mean_image;
pub mod preprocess;
pub mod sampler;
pub mod shard;
pub mod synth;

pub use loader::{BatchSource, HostBatch, LoaderStats, ParallelLoader, SerialLoader};
pub use sampler::EpochSampler;
pub use shard::{ShardReader, ShardWriter, ShardedDataset};
pub use synth::{generate_dataset, DatasetMeta, SynthSpec};
