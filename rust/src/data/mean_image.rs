//! Mean-image computation over an existing shard split.
//!
//! Normally the mean is produced during dataset generation; this
//! streaming pass exists for datasets imported from elsewhere and for
//! verifying a stored `mean.f32` against its shards.

use std::path::Path;

use crate::data::preprocess::MeanImage;
use crate::data::shard::ShardedDataset;
use crate::error::Result;

/// Stream every example of `split` and average the pixels (f64 acc).
pub fn compute_mean(dir: &Path, split: &str) -> Result<MeanImage> {
    let mut ds = ShardedDataset::open(dir, split, false)?;
    let n = ds.len().max(1);
    let mut acc = vec![0f64; ds.channels * ds.height * ds.width];
    let mut buf = Vec::new();
    for i in 0..ds.len() {
        ds.read_into(i, &mut buf)?;
        for (a, &p) in acc.iter_mut().zip(&buf) {
            *a += p as f64;
        }
    }
    let inv = 1.0 / n as f64;
    let data: Vec<f32> = acc.iter().map(|&a| (a * inv) as f32).collect();
    MeanImage::new(ds.channels, ds.height, data)
}

/// Max |stored - recomputed| between `mean.f32` and the split's pixels.
pub fn verify_mean(dir: &Path, split: &str) -> Result<f32> {
    let computed = compute_mean(dir, split)?;
    let stored = MeanImage::load(
        &dir.join("mean.f32"),
        computed.channels,
        computed.hw,
    )?;
    Ok(crate::util::math::max_abs_diff(&stored.data, &computed.data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_dataset, SynthSpec};

    #[test]
    fn stored_mean_matches_streaming_recompute() {
        let dir = std::env::temp_dir().join(format!("tmg_mean_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = SynthSpec { classes: 4, hw: 12, seed: 8, ..Default::default() };
        generate_dataset(&dir, &spec, 64, 16, 32).unwrap();
        let err = verify_mean(&dir, "train").unwrap();
        assert!(err < 1e-3, "stored vs recomputed mean differs by {err}");
    }
}
