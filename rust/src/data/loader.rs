//! Minibatch loaders — the paper's Fig 1.
//!
//! [`SerialLoader`] is the "No parallel loading" baseline of Table 1:
//! read + preprocess happen on the training thread, so every step pays
//! `load + compute`.
//!
//! [`ParallelLoader`] is the paper's contribution: a loading thread
//! (the paper's separate *process*; Rust has no GIL so a thread
//! suffices — DESIGN.md substitution table) prefetches and preprocesses
//! the next minibatch while the trainer computes, handing over through
//! a depth-1 bounded channel — the exact double-buffer the paper built
//! with two shared GPU variables.  A step then pays
//! `max(load, compute)`; the `stall_seconds` stat measures the residue
//! (E3's overlap-efficiency metric).  The hand-off is fully park-based
//! (the channel's own blocking `send`/`recv`): the producer never
//! spins or sleeps, and shutdown (`Drop`) wakes a parked producer by
//! draining the staged batch before joining the thread.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::preprocess::{preprocess_into, Augment, MeanImage};
use crate::data::sampler::EpochSampler;
use crate::data::shard::ShardedDataset;
use crate::error::{Error, Result};
use crate::tensor::{HostTensor, Shape};
use crate::util::{Pcg32, Timer};

/// One staged minibatch: preprocessed images (NCHW) + labels.
#[derive(Clone, Debug)]
pub struct HostBatch {
    pub images: HostTensor,
    pub labels: Vec<i32>,
    /// Monotone sequence number (step the batch is destined for).
    pub seq: usize,
}

/// Loader-side counters for the Fig-1 experiments.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoaderStats {
    pub batches: u64,
    /// Producer time: disk read + preprocess (+ staging copy).
    pub load_seconds: f64,
    /// Consumer time blocked waiting for a batch (0 when fully hidden).
    pub stall_seconds: f64,
}

/// Anything the trainer can pull batches from.
pub trait BatchSource: Send {
    fn next_batch(&mut self) -> Result<HostBatch>;
    fn stats(&self) -> LoaderStats;
}

/// Shared innards: one worker's view of the dataset + augmentation.
struct BatchProducer {
    dataset: ShardedDataset,
    sampler: EpochSampler,
    mean: MeanImage,
    rng: Pcg32,
    crop_hw: usize,
    batch: usize,
    seq: usize,
    idx_buf: Vec<usize>,
    pix_buf: Vec<u8>,
    train_augment: bool,
}

impl BatchProducer {
    fn produce(&mut self) -> Result<HostBatch> {
        let c = self.dataset.channels;
        let stored_hw = self.dataset.height;
        let hw = self.crop_hw;
        let mut images = HostTensor::zeros(Shape::of(&[self.batch, c, hw, hw]));
        let mut labels = Vec::with_capacity(self.batch);
        // Split the borrows before the loop: sampler fills the index
        // buffer, then each example is read + preprocessed in place.
        let mut idx_buf = std::mem::take(&mut self.idx_buf);
        self.sampler.next_batch_indices(&mut idx_buf);
        let stride = c * hw * hw;
        let out = images.as_mut_slice();
        for (bi, &ex) in idx_buf.iter().enumerate() {
            let label = self.dataset.read_into(ex, &mut self.pix_buf)?;
            let aug = if self.train_augment {
                Augment::random(&mut self.rng, stored_hw, hw)
            } else {
                Augment::center(stored_hw, hw)
            };
            preprocess_into(
                &self.pix_buf,
                &self.mean,
                stored_hw,
                hw,
                aug,
                &mut out[bi * stride..(bi + 1) * stride],
            )?;
            labels.push(label as i32);
        }
        self.idx_buf = idx_buf;
        let seq = self.seq;
        self.seq += 1;
        Ok(HostBatch { images, labels, seq })
    }

    /// Fast-forward past `batches` already-consumed minibatches
    /// (checkpoint resume): jump the sampler to the exact position and
    /// replay the augmentation RNG draws those batches would have made
    /// — one `Augment::random` per example, no disk reads — so the
    /// continued stream is bit-identical to an uninterrupted run.
    fn fast_forward(&mut self, batches: usize) {
        if batches == 0 {
            return;
        }
        self.sampler.fast_forward(batches);
        if self.train_augment {
            let stored_hw = self.dataset.height;
            for _ in 0..batches * self.batch {
                Augment::random(&mut self.rng, stored_hw, self.crop_hw);
            }
        }
        self.seq = batches;
    }
}

/// Configuration for constructing either loader.
pub struct LoaderCfg<'a> {
    pub data_dir: &'a std::path::Path,
    pub split: &'a str,
    pub batch: usize,
    pub crop_hw: usize,
    pub worker: usize,
    pub workers: usize,
    pub seed: u64,
    pub train_augment: bool,
    pub verify_shards: bool,
}

/// Open one split's dataset + mean image, validating the crop bound —
/// the shared entry point for the training loaders and the sequential
/// evaluator, so the preprocessing inputs (mean file, crop check) have
/// one source of truth.
pub fn open_split(
    data_dir: &std::path::Path,
    split: &str,
    crop_hw: usize,
    verify_shards: bool,
) -> Result<(ShardedDataset, MeanImage)> {
    let dataset = ShardedDataset::open(data_dir, split, verify_shards)?;
    if crop_hw > dataset.height {
        return Err(Error::Shape(format!(
            "crop {} larger than stored image {}",
            crop_hw, dataset.height
        )));
    }
    let mean = MeanImage::load(&data_dir.join("mean.f32"), dataset.channels, dataset.height)?;
    Ok((dataset, mean))
}

/// Like [`open_split`], but an *absent* split (no shard files at all —
/// e.g. a corpus generated with `--val 0`) is `Ok(None)` rather than an
/// error.  Real failures — unreadable directory, corrupt shards,
/// missing mean file, bad crop — still error.
pub fn open_split_optional(
    data_dir: &std::path::Path,
    split: &str,
    crop_hw: usize,
    verify_shards: bool,
) -> Result<Option<(ShardedDataset, MeanImage)>> {
    if ShardedDataset::scan_split(data_dir, split)?.is_empty() {
        return Ok(None);
    }
    open_split(data_dir, split, crop_hw, verify_shards).map(Some)
}

fn build_producer(cfg: &LoaderCfg) -> Result<BatchProducer> {
    let (dataset, mean) = open_split(cfg.data_dir, cfg.split, cfg.crop_hw, cfg.verify_shards)?;
    let sampler = EpochSampler::new(dataset.len(), cfg.batch, cfg.worker, cfg.workers, cfg.seed);
    Ok(BatchProducer {
        rng: Pcg32::new(cfg.seed ^ 0xAAB0_57E0, cfg.worker as u64 + 101),
        dataset,
        sampler,
        mean,
        crop_hw: cfg.crop_hw,
        batch: cfg.batch,
        seq: 0,
        idx_buf: Vec::new(),
        pix_buf: Vec::new(),
        train_augment: cfg.train_augment,
    })
}

/// Table 1's "parallel loading: No" baseline.
pub struct SerialLoader {
    producer: BatchProducer,
    stats: LoaderStats,
}

impl SerialLoader {
    pub fn new(cfg: &LoaderCfg) -> Result<Self> {
        Self::resumed(cfg, 0)
    }

    /// Loader whose stream starts after `skip_batches` already-consumed
    /// minibatches (checkpoint resume).
    pub fn resumed(cfg: &LoaderCfg, skip_batches: usize) -> Result<Self> {
        let mut producer = build_producer(cfg)?;
        producer.fast_forward(skip_batches);
        Ok(SerialLoader { producer, stats: LoaderStats::default() })
    }
}

impl BatchSource for SerialLoader {
    fn next_batch(&mut self) -> Result<HostBatch> {
        let t = Timer::start();
        let b = self.producer.produce()?;
        let dt = t.elapsed_secs();
        self.stats.batches += 1;
        self.stats.load_seconds += dt;
        // Serial loading is *all* stall: the trainer sat idle for it.
        self.stats.stall_seconds += dt;
        Ok(b)
    }

    fn stats(&self) -> LoaderStats {
        self.stats
    }
}

/// The paper's Fig-1 prefetching loader.
pub struct ParallelLoader {
    rx: Receiver<Result<HostBatch>>,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    batches: u64,
    stall_nanos: u64,
    load_nanos: Arc<AtomicU64>,
}

impl ParallelLoader {
    pub fn new(cfg: &LoaderCfg) -> Result<Self> {
        Self::resumed(cfg, 0)
    }

    /// Loader whose stream starts after `skip_batches` already-consumed
    /// minibatches (checkpoint resume).  The fast-forward happens
    /// before the prefetch thread spawns, so the first staged batch is
    /// already the post-resume one.
    pub fn resumed(cfg: &LoaderCfg, skip_batches: usize) -> Result<Self> {
        let mut producer = build_producer(cfg)?;
        producer.fast_forward(skip_batches);
        // Depth-1 channel: exactly one staged batch, as in Fig 1.
        let (tx, rx): (SyncSender<Result<HostBatch>>, _) = std::sync::mpsc::sync_channel(1);
        let stop = Arc::new(AtomicBool::new(false));
        let load_nanos = Arc::new(AtomicU64::new(0));
        let stop2 = stop.clone();
        let load2 = load_nanos.clone();
        let handle = std::thread::Builder::new()
            .name("tmg-loader".into())
            .spawn(move || loop {
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                let t = Timer::start();
                let item = producer.produce();
                load2.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let failed = item.is_err();
                // Block until the trainer takes the staged batch (the
                // paper's "wait for the training process to swap").
                // `SyncSender::send` parks this thread — no polling —
                // and returns `Err` when the receiver is gone, which is
                // also how shutdown unblocks a parked producer: `Drop`
                // drains the staged batch (completing this send) and
                // the next loop iteration observes the stop flag.
                if tx.send(item).is_err() {
                    return;
                }
                if failed {
                    return;
                }
            })
            .map_err(Error::RawIo)?;
        Ok(ParallelLoader {
            rx,
            handle: Some(handle),
            stop,
            batches: 0,
            stall_nanos: 0,
            load_nanos,
        })
    }
}

impl BatchSource for ParallelLoader {
    fn next_batch(&mut self) -> Result<HostBatch> {
        let t = Timer::start();
        let item = self
            .rx
            .recv()
            .map_err(|_| Error::msg("loader thread terminated unexpectedly"))?;
        self.stall_nanos += t.elapsed().as_nanos() as u64;
        self.batches += 1;
        item
    }

    fn stats(&self) -> LoaderStats {
        LoaderStats {
            batches: self.batches,
            load_seconds: self.load_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            stall_seconds: self.stall_nanos as f64 * 1e-9,
        }
    }
}

impl Drop for ParallelLoader {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Drain anything staged so a producer parked in `send` wakes
        // up; it then either exits on the stop flag or completes one
        // last send into the slot we just freed — never blocks again —
        // so the join is bounded by one `produce()`.
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_dataset, SynthSpec};
    use std::path::PathBuf;

    fn make_dataset(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tmg_loader_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = SynthSpec { classes: 7, hw: 20, seed: 3, ..Default::default() };
        generate_dataset(&dir, &spec, 128, 32, 64).unwrap();
        dir
    }

    fn cfg(dir: &std::path::Path, worker: usize, workers: usize) -> LoaderCfg<'_> {
        LoaderCfg {
            data_dir: dir,
            split: "train",
            batch: 8,
            crop_hw: 16,
            worker,
            workers,
            seed: 11,
            train_augment: true,
            verify_shards: true,
        }
    }

    #[test]
    fn serial_and_parallel_yield_same_batches() {
        let dir = make_dataset("same");
        let mut s = SerialLoader::new(&cfg(&dir, 0, 1)).unwrap();
        let mut p = ParallelLoader::new(&cfg(&dir, 0, 1)).unwrap();
        for _ in 0..6 {
            let a = s.next_batch().unwrap();
            let b = p.next_batch().unwrap();
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.images.as_slice(), b.images.as_slice());
        }
    }

    #[test]
    fn batch_shape_and_labels() {
        let dir = make_dataset("shape");
        let mut s = SerialLoader::new(&cfg(&dir, 0, 1)).unwrap();
        let b = s.next_batch().unwrap();
        assert_eq!(b.images.shape().dims(), &[8, 3, 16, 16]);
        assert_eq!(b.labels.len(), 8);
        assert!(b.labels.iter().all(|&l| (0..7).contains(&l)));
        let st = s.stats();
        assert_eq!(st.batches, 1);
        assert!(st.load_seconds > 0.0);
        assert_eq!(st.load_seconds, st.stall_seconds);
    }

    #[test]
    fn two_workers_disjoint_streams() {
        let dir = make_dataset("workers");
        let mut w0 = SerialLoader::new(&cfg(&dir, 0, 2)).unwrap();
        let mut w1 = SerialLoader::new(&cfg(&dir, 1, 2)).unwrap();
        let a = w0.next_batch().unwrap();
        let b = w1.next_batch().unwrap();
        // Same epoch order, different slots => different content.
        assert_ne!(a.images.as_slice(), b.images.as_slice());
    }

    #[test]
    fn parallel_loader_hides_load_when_compute_dominates() {
        let dir = make_dataset("hide");
        let mut p = ParallelLoader::new(&cfg(&dir, 0, 1)).unwrap();
        // Simulate compute long enough to cover load.
        let mut stalled_after_warmup = 0.0;
        for i in 0..8 {
            let _b = p.next_batch().unwrap();
            if i == 2 {
                stalled_after_warmup = p.stats().stall_seconds;
            }
            std::thread::sleep(std::time::Duration::from_millis(12));
        }
        let st = p.stats();
        let steady_stall = st.stall_seconds - stalled_after_warmup;
        assert!(
            steady_stall < 0.5 * st.load_seconds,
            "stall {steady_stall} should be well under load {}",
            st.load_seconds
        );
    }

    #[test]
    fn resumed_loader_continues_the_stream_bit_exactly() {
        // A loader fast-forwarded past k batches must serve exactly the
        // batches an uninterrupted loader serves from k on — same
        // sampler indices AND same crop/flip augmentation draws.  This
        // is the loader half of the bit-exact `--resume` contract.
        let dir = make_dataset("resume");
        for skip in [1usize, 3, 7] {
            let mut straight = SerialLoader::new(&cfg(&dir, 0, 2)).unwrap();
            for _ in 0..skip {
                straight.next_batch().unwrap();
            }
            let mut resumed = SerialLoader::resumed(&cfg(&dir, 0, 2), skip).unwrap();
            // Also exercise the parallel loader's pre-spawn fast-forward.
            let mut resumed_par = ParallelLoader::resumed(&cfg(&dir, 0, 2), skip).unwrap();
            for i in 0..4 {
                let a = straight.next_batch().unwrap();
                let b = resumed.next_batch().unwrap();
                let c = resumed_par.next_batch().unwrap();
                assert_eq!(a.seq, b.seq, "skip {skip}, batch {i}: seq");
                assert_eq!(a.labels, b.labels, "skip {skip}, batch {i}: labels");
                assert_eq!(
                    a.images.as_slice(),
                    b.images.as_slice(),
                    "skip {skip}, batch {i}: pixels"
                );
                assert_eq!(a.seq, c.seq);
                assert_eq!(a.labels, c.labels);
                assert_eq!(a.images.as_slice(), c.images.as_slice());
            }
        }
    }

    #[test]
    fn parallel_loader_shuts_down_cleanly() {
        let dir = make_dataset("drop");
        let p = ParallelLoader::new(&cfg(&dir, 0, 1)).unwrap();
        drop(p); // must not hang
    }

    #[test]
    fn drop_mid_epoch_unparks_and_joins_the_producer() {
        // Regression for the old 50µs try_send poll loop: after a few
        // batches the producer is parked in a blocking `send` with the
        // next batch staged.  Drop must wake it (by draining the staged
        // batch), let it observe the stop flag, and join the thread —
        // all without a hang.  The join inside Drop *is* the
        // thread-exited assertion; the bound just keeps a regression
        // from masquerading as a slow disk.
        let dir = make_dataset("midepoch");
        let mut p = ParallelLoader::new(&cfg(&dir, 0, 1)).unwrap();
        for _ in 0..3 {
            p.next_batch().unwrap();
        }
        // Give the producer time to stage a batch and park on the full
        // channel.
        std::thread::sleep(std::time::Duration::from_millis(30));
        let t = std::time::Instant::now();
        drop(p);
        assert!(
            t.elapsed() < std::time::Duration::from_secs(5),
            "drop took {:?}; producer did not unpark",
            t.elapsed()
        );
    }
}
