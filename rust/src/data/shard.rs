//! On-disk shard format + readers.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   u32  = 0x544D4753        ("TMGS")
//! version u32  = 1
//! channels u32, height u32, width u32
//! count   u32                       (records in this shard)
//! records: count x { label u32, pixels u8[c*h*w] }
//! crc32   u32                       (over all record bytes)
//! ```
//!
//! A `ShardedDataset` maps a global example index to (shard, offset)
//! and serves point reads; the loader wraps it with batching and
//! prefetch.  CRC verification happens once per shard at open.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::Image8;
use crate::util::crc32::Hasher;

pub const MAGIC: u32 = 0x544D_4753;
pub const VERSION: u32 = 1;
const HEADER_BYTES: u64 = 24;

/// Streaming shard writer.
pub struct ShardWriter {
    path: PathBuf,
    file: BufWriter<File>,
    channels: u32,
    height: u32,
    width: u32,
    count: u32,
    crc: Hasher,
    finished: bool,
}

impl ShardWriter {
    pub fn create(path: &Path, channels: usize, height: usize, width: usize) -> Result<Self> {
        let file = File::create(path).map_err(|e| Error::io(path, e))?;
        let mut w = ShardWriter {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            channels: channels as u32,
            height: height as u32,
            width: width as u32,
            count: 0,
            crc: Hasher::new(),
            finished: false,
        };
        // Placeholder header; rewritten with the real count on finish.
        w.write_header(0)?;
        Ok(w)
    }

    fn write_header(&mut self, count: u32) -> Result<()> {
        let mut hdr = Vec::with_capacity(HEADER_BYTES as usize);
        for v in [MAGIC, VERSION, self.channels, self.height, self.width, count] {
            hdr.extend_from_slice(&v.to_le_bytes());
        }
        self.file.write_all(&hdr).map_err(|e| Error::io(&self.path, e))
    }

    /// Append one record.
    pub fn append(&mut self, label: u32, img: &Image8) -> Result<()> {
        debug_assert!(!self.finished);
        let expect =
            (self.channels * self.height * self.width) as usize;
        if img.numel() != expect {
            return Err(Error::Shape(format!(
                "shard record: image has {} pixels, shard expects {expect}",
                img.numel()
            )));
        }
        let lbl = label.to_le_bytes();
        self.file.write_all(&lbl).map_err(|e| Error::io(&self.path, e))?;
        self.file.write_all(&img.pixels).map_err(|e| Error::io(&self.path, e))?;
        self.crc.update(&lbl);
        self.crc.update(&img.pixels);
        self.count += 1;
        Ok(())
    }

    /// Write trailer + fixed-up header.  Must be called exactly once.
    pub fn finish(mut self) -> Result<()> {
        let crc = self.crc.finalize();
        self.file
            .write_all(&crc.to_le_bytes())
            .map_err(|e| Error::io(&self.path, e))?;
        self.file.flush().map_err(|e| Error::io(&self.path, e))?;
        let mut f = self
            .file
            .into_inner()
            .map_err(|e| Error::io(&self.path, e.into_error()))?;
        f.seek(SeekFrom::Start(0)).map_err(|e| Error::io(&self.path, e))?;
        let mut hdr = Vec::with_capacity(HEADER_BYTES as usize);
        for v in [MAGIC, VERSION, self.channels, self.height, self.width, self.count] {
            hdr.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&hdr).map_err(|e| Error::io(&self.path, e))?;
        f.sync_all().map_err(|e| Error::io(&self.path, e))?;
        self.finished = true;
        Ok(())
    }
}

/// Header of an opened shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub count: usize,
}

impl ShardHeader {
    pub fn record_bytes(&self) -> usize {
        4 + self.channels * self.height * self.width
    }
}

/// Random-access reader over one shard file.
pub struct ShardReader {
    path: PathBuf,
    file: BufReader<File>,
    pub header: ShardHeader,
}

fn read_u32(r: &mut impl Read, path: &Path) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|e| Error::io(path, e))?;
    Ok(u32::from_le_bytes(b))
}

impl ShardReader {
    /// Open and header-check; `verify` additionally streams the whole
    /// payload through CRC32 (done once per shard by `ShardedDataset`).
    pub fn open(path: &Path, verify: bool) -> Result<Self> {
        let file = File::open(path).map_err(|e| Error::io(path, e))?;
        let mut br = BufReader::new(file);
        let magic = read_u32(&mut br, path)?;
        if magic != MAGIC {
            return Err(Error::Shard {
                path: path.into(),
                msg: format!("bad magic {magic:#x}"),
            });
        }
        let version = read_u32(&mut br, path)?;
        if version != VERSION {
            return Err(Error::Shard {
                path: path.into(),
                msg: format!("unsupported version {version}"),
            });
        }
        let channels = read_u32(&mut br, path)? as usize;
        let height = read_u32(&mut br, path)? as usize;
        let width = read_u32(&mut br, path)? as usize;
        let count = read_u32(&mut br, path)? as usize;
        let header = ShardHeader { channels, height, width, count };

        let mut rd = ShardReader { path: path.to_path_buf(), file: br, header };
        if verify {
            rd.verify_crc()?;
        }
        Ok(rd)
    }

    fn verify_crc(&mut self) -> Result<()> {
        let payload = self.header.count * self.header.record_bytes();
        self.file
            .seek(SeekFrom::Start(HEADER_BYTES))
            .map_err(|e| Error::io(&self.path, e))?;
        let mut hasher = Hasher::new();
        let mut remaining = payload;
        let mut buf = vec![0u8; 1 << 16];
        while remaining > 0 {
            let n = remaining.min(buf.len());
            self.file
                .read_exact(&mut buf[..n])
                .map_err(|e| Error::io(&self.path, e))?;
            hasher.update(&buf[..n]);
            remaining -= n;
        }
        let stored = read_u32(&mut self.file, &self.path)?;
        let computed = hasher.finalize();
        if stored != computed {
            return Err(Error::Shard {
                path: self.path.clone(),
                msg: format!("crc mismatch: stored {stored:#x}, computed {computed:#x}"),
            });
        }
        Ok(())
    }

    /// Read record `i` into (label, pixel buffer).
    pub fn read_into(&mut self, i: usize, pixels: &mut Vec<u8>) -> Result<u32> {
        if i >= self.header.count {
            return Err(Error::Shard {
                path: self.path.clone(),
                msg: format!("record {i} out of range (count {})", self.header.count),
            });
        }
        let off = HEADER_BYTES + (i * self.header.record_bytes()) as u64;
        self.file
            .seek(SeekFrom::Start(off))
            .map_err(|e| Error::io(&self.path, e))?;
        let label = read_u32(&mut self.file, &self.path)?;
        let n = self.header.record_bytes() - 4;
        pixels.resize(n, 0);
        self.file
            .read_exact(pixels)
            .map_err(|e| Error::io(&self.path, e))?;
        Ok(label)
    }
}

/// A split ("train"/"val") of shards under one directory, addressable
/// by global example index.
pub struct ShardedDataset {
    readers: Vec<ShardReader>,
    /// Cumulative example counts: offsets[i] = first global index of shard i.
    offsets: Vec<usize>,
    total: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
}

impl ShardedDataset {
    /// The sorted `{split}_NNNN.shard` files present in `dir` — the
    /// existence probe behind [`ShardedDataset::open`], exposed so
    /// callers can distinguish "split absent" from real open errors.
    pub fn scan_split(dir: &Path, split: &str) -> Result<Vec<PathBuf>> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| Error::io(dir, e))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with(&format!("{split}_")) && n.ends_with(".shard"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        Ok(paths)
    }

    /// Open all `{split}_NNNN.shard` files in `dir` (sorted), verifying
    /// CRCs once.
    pub fn open(dir: &Path, split: &str, verify: bool) -> Result<Self> {
        let paths = Self::scan_split(dir, split)?;
        if paths.is_empty() {
            return Err(Error::Shard {
                path: dir.into(),
                msg: format!("no {split} shards found"),
            });
        }
        let mut readers = Vec::with_capacity(paths.len());
        let mut offsets = Vec::with_capacity(paths.len());
        let mut total = 0usize;
        for p in &paths {
            let r = ShardReader::open(p, verify)?;
            offsets.push(total);
            total += r.header.count;
            readers.push(r);
        }
        let h = readers[0].header;
        for r in &readers {
            if (r.header.channels, r.header.height, r.header.width)
                != (h.channels, h.height, h.width)
            {
                return Err(Error::Shard {
                    path: r.path.clone(),
                    msg: "inconsistent image dims across shards".into(),
                });
            }
        }
        Ok(ShardedDataset {
            readers,
            offsets,
            total,
            channels: h.channels,
            height: h.height,
            width: h.width,
        })
    }

    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Read global example `idx`.
    pub fn read_into(&mut self, idx: usize, pixels: &mut Vec<u8>) -> Result<u32> {
        if idx >= self.total {
            return Err(Error::msg(format!("example {idx} out of range ({})", self.total)));
        }
        // Binary search the shard containing idx.
        let shard = match self.offsets.binary_search(&idx) {
            Ok(s) => s,
            Err(s) => s - 1,
        };
        self.readers[shard].read_into(idx - self.offsets[shard], pixels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_example, SynthSpec};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tmg_shard_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_read_roundtrip() {
        let dir = tmpdir("rt");
        let spec = SynthSpec { classes: 5, hw: 16, ..Default::default() };
        let path = dir.join("train_0000.shard");
        let mut w = ShardWriter::create(&path, 3, 16, 16).unwrap();
        let mut expect = Vec::new();
        for i in 0..10u64 {
            let img = generate_example(&spec, (i % 5) as usize, i);
            w.append((i % 5) as u32, &img).unwrap();
            expect.push((i % 5, img));
        }
        w.finish().unwrap();

        let mut r = ShardReader::open(&path, true).unwrap();
        assert_eq!(r.header.count, 10);
        let mut buf = Vec::new();
        for (i, (lbl, img)) in expect.iter().enumerate() {
            let got = r.read_into(i, &mut buf).unwrap();
            assert_eq!(got as u64, *lbl);
            assert_eq!(&buf, &img.pixels);
        }
        assert!(r.read_into(10, &mut buf).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("crc");
        let path = dir.join("train_0000.shard");
        let mut w = ShardWriter::create(&path, 1, 4, 4).unwrap();
        w.append(0, &Image8::new(1, 4, 4)).unwrap();
        w.finish().unwrap();
        // Flip one payload byte.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 8] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardReader::open(&path, true).is_err());
        // Without verify, the header still opens.
        assert!(ShardReader::open(&path, false).is_ok());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = tmpdir("magic");
        let path = dir.join("train_0000.shard");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(ShardReader::open(&path, false).is_err());
    }

    #[test]
    fn sharded_dataset_global_index() {
        let dir = tmpdir("ds");
        for s in 0..3 {
            let path = dir.join(format!("train_{s:04}.shard"));
            let mut w = ShardWriter::create(&path, 1, 2, 2).unwrap();
            for i in 0..4 {
                let mut img = Image8::new(1, 2, 2);
                img.pixels = vec![(s * 4 + i) as u8; 4];
                w.append((s * 4 + i) as u32, &img).unwrap();
            }
            w.finish().unwrap();
        }
        let mut ds = ShardedDataset::open(&dir, "train", true).unwrap();
        assert_eq!(ds.len(), 12);
        let mut buf = Vec::new();
        for idx in 0..12 {
            let lbl = ds.read_into(idx, &mut buf).unwrap();
            assert_eq!(lbl as usize, idx);
            assert_eq!(buf[0] as usize, idx);
        }
        assert!(ds.read_into(12, &mut buf).is_err());
        assert!(ShardedDataset::open(&dir, "val", false).is_err());
    }
}
