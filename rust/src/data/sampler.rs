//! Epoch sampling: deterministic shuffles, partitioned across workers.
//!
//! The paper trains the two replicas on *different* minibatches of the
//! same epoch stream (§2.2).  `EpochSampler` reproduces that: one
//! shared seed shuffles each epoch, then worker `w` of `n` takes every
//! n-th minibatch.  Every worker is assigned exactly
//! `batches_per_epoch / workers` minibatches per epoch — the ragged
//! global tail (when `batches_per_epoch % workers != 0`) is dropped, so
//! all workers roll epochs after the *same* number of calls and stay in
//! the same epoch forever.  (Serving the tail to a subset of workers
//! would desynchronize the epochs: replicas would shuffle with
//! different epoch keys and train on overlapping data.)
//!
//! Sampler state is a pure function of the number of batches consumed,
//! which is what makes checkpoint resume bit-exact: `fast_forward`
//! jumps to the state after any batch count, and `position_after`
//! computes that state without a sampler instance (the checkpoint
//! cross-check).

use crate::util::Pcg32;

/// Deterministic per-worker epoch iterator over example indices.
#[derive(Clone, Debug)]
pub struct EpochSampler {
    dataset_len: usize,
    batch: usize,
    worker: usize,
    workers: usize,
    seed: u64,
    epoch: usize,
    order: Vec<u32>,
    /// Next *global* batch number within the epoch assigned to us.
    next_batch: usize,
}

impl EpochSampler {
    pub fn new(dataset_len: usize, batch: usize, worker: usize, workers: usize, seed: u64) -> Self {
        assert!(batch > 0 && workers > 0 && worker < workers);
        assert!(
            dataset_len >= batch * workers,
            "dataset ({dataset_len}) smaller than one round of batches ({})",
            batch * workers
        );
        let mut s = EpochSampler {
            dataset_len,
            batch,
            worker,
            workers,
            seed,
            epoch: 0,
            order: Vec::new(),
            next_batch: 0,
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.dataset_len as u32).collect();
        // Same (seed, epoch) on every worker => identical epoch order;
        // partitioning below keeps their minibatches disjoint.
        let mut rng = Pcg32::new(self.seed, 0xE90C ^ self.epoch as u64);
        rng.shuffle(&mut self.order);
        self.next_batch = self.worker;
    }

    /// Number of whole batches per epoch (shared across workers; the
    /// per-image tail smaller than one batch is dropped).
    pub fn batches_per_epoch(&self) -> usize {
        self.dataset_len / self.batch
    }

    /// Batches *this worker* serves per epoch: the equal share
    /// `batches_per_epoch / workers`.  The ragged global tail (the
    /// `batches_per_epoch % workers` batches that cannot be divided
    /// evenly) is dropped so every worker rolls epochs in lockstep.
    pub fn batches_per_worker_epoch(&self) -> usize {
        (self.batches_per_epoch() / self.workers).max(1)
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Raw (epoch, next global batch) state, pre-roll: immediately
    /// after a worker's last batch of an epoch this still reports the
    /// old epoch (the roll is lazy).  Use [`Self::position_after`] for
    /// the normalized position.
    pub fn position(&self) -> (usize, usize) {
        (self.epoch, self.next_batch)
    }

    /// True once this worker has consumed its per-epoch share.
    fn exhausted(&self) -> bool {
        self.next_batch >= self.worker + self.batches_per_worker_epoch() * self.workers
    }

    /// Indices of the next minibatch for this worker, advancing epochs
    /// as needed (partial trailing batches are dropped, as the paper's
    /// fixed-size Theano functions required).
    pub fn next_batch_indices(&mut self, out: &mut Vec<usize>) {
        if self.exhausted() {
            self.epoch += 1;
            self.reshuffle();
        }
        let start = self.next_batch * self.batch;
        out.clear();
        out.extend(
            self.order[start..start + self.batch]
                .iter()
                .map(|&i| i as usize),
        );
        self.next_batch += self.workers;
    }

    /// Jump to the exact state after `consumed` batches have been
    /// served, without replaying them.  Each epoch shuffles with a
    /// fresh `(seed, epoch)`-keyed stream, so skipping whole epochs
    /// consumes nothing; only the current epoch's order is rebuilt.
    /// A fresh sampler fast-forwarded by `k` then produces the same
    /// stream as a sampler that served `k` batches.
    pub fn fast_forward(&mut self, consumed: usize) {
        let share = self.batches_per_worker_epoch();
        self.epoch = consumed / share;
        self.reshuffle();
        self.next_batch = self.worker + (consumed % share) * self.workers;
    }

    /// The normalized `(epoch, next_batch)` position after `consumed`
    /// batches, as a pure function of the epoch geometry — what a
    /// checkpoint records and what resume cross-checks against the
    /// current data configuration.
    pub fn position_after(
        dataset_len: usize,
        batch: usize,
        worker: usize,
        workers: usize,
        consumed: usize,
    ) -> (u64, u64) {
        let share = ((dataset_len / batch.max(1)) / workers.max(1)).max(1);
        ((consumed / share) as u64, (worker + (consumed % share) * workers) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn workers_partition_an_epoch() {
        let n = 64;
        let batch = 4;
        let mut w0 = EpochSampler::new(n, batch, 0, 2, 9);
        let mut w1 = EpochSampler::new(n, batch, 1, 2, 9);
        let mut seen = HashSet::new();
        let mut buf = Vec::new();
        let rounds = n / batch / 2;
        for _ in 0..rounds {
            w0.next_batch_indices(&mut buf);
            seen.extend(buf.iter().copied());
            w1.next_batch_indices(&mut buf);
            seen.extend(buf.iter().copied());
        }
        assert_eq!(seen.len(), n, "epoch must cover the dataset exactly once");
        assert_eq!(w0.epoch(), 0);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = EpochSampler::new(16, 4, 0, 1, 5);
        let mut e0 = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..4 {
            s.next_batch_indices(&mut buf);
            e0.extend(buf.iter().copied());
        }
        let mut e1 = Vec::new();
        for _ in 0..4 {
            s.next_batch_indices(&mut buf);
            e1.extend(buf.iter().copied());
        }
        assert_eq!(s.epoch(), 1);
        let h0: HashSet<_> = e0.iter().collect();
        let h1: HashSet<_> = e1.iter().collect();
        assert_eq!(h0, h1, "same elements");
        assert_ne!(e0, e1, "different order");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = EpochSampler::new(32, 4, 1, 2, 77);
        let mut b = EpochSampler::new(32, 4, 1, 2, 77);
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..10 {
            a.next_batch_indices(&mut ba);
            b.next_batch_indices(&mut bb);
            assert_eq!(ba, bb);
        }
    }

    /// Regression for the epoch-desync bug: with a ragged batch count
    /// (`batches_per_epoch % workers != 0`) workers used to roll epochs
    /// after *different* numbers of calls, landing replicas in
    /// different epochs with overlapping data.  Every worker now serves
    /// exactly `batches_per_epoch / workers` batches per epoch and all
    /// workers roll together.
    #[test]
    fn ragged_batch_counts_keep_workers_in_epoch_lockstep() {
        for workers in [2usize, 3] {
            // 28 examples / batch 4 = 7 batches per epoch: ragged for
            // both 2 (7 % 2 = 1) and 3 (7 % 3 = 1) workers.
            let n = 28;
            let batch = 4;
            let mut samplers: Vec<_> = (0..workers)
                .map(|w| EpochSampler::new(n, batch, w, workers, 13))
                .collect();
            let share = samplers[0].batches_per_worker_epoch();
            assert_eq!(share, 7 / workers);
            let mut buf = Vec::new();
            for round in 0..3 * share {
                let mut seen = HashSet::new();
                for s in samplers.iter_mut() {
                    s.next_batch_indices(&mut buf);
                    for &i in &buf {
                        assert!(
                            seen.insert(i),
                            "workers={workers} round={round}: index {i} served to \
                             two workers in the same round"
                        );
                    }
                }
                // All workers sit in the same epoch after every round.
                let epochs: HashSet<_> = samplers.iter().map(|s| s.epoch()).collect();
                assert_eq!(
                    epochs.len(),
                    1,
                    "workers={workers} round={round}: epochs desynced: {epochs:?}"
                );
                // Epoch rolls are lazy (they happen inside the call
                // that serves the first batch of the new epoch), so
                // after serving batch `round` the epoch is round/share.
                assert_eq!(samplers[0].epoch(), round / share);
            }
        }
    }

    /// `fast_forward(k)` must land exactly where `k` served batches
    /// land: the continued streams are identical, across epoch rolls.
    #[test]
    fn fast_forward_matches_replay() {
        for (worker, workers, consumed) in
            [(0usize, 1usize, 0usize), (0, 1, 7), (1, 2, 3), (1, 2, 9), (2, 3, 5)]
        {
            let n = 28;
            let batch = 4;
            let mut replayed = EpochSampler::new(n, batch, worker, workers, 99);
            let mut buf = Vec::new();
            for _ in 0..consumed {
                replayed.next_batch_indices(&mut buf);
            }
            let mut jumped = EpochSampler::new(n, batch, worker, workers, 99);
            jumped.fast_forward(consumed);
            let (mut ba, mut bb) = (Vec::new(), Vec::new());
            for i in 0..8 {
                replayed.next_batch_indices(&mut ba);
                jumped.next_batch_indices(&mut bb);
                assert_eq!(ba, bb, "worker {worker}/{workers} skip {consumed}: batch {i} differs");
            }
            // And the jump matches the pure-arithmetic position.
            let mut probe = EpochSampler::new(n, batch, worker, workers, 99);
            probe.fast_forward(consumed);
            let (e, nb) = probe.position();
            assert_eq!(
                (e as u64, nb as u64),
                EpochSampler::position_after(n, batch, worker, workers, consumed)
            );
        }
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_dataset() {
        EpochSampler::new(4, 4, 0, 2, 0);
    }
}
