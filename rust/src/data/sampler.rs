//! Epoch sampling: deterministic shuffles, partitioned across workers.
//!
//! The paper trains the two replicas on *different* minibatches of the
//! same epoch stream (§2.2).  `EpochSampler` reproduces that: one
//! shared seed shuffles each epoch, then worker `w` of `n` takes every
//! n-th minibatch — so the union of what all workers see per epoch is
//! exactly the dataset, with no overlap.

use crate::util::Pcg32;

/// Deterministic per-worker epoch iterator over example indices.
#[derive(Clone, Debug)]
pub struct EpochSampler {
    dataset_len: usize,
    batch: usize,
    worker: usize,
    workers: usize,
    seed: u64,
    epoch: usize,
    order: Vec<u32>,
    /// Next *global* batch number within the epoch assigned to us.
    next_batch: usize,
}

impl EpochSampler {
    pub fn new(dataset_len: usize, batch: usize, worker: usize, workers: usize, seed: u64) -> Self {
        assert!(batch > 0 && workers > 0 && worker < workers);
        assert!(
            dataset_len >= batch * workers,
            "dataset ({dataset_len}) smaller than one round of batches ({})",
            batch * workers
        );
        let mut s = EpochSampler {
            dataset_len,
            batch,
            worker,
            workers,
            seed,
            epoch: 0,
            order: Vec::new(),
            next_batch: 0,
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.dataset_len as u32).collect();
        // Same (seed, epoch) on every worker => identical epoch order;
        // partitioning below keeps their minibatches disjoint.
        let mut rng = Pcg32::new(self.seed, 0xE90C ^ self.epoch as u64);
        rng.shuffle(&mut self.order);
        self.next_batch = self.worker;
    }

    /// Number of whole batches per epoch (shared across workers).
    pub fn batches_per_epoch(&self) -> usize {
        self.dataset_len / self.batch
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Indices of the next minibatch for this worker, advancing epochs
    /// as needed (partial trailing batches are dropped, as the paper's
    /// fixed-size Theano functions required).
    pub fn next_batch_indices(&mut self, out: &mut Vec<usize>) {
        if self.next_batch >= self.batches_per_epoch() {
            self.epoch += 1;
            self.reshuffle();
        }
        let start = self.next_batch * self.batch;
        out.clear();
        out.extend(
            self.order[start..start + self.batch]
                .iter()
                .map(|&i| i as usize),
        );
        self.next_batch += self.workers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn workers_partition_an_epoch() {
        let n = 64;
        let batch = 4;
        let mut w0 = EpochSampler::new(n, batch, 0, 2, 9);
        let mut w1 = EpochSampler::new(n, batch, 1, 2, 9);
        let mut seen = HashSet::new();
        let mut buf = Vec::new();
        let rounds = n / batch / 2;
        for _ in 0..rounds {
            w0.next_batch_indices(&mut buf);
            seen.extend(buf.iter().copied());
            w1.next_batch_indices(&mut buf);
            seen.extend(buf.iter().copied());
        }
        assert_eq!(seen.len(), n, "epoch must cover the dataset exactly once");
        assert_eq!(w0.epoch(), 0);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut s = EpochSampler::new(16, 4, 0, 1, 5);
        let mut e0 = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..4 {
            s.next_batch_indices(&mut buf);
            e0.extend(buf.iter().copied());
        }
        let mut e1 = Vec::new();
        for _ in 0..4 {
            s.next_batch_indices(&mut buf);
            e1.extend(buf.iter().copied());
        }
        assert_eq!(s.epoch(), 1);
        let h0: HashSet<_> = e0.iter().collect();
        let h1: HashSet<_> = e1.iter().collect();
        assert_eq!(h0, h1, "same elements");
        assert_ne!(e0, e1, "different order");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = EpochSampler::new(32, 4, 1, 2, 77);
        let mut b = EpochSampler::new(32, 4, 1, 2, 77);
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..10 {
            a.next_batch_indices(&mut ba);
            b.next_batch_indices(&mut bb);
            assert_eq!(ba, bb);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_tiny_dataset() {
        EpochSampler::new(4, 4, 0, 2, 0);
    }
}
