//! Synthetic class-conditional image corpus (the ImageNet substitute).
//!
//! Each class is a distinct oriented sinusoidal texture: class `k`
//! fixes a (frequency, orientation, per-channel phase) triple, and an
//! example is that texture plus uniform pixel noise and a random DC
//! shift.  Properties that matter for the reproduction:
//!
//! - **learnable**: a small ConvNet separates classes quickly, so the
//!   E2 accuracy-shape experiment (replica averaging vs large batch)
//!   is meaningful;
//! - **deterministic**: (seed, index) fully determines an example, so
//!   runs are bit-reproducible across loader modes and worker counts;
//! - **real cost**: examples are written to (and re-read from) real
//!   shard files as u8 pixels and preprocessed per batch, giving the
//!   Fig-1 pipeline a genuine loading stage to hide.

use std::f32::consts::PI;
use std::path::Path;

use crate::data::shard::ShardWriter;
use crate::error::{Error, Result};
use crate::tensor::Image8;
use crate::util::Pcg32;

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub classes: usize,
    pub channels: usize,
    /// Stored edge (larger than the model input; training crops down).
    pub hw: usize,
    pub noise: f32,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec { classes: 100, channels: 3, hw: 72, noise: 24.0, seed: 1234 }
    }
}

/// Dataset metadata persisted alongside the shards (meta.json).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetMeta {
    pub classes: usize,
    pub channels: usize,
    pub hw: usize,
    pub train_examples: usize,
    pub val_examples: usize,
    pub shard_examples: usize,
    pub seed: u64,
}

impl DatasetMeta {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"classes\": {}, \"channels\": {}, \"hw\": {}, \"train_examples\": {}, \
             \"val_examples\": {}, \"shard_examples\": {}, \"seed\": {}}}",
            self.classes,
            self.channels,
            self.hw,
            self.train_examples,
            self.val_examples,
            self.shard_examples,
            self.seed
        )
    }

    pub fn from_json(src: &str) -> Result<DatasetMeta> {
        let v = crate::util::Json::parse(src)?;
        Ok(DatasetMeta {
            classes: v.num_field("classes")? as usize,
            channels: v.num_field("channels")? as usize,
            hw: v.num_field("hw")? as usize,
            train_examples: v.num_field("train_examples")? as usize,
            val_examples: v.num_field("val_examples")? as usize,
            shard_examples: v.num_field("shard_examples")? as usize,
            seed: v.num_field("seed")? as u64,
        })
    }
}

/// Class-conditional texture parameters, derived deterministically from
/// (seed, class) so generator and tests agree without shared state.
#[derive(Clone, Copy, Debug)]
pub struct ClassTexture {
    pub freq: f32,
    pub angle: f32,
    pub phase: [f32; 4],
}

pub fn class_texture(seed: u64, class: usize) -> ClassTexture {
    let mut r = Pcg32::new(seed ^ 0xC1A5_5E5E, class as u64 + 1);
    ClassTexture {
        freq: 0.15 + 0.55 * r.next_f32(),
        angle: PI * r.next_f32(),
        phase: [
            2.0 * PI * r.next_f32(),
            2.0 * PI * r.next_f32(),
            2.0 * PI * r.next_f32(),
            2.0 * PI * r.next_f32(),
        ],
    }
}

/// Deterministically generate example `index` of class `label`.
pub fn generate_example(spec: &SynthSpec, label: usize, index: u64) -> Image8 {
    let tex = class_texture(spec.seed, label);
    let mut r = Pcg32::new(spec.seed ^ 0xE7A3_11D0, index + 1);
    let dc = (r.next_f32() - 0.5) * 40.0;
    let amp = 70.0 + 30.0 * r.next_f32();
    let (sin_a, cos_a) = tex.angle.sin_cos();
    let mut img = Image8::new(spec.channels, spec.hw, spec.hw);
    for c in 0..spec.channels {
        let phase = tex.phase[c % 4];
        for y in 0..spec.hw {
            for x in 0..spec.hw {
                let u = cos_a * x as f32 + sin_a * y as f32;
                let base = 128.0 + dc + amp * (tex.freq * u + phase).sin();
                let noise = (r.next_f32() - 0.5) * 2.0 * spec.noise;
                img.set(c, y, x, (base + noise).clamp(0.0, 255.0) as u8);
            }
        }
    }
    img
}

/// Label for example `index` (round-robin keeps classes balanced).
pub fn label_of(spec: &SynthSpec, index: u64) -> usize {
    // Mix the index so shard boundaries don't align with class blocks.
    let mut r = Pcg32::new(spec.seed ^ 0x1AB3_7E, index + 1);
    r.below(spec.classes as u32) as usize
}

/// Write a full train/val dataset to `dir`: sharded images, labels,
/// meta.json and the preprocessing mean image (mean.f32).
pub fn generate_dataset(
    dir: &Path,
    spec: &SynthSpec,
    train_examples: usize,
    val_examples: usize,
    shard_examples: usize,
) -> Result<DatasetMeta> {
    if shard_examples == 0 {
        return Err(Error::msg("shard_examples must be > 0"));
    }
    std::fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;

    let mut mean_acc = vec![0f64; spec.channels * spec.hw * spec.hw];
    let mut write_split = |split: &str, count: usize, base_index: u64| -> Result<()> {
        let mut written = 0usize;
        let mut shard_idx = 0usize;
        while written < count {
            let n = shard_examples.min(count - written);
            let path = dir.join(format!("{split}_{shard_idx:04}.shard"));
            let mut w = ShardWriter::create(&path, spec.channels, spec.hw, spec.hw)?;
            for i in 0..n {
                let gidx = base_index + (written + i) as u64;
                let label = label_of(spec, gidx);
                let img = generate_example(spec, label, gidx);
                if split == "train" {
                    for (acc, &p) in mean_acc.iter_mut().zip(&img.pixels) {
                        *acc += p as f64;
                    }
                }
                w.append(label as u32, &img)?;
            }
            w.finish()?;
            written += n;
            shard_idx += 1;
        }
        Ok(())
    };

    write_split("train", train_examples, 0)?;
    // Validation examples draw from a disjoint index range.
    write_split("val", val_examples, 1u64 << 40)?;

    // Mean image over the training split (paper footnote 2).
    let inv = 1.0 / train_examples.max(1) as f64;
    let mean: Vec<f32> = mean_acc.iter().map(|&a| (a * inv) as f32).collect();
    let mut bytes = Vec::with_capacity(mean.len() * 4);
    for v in &mean {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let mean_path = dir.join("mean.f32");
    std::fs::write(&mean_path, &bytes).map_err(|e| Error::io(&mean_path, e))?;

    let meta = DatasetMeta {
        classes: spec.classes,
        channels: spec.channels,
        hw: spec.hw,
        train_examples,
        val_examples,
        shard_examples,
        seed: spec.seed,
    };
    let meta_path = dir.join("meta.json");
    std::fs::write(&meta_path, meta.to_json()).map_err(|e| Error::io(&meta_path, e))?;
    Ok(meta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = SynthSpec::default();
        let a = generate_example(&spec, 3, 17);
        let b = generate_example(&spec, 3, 17);
        assert_eq!(a, b);
        let c = generate_example(&spec, 3, 18);
        assert_ne!(a, c);
    }

    #[test]
    fn classes_have_distinct_textures() {
        let t1 = class_texture(1, 0);
        let t2 = class_texture(1, 1);
        assert!((t1.freq - t2.freq).abs() > 1e-6 || (t1.angle - t2.angle).abs() > 1e-6);
    }

    #[test]
    fn labels_balanced_roughly() {
        let spec = SynthSpec { classes: 10, ..Default::default() };
        let mut counts = [0usize; 10];
        for i in 0..5_000 {
            counts[label_of(&spec, i)] += 1;
        }
        for &c in &counts {
            assert!((300..800).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn meta_roundtrip() {
        let m = DatasetMeta {
            classes: 10,
            channels: 3,
            hw: 40,
            train_examples: 100,
            val_examples: 10,
            shard_examples: 64,
            seed: 7,
        };
        assert_eq!(DatasetMeta::from_json(&m.to_json()).unwrap(), m);
    }
}
