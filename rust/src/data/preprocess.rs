//! Minibatch preprocessing (paper footnote 2): subtract the training
//! mean image, take a random crop of the model's input size, and
//! horizontally flip with probability 1/2.  Eval uses the center crop
//! and no flip, as AlexNet did at validation time.
//!
//! Output scale: `(pixel - mean) / 64.0` — roughly unit-variance input
//! for the He-initialized scaled models (the full AlexNet config keeps
//! the paper's raw-scale convention via `PIXEL_SCALE = 1.0` would be a
//! config knob; one scale is used everywhere for consistency).

use crate::error::{Error, Result};
use crate::util::Pcg32;

/// Divisor applied after mean subtraction.
pub const PIXEL_SCALE: f32 = 64.0;

/// Mean image in stored (full) resolution, CHW f32.
#[derive(Clone, Debug)]
pub struct MeanImage {
    pub channels: usize,
    pub hw: usize,
    pub data: Vec<f32>,
}

impl MeanImage {
    pub fn new(channels: usize, hw: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != channels * hw * hw {
            return Err(Error::Shape(format!(
                "mean image: {} values for {channels}x{hw}x{hw}",
                data.len()
            )));
        }
        Ok(MeanImage { channels, hw, data })
    }

    /// Load the little-endian f32 blob written by data generation.
    pub fn load(path: &std::path::Path, channels: usize, hw: usize) -> Result<Self> {
        let bytes = std::fs::read(path).map_err(|e| Error::io(path, e))?;
        if bytes.len() != channels * hw * hw * 4 {
            return Err(Error::Shape(format!(
                "mean.f32 has {} bytes, expected {}",
                bytes.len(),
                channels * hw * hw * 4
            )));
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(MeanImage { channels, hw, data })
    }

    #[inline]
    fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.hw + y) * self.hw + x]
    }
}

/// Crop + flip decision for one example.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Augment {
    pub off_y: usize,
    pub off_x: usize,
    pub flip: bool,
}

impl Augment {
    /// Training augmentation: uniform crop offset + fair-coin flip.
    pub fn random(rng: &mut Pcg32, stored_hw: usize, crop_hw: usize) -> Augment {
        let span = (stored_hw - crop_hw + 1) as u32;
        Augment {
            off_y: rng.below(span) as usize,
            off_x: rng.below(span) as usize,
            flip: rng.coin(0.5),
        }
    }

    /// Eval: deterministic center crop, no flip.
    pub fn center(stored_hw: usize, crop_hw: usize) -> Augment {
        let off = (stored_hw - crop_hw) / 2;
        Augment { off_y: off, off_x: off, flip: false }
    }
}

/// Preprocess one stored u8 image (CHW, `stored_hw` edge) into the
/// destination f32 slice (CHW, `crop_hw` edge): mean-subtract, crop,
/// optional horizontal flip, scale.
///
/// `dst` must have exactly `channels * crop_hw * crop_hw` elements.
pub fn preprocess_into(
    pixels: &[u8],
    mean: &MeanImage,
    stored_hw: usize,
    crop_hw: usize,
    aug: Augment,
    dst: &mut [f32],
) -> Result<()> {
    let channels = mean.channels;
    if pixels.len() != channels * stored_hw * stored_hw {
        return Err(Error::Shape(format!(
            "preprocess: {} pixels for {channels}x{stored_hw}x{stored_hw}",
            pixels.len()
        )));
    }
    if dst.len() != channels * crop_hw * crop_hw {
        return Err(Error::Shape(format!(
            "preprocess: dst {} values for {channels}x{crop_hw}x{crop_hw}",
            dst.len()
        )));
    }
    if aug.off_y + crop_hw > stored_hw || aug.off_x + crop_hw > stored_hw {
        return Err(Error::Shape("crop window out of bounds".into()));
    }
    let inv = 1.0 / PIXEL_SCALE;
    for c in 0..channels {
        for y in 0..crop_hw {
            let sy = y + aug.off_y;
            let src_row = (c * stored_hw + sy) * stored_hw + aug.off_x;
            let dst_row = (c * crop_hw + y) * crop_hw;
            for x in 0..crop_hw {
                let sx = if aug.flip { crop_hw - 1 - x } else { x };
                let p = pixels[src_row + sx] as f32;
                let m = mean.at(c, sy, aug.off_x + sx);
                dst[dst_row + x] = (p - m) * inv;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_mean(channels: usize, hw: usize, v: f32) -> MeanImage {
        MeanImage::new(channels, hw, vec![v; channels * hw * hw]).unwrap()
    }

    #[test]
    fn center_crop_values() {
        // stored 4x4, crop 2x2 from center offset (1,1).
        let pixels: Vec<u8> = (0..16).collect();
        let mean = flat_mean(1, 4, 0.0);
        let mut dst = vec![0f32; 4];
        preprocess_into(&pixels, &mean, 4, 2, Augment::center(4, 2), &mut dst).unwrap();
        // rows y=1..2, x=1..2 of the 4x4 ramp: 5,6,9,10
        let want: Vec<f32> = [5.0, 6.0, 9.0, 10.0].iter().map(|v| v / PIXEL_SCALE).collect();
        assert_eq!(dst, want);
    }

    #[test]
    fn flip_reverses_rows() {
        let pixels: Vec<u8> = (0..16).collect();
        let mean = flat_mean(1, 4, 0.0);
        let mut a = vec![0f32; 4];
        let mut b = vec![0f32; 4];
        let base = Augment { off_y: 1, off_x: 1, flip: false };
        let flip = Augment { off_y: 1, off_x: 1, flip: true };
        preprocess_into(&pixels, &mean, 4, 2, base, &mut a).unwrap();
        preprocess_into(&pixels, &mean, 4, 2, flip, &mut b).unwrap();
        assert_eq!(a[0], b[1]);
        assert_eq!(a[1], b[0]);
        assert_eq!(a[2], b[3]);
    }

    #[test]
    fn mean_subtraction() {
        let pixels = vec![100u8; 9];
        let mean = flat_mean(1, 3, 40.0);
        let mut dst = vec![0f32; 9];
        preprocess_into(&pixels, &mean, 3, 3, Augment::center(3, 3), &mut dst).unwrap();
        for v in dst {
            assert!((v - 60.0 / PIXEL_SCALE).abs() < 1e-6);
        }
    }

    #[test]
    fn random_augment_in_bounds() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..1000 {
            let a = Augment::random(&mut rng, 72, 64);
            assert!(a.off_y <= 8 && a.off_x <= 8);
        }
    }

    #[test]
    fn shape_errors() {
        let mean = flat_mean(1, 4, 0.0);
        let mut dst = vec![0f32; 4];
        assert!(preprocess_into(&[0u8; 15], &mean, 4, 2, Augment::center(4, 2), &mut dst).is_err());
        let mut small = vec![0f32; 3];
        assert!(
            preprocess_into(&[0u8; 16], &mean, 4, 2, Augment::center(4, 2), &mut small).is_err()
        );
        let bad = Augment { off_y: 3, off_x: 0, flip: false };
        assert!(preprocess_into(&[0u8; 16], &mean, 4, 2, bad, &mut dst).is_err());
    }
}
