//! Host-side parameter initialization from manifest specs.
//!
//! Both replicas call this with the *same* seed, reproducing the
//! paper's "they are initialized identically": the tensors are
//! generated from per-tensor PCG streams derived from (seed, index),
//! so the result is independent of iteration order and worker id.

use crate::runtime::artifact::ParamManifestSpec;
use crate::tensor::HostTensor;
use crate::util::Pcg32;

/// Materialize parameters per manifest recipe.
pub fn init_params(specs: &[ParamManifestSpec], seed: u64) -> Vec<HostTensor> {
    specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut t = HostTensor::zeros(s.shape.clone());
            match s.init.as_str() {
                "normal" => {
                    let mut rng = Pcg32::new(seed ^ 0x9A17_AB1E, i as u64 + 1);
                    rng.fill_normal(t.as_mut_slice(), s.std);
                }
                // "zeros" honours bias_value (AlexNet sets some biases to 1).
                _ => {
                    if s.bias_value != 0.0 {
                        t.as_mut_slice().fill(s.bias_value);
                    }
                }
            }
            t
        })
        .collect()
}

/// Zero momenta matching the parameter shapes.
pub fn zero_momenta(specs: &[ParamManifestSpec]) -> Vec<HostTensor> {
    specs.iter().map(|s| HostTensor::zeros(s.shape.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    fn spec(name: &str, shape: &[usize], init: &str, std: f32, bias: f32) -> ParamManifestSpec {
        ParamManifestSpec {
            name: name.into(),
            shape: Shape::of(shape),
            init: init.into(),
            std,
            bias_value: bias,
        }
    }

    #[test]
    fn identical_across_calls() {
        let specs = vec![spec("w", &[32, 16], "normal", 0.05, 0.0)];
        let a = init_params(&specs, 7);
        let b = init_params(&specs, 7);
        assert_eq!(a[0].as_slice(), b[0].as_slice());
        let c = init_params(&specs, 8);
        assert_ne!(a[0].as_slice(), c[0].as_slice());
    }

    #[test]
    fn respects_std() {
        let specs = vec![spec("w", &[10_000], "normal", 0.02, 0.0)];
        let p = init_params(&specs, 1);
        let std = crate::util::math::stddev(
            &p[0].as_slice().iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!((std - 0.02).abs() < 0.002, "std {std}");
    }

    #[test]
    fn bias_fill() {
        let specs = vec![
            spec("b0", &[4], "zeros", 0.0, 0.0),
            spec("b1", &[4], "zeros", 0.0, 1.0),
        ];
        let p = init_params(&specs, 1);
        assert_eq!(p[0].as_slice(), &[0.0; 4]);
        assert_eq!(p[1].as_slice(), &[1.0; 4]);
    }

    #[test]
    fn per_tensor_streams_differ() {
        let specs = vec![
            spec("w1", &[64], "normal", 1.0, 0.0),
            spec("w2", &[64], "normal", 1.0, 0.0),
        ];
        let p = init_params(&specs, 3);
        assert_ne!(p[0].as_slice(), p[1].as_slice());
    }

    #[test]
    fn momenta_zero() {
        let specs = vec![spec("w", &[3, 3], "normal", 0.1, 0.0)];
        let m = zero_momenta(&specs);
        assert!(m[0].as_slice().iter().all(|&v| v == 0.0));
    }
}
