//! Averaging kernels (Fig-2 step 3) as standalone slice ops.
//!
//! Kept separate from `ParamStore` so the comm layer and the N-GPU
//! ring extension can reuse them on raw buffers, and so the perf pass
//! can optimize one single-pass loop.

/// `a <- (a + b) / 2`, elementwise.  The Fig-2 pairwise average.
pub fn average_pair(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x = 0.5 * (*x + y);
    }
}

/// `a <- wa * a + wb * b` — generalized weighted average, used by the
/// ring all-reduce (weights 1/N) and the ablation configurations.
pub fn average_weighted(a: &mut [f32], wa: f32, b: &[f32], wb: f32) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x = wa * *x + wb * y;
    }
}

/// `acc <- acc + b` (ring reduce-scatter accumulate step).
pub fn accumulate(acc: &mut [f32], b: &[f32]) {
    debug_assert_eq!(acc.len(), b.len());
    for (x, &y) in acc.iter_mut().zip(b) {
        *x += y;
    }
}

/// `a <- a * s` (ring finalization: divide by N).
pub fn scale_in_place(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_is_midpoint() {
        let mut a = [1.0, 3.0];
        average_pair(&mut a, &[3.0, 1.0]);
        assert_eq!(a, [2.0, 2.0]);
    }

    #[test]
    fn weighted_generalizes_pair() {
        let mut a = [1.0, 3.0];
        average_weighted(&mut a, 0.5, &[3.0, 1.0], 0.5);
        assert_eq!(a, [2.0, 2.0]);
        let mut b = [1.0];
        average_weighted(&mut b, 0.25, &[2.0], 0.75);
        assert_eq!(b, [1.75]);
    }

    #[test]
    fn accumulate_and_scale() {
        let mut acc = [1.0, 2.0];
        accumulate(&mut acc, &[3.0, 4.0]);
        assert_eq!(acc, [4.0, 6.0]);
        scale_in_place(&mut acc, 0.5);
        assert_eq!(acc, [2.0, 3.0]);
    }
}
