//! Parameter state: host-side init (identical across replicas, paper
//! §2.2), the per-worker store of weights + momenta, averaging kernels
//! (Fig-2 step 3) and binary checkpoints.

pub mod average;
pub mod checkpoint;
pub mod init;
pub mod store;

pub use average::{average_pair, average_weighted};
pub use checkpoint::{load_checkpoint, save_checkpoint};
pub use init::init_params;
pub use store::ParamStore;
