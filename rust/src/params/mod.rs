//! Parameter state: host-side init (identical across replicas, paper
//! §2.2), the per-worker store of weights + momenta, averaging kernels
//! (Fig-2 step 3) and binary checkpoints.

pub mod average;
pub mod checkpoint;
pub mod init;
pub mod store;

pub use average::{average_pair, average_weighted};
pub use checkpoint::{
    best_marker_error, find_auto_resume, load_checkpoint, load_checkpoint_full,
    peek_checkpoint, periodic_checkpoint_name, prune_checkpoints, read_marker,
    resume_set_from_path, save_checkpoint, save_checkpoint_v2, verify_checkpoint,
    worker_sibling, write_marker, CheckpointInfo, ResumeSet, TrainState, BEST_MARKER,
    LATEST_MARKER,
};
pub use init::init_params;
pub use store::ParamStore;
