//! Per-worker parameter store: weights + momenta, addressable by the
//! manifest order the step ABI expects.

use crate::error::{Error, Result};
use crate::params::init::{init_params, zero_momenta};
use crate::runtime::artifact::ParamManifestSpec;
use crate::tensor::HostTensor;

/// Weights and momenta for one replica.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub specs: Vec<ParamManifestSpec>,
    pub params: Vec<HostTensor>,
    pub momenta: Vec<HostTensor>,
}

impl ParamStore {
    /// Fresh store per manifest; same seed => identical replicas.
    pub fn init(specs: &[ParamManifestSpec], seed: u64) -> Self {
        ParamStore {
            specs: specs.to_vec(),
            params: init_params(specs, seed),
            momenta: zero_momenta(specs),
        }
    }

    pub fn n_tensors(&self) -> usize {
        self.params.len()
    }

    pub fn total_elements(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Bytes exchanged per Fig-2 round: params (+ momenta if included).
    pub fn exchange_bytes(&self, include_momentum: bool) -> usize {
        let p = self.total_elements() * 4;
        if include_momentum {
            2 * p
        } else {
            p
        }
    }

    /// Replace state from step outputs (same order as `params` then
    /// `momenta`).
    pub fn update_from(&mut self, new_params: Vec<HostTensor>, new_momenta: Vec<HostTensor>) -> Result<()> {
        if new_params.len() != self.params.len() || new_momenta.len() != self.momenta.len() {
            return Err(Error::Shape(format!(
                "update_from: {}+{} tensors, store holds {}+{}",
                new_params.len(),
                new_momenta.len(),
                self.params.len(),
                self.momenta.len()
            )));
        }
        for (slot, t) in self.params.iter_mut().zip(new_params) {
            if slot.shape() != t.shape() {
                return Err(Error::Shape(format!(
                    "update_from: param shape {} -> {}",
                    slot.shape(),
                    t.shape()
                )));
            }
            *slot = t;
        }
        for (slot, t) in self.momenta.iter_mut().zip(new_momenta) {
            if slot.shape() != t.shape() {
                return Err(Error::Shape("update_from: momentum shape mismatch".into()));
            }
            *slot = t;
        }
        Ok(())
    }

    /// Flatten all state (params then momenta) into one contiguous
    /// buffer — the wire format of the exchange transports.
    pub fn flatten(&self, include_momentum: bool) -> Vec<f32> {
        let mut out = Vec::new();
        self.flatten_into(&mut out, include_momentum);
        out
    }

    /// Allocation-reusing flatten (§Perf: the exchange hot path calls
    /// this every round; steady-state it performs zero allocations).
    pub fn flatten_into(&self, out: &mut Vec<f32>, include_momentum: bool) {
        let n = self.total_elements() * if include_momentum { 2 } else { 1 };
        out.clear();
        out.reserve(n);
        for p in &self.params {
            out.extend_from_slice(p.as_slice());
        }
        if include_momentum {
            for m in &self.momenta {
                out.extend_from_slice(m.as_slice());
            }
        }
    }

    /// Average our state with a peer's flattened state in place
    /// (Fig-2 step 3).  The peer buffer must come from `flatten` with
    /// the same `include_momentum`.
    pub fn average_with_flat(&mut self, peer: &[f32], include_momentum: bool) -> Result<()> {
        let want = self.total_elements() * if include_momentum { 2 } else { 1 };
        if peer.len() != want {
            return Err(Error::Shape(format!(
                "average_with_flat: peer has {} values, want {want}",
                peer.len()
            )));
        }
        let mut off = 0;
        for p in self.params.iter_mut() {
            let n = p.numel();
            for (a, &b) in p.as_mut_slice().iter_mut().zip(&peer[off..off + n]) {
                *a = 0.5 * (*a + b);
            }
            off += n;
        }
        if include_momentum {
            for m in self.momenta.iter_mut() {
                let n = m.numel();
                for (a, &b) in m.as_mut_slice().iter_mut().zip(&peer[off..off + n]) {
                    *a = 0.5 * (*a + b);
                }
                off += n;
            }
        }
        Ok(())
    }

    /// Overwrite state from a flat buffer produced by [`Self::flatten`]
    /// with the same `include_momentum` — the unstage step of the ring
    /// collective (the inverse of `flatten_into`).
    pub fn unflatten_from(&mut self, flat: &[f32], include_momentum: bool) -> Result<()> {
        let want = self.total_elements() * if include_momentum { 2 } else { 1 };
        if flat.len() != want {
            return Err(Error::Shape(format!(
                "unflatten_from: {} values, want {want}",
                flat.len()
            )));
        }
        let mut off = 0;
        for p in self.params.iter_mut() {
            let n = p.numel();
            p.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        if include_momentum {
            for m in self.momenta.iter_mut() {
                let n = m.numel();
                m.as_mut_slice().copy_from_slice(&flat[off..off + n]);
                off += n;
            }
        }
        Ok(())
    }

    /// Max |a-b| over params only — the drift metric when replicas are
    /// *not* expected to be fully synchronized (exchange period > 1 or
    /// momenta excluded), where momenta legitimately differ.
    pub fn param_divergence(&self, other: &ParamStore) -> f32 {
        let mut d = 0f32;
        for (a, b) in self.params.iter().zip(&other.params) {
            d = d.max(crate::util::math::max_abs_diff(a.as_slice(), b.as_slice()));
        }
        d
    }

    /// Max |a-b| across all state of two stores (divergence metric for
    /// the exchange-period ablation E6).
    pub fn max_divergence(&self, other: &ParamStore) -> f32 {
        let mut d = 0f32;
        for (a, b) in self.params.iter().zip(&other.params) {
            d = d.max(crate::util::math::max_abs_diff(a.as_slice(), b.as_slice()));
        }
        for (a, b) in self.momenta.iter().zip(&other.momenta) {
            d = d.max(crate::util::math::max_abs_diff(a.as_slice(), b.as_slice()));
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    fn specs() -> Vec<ParamManifestSpec> {
        vec![
            ParamManifestSpec {
                name: "w".into(),
                shape: Shape::of(&[2, 3]),
                init: "normal".into(),
                std: 0.1,
                bias_value: 0.0,
            },
            ParamManifestSpec {
                name: "b".into(),
                shape: Shape::of(&[3]),
                init: "zeros".into(),
                std: 0.0,
                bias_value: 0.5,
            },
        ]
    }

    #[test]
    fn init_identical_replicas() {
        let a = ParamStore::init(&specs(), 5);
        let b = ParamStore::init(&specs(), 5);
        assert_eq!(a.max_divergence(&b), 0.0);
        assert_eq!(a.total_elements(), 9);
        assert_eq!(a.exchange_bytes(true), 72);
        assert_eq!(a.exchange_bytes(false), 36);
    }

    #[test]
    fn flatten_average_roundtrip() {
        let mut a = ParamStore::init(&specs(), 5);
        let mut b = ParamStore::init(&specs(), 5);
        // Perturb b.
        for v in b.params[0].as_mut_slice() {
            *v += 1.0;
        }
        for v in b.momenta[1].as_mut_slice() {
            *v += 2.0;
        }
        let fa = a.flatten(true);
        let fb = b.flatten(true);
        a.average_with_flat(&fb, true).unwrap();
        b.average_with_flat(&fa, true).unwrap();
        // After symmetric averaging both replicas agree (Fig-2 invariant).
        assert!(a.max_divergence(&b) < 1e-7);
        // And the averaged value is midway.
        assert!((a.params[0].as_slice()[0]
            - (fa[0] + fb[0]) * 0.5)
            .abs()
            < 1e-7);
    }

    #[test]
    fn average_without_momentum_leaves_momenta() {
        let mut a = ParamStore::init(&specs(), 5);
        let mut b = ParamStore::init(&specs(), 5);
        for v in b.momenta[0].as_mut_slice() {
            *v += 3.0;
        }
        let fb = b.flatten(false);
        let before = a.momenta[0].clone();
        a.average_with_flat(&fb, false).unwrap();
        assert_eq!(a.momenta[0], before);
    }

    #[test]
    fn unflatten_roundtrips_flatten() {
        let mut a = ParamStore::init(&specs(), 5);
        for v in a.momenta[0].as_mut_slice() {
            *v = 0.75;
        }
        let flat = a.flatten(true);
        let mut b = ParamStore::init(&specs(), 99);
        b.unflatten_from(&flat, true).unwrap();
        assert_eq!(a.max_divergence(&b), 0.0);
        // Params-only buffer leaves momenta alone.
        let mut c = ParamStore::init(&specs(), 99);
        let before = c.momenta[0].clone();
        c.unflatten_from(&a.flatten(false), false).unwrap();
        assert_eq!(c.momenta[0], before);
        assert_eq!(c.param_divergence(&a), 0.0);
        // Wrong length rejected.
        assert!(b.unflatten_from(&[0.0; 3], true).is_err());
    }

    #[test]
    fn param_divergence_ignores_momenta() {
        let a = ParamStore::init(&specs(), 5);
        let mut b = ParamStore::init(&specs(), 5);
        for v in b.momenta[0].as_mut_slice() {
            *v += 9.0;
        }
        assert_eq!(a.param_divergence(&b), 0.0);
        assert!(a.max_divergence(&b) > 8.0);
    }

    #[test]
    fn shape_guards() {
        let mut a = ParamStore::init(&specs(), 5);
        assert!(a.average_with_flat(&[0.0; 3], true).is_err());
        let wrong = vec![HostTensor::zeros(Shape::of(&[1]))];
        assert!(a.update_from(wrong, vec![]).is_err());
    }
}
