//! Binary checkpoints: params + momenta + step counter, CRC-protected.
//!
//! Format (little-endian):
//!
//! ```text
//! magic u32 = 0x544D4743 ("TMGC"), version u32 = 1
//! step u64, n_tensors u32
//! per tensor: name_len u32, name bytes, rank u32, dims u32[rank]
//! payload: params f32s then momenta f32s, manifest order
//! crc32 u32 over payload bytes
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::params::store::ParamStore;
use crate::tensor::{HostTensor, Shape};
use crate::util::crc32::Hasher;

const MAGIC: u32 = 0x544D_4743;
const VERSION: u32 = 1;

/// Serialize a replica's state.
pub fn save_checkpoint(path: &Path, store: &ParamStore, step: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
        }
    }
    let f = std::fs::File::create(path).map_err(|e| Error::io(path, e))?;
    let mut w = BufWriter::new(f);
    let put_u32 = |w: &mut BufWriter<std::fs::File>, v: u32| -> Result<()> {
        w.write_all(&v.to_le_bytes()).map_err(Error::RawIo)
    };
    put_u32(&mut w, MAGIC)?;
    put_u32(&mut w, VERSION)?;
    w.write_all(&step.to_le_bytes()).map_err(Error::RawIo)?;
    put_u32(&mut w, store.n_tensors() as u32)?;
    for (spec, p) in store.specs.iter().zip(&store.params) {
        put_u32(&mut w, spec.name.len() as u32)?;
        w.write_all(spec.name.as_bytes()).map_err(Error::RawIo)?;
        put_u32(&mut w, p.shape().rank() as u32)?;
        for &d in p.shape().dims() {
            put_u32(&mut w, d as u32)?;
        }
    }
    let mut crc = Hasher::new();
    let write_tensor = |w: &mut BufWriter<std::fs::File>, t: &HostTensor, crc: &mut Hasher| -> Result<()> {
        let mut bytes = Vec::with_capacity(t.numel() * 4);
        for v in t.as_slice() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        crc.update(&bytes);
        w.write_all(&bytes).map_err(Error::RawIo)
    };
    for p in &store.params {
        write_tensor(&mut w, p, &mut crc)?;
    }
    for m in &store.momenta {
        write_tensor(&mut w, m, &mut crc)?;
    }
    put_u32(&mut w, crc.finalize())?;
    w.flush().map_err(Error::RawIo)
}

/// Load a checkpoint into a store initialized from the same manifest;
/// returns the saved step.  Validates names, shapes and CRC.
pub fn load_checkpoint(path: &Path, store: &mut ParamStore) -> Result<u64> {
    let f = std::fs::File::open(path).map_err(|e| Error::io(path, e))?;
    let mut r = BufReader::new(f);
    let get_u32 = |r: &mut BufReader<std::fs::File>| -> Result<u32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b).map_err(Error::RawIo)?;
        Ok(u32::from_le_bytes(b))
    };
    if get_u32(&mut r)? != MAGIC {
        return Err(Error::Checkpoint(format!("{path:?}: bad magic")));
    }
    if get_u32(&mut r)? != VERSION {
        return Err(Error::Checkpoint(format!("{path:?}: bad version")));
    }
    let mut step_b = [0u8; 8];
    r.read_exact(&mut step_b).map_err(Error::RawIo)?;
    let step = u64::from_le_bytes(step_b);
    let n = get_u32(&mut r)? as usize;
    if n != store.n_tensors() {
        return Err(Error::Checkpoint(format!(
            "{path:?}: {n} tensors, store has {}",
            store.n_tensors()
        )));
    }
    for spec in &store.specs {
        let name_len = get_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name).map_err(Error::RawIo)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Checkpoint("non-utf8 tensor name".into()))?;
        if name != spec.name {
            return Err(Error::Checkpoint(format!(
                "{path:?}: tensor {name:?} where {:?} expected (manifest changed?)",
                spec.name
            )));
        }
        let rank = get_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(get_u32(&mut r)? as usize);
        }
        if Shape(dims.clone()) != spec.shape {
            return Err(Error::Checkpoint(format!(
                "{path:?}: {name:?} has shape {dims:?}, manifest wants {}",
                spec.shape
            )));
        }
    }
    let mut crc = Hasher::new();
    let read_tensor = |r: &mut BufReader<std::fs::File>, t: &mut HostTensor, crc: &mut Hasher| -> Result<()> {
        let mut bytes = vec![0u8; t.numel() * 4];
        r.read_exact(&mut bytes).map_err(Error::RawIo)?;
        crc.update(&bytes);
        for (v, c) in t.as_mut_slice().iter_mut().zip(bytes.chunks_exact(4)) {
            *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    };
    let mut params = store.params.clone();
    let mut momenta = store.momenta.clone();
    for p in params.iter_mut() {
        read_tensor(&mut r, p, &mut crc)?;
    }
    for m in momenta.iter_mut() {
        read_tensor(&mut r, m, &mut crc)?;
    }
    let stored = get_u32(&mut r)?;
    if stored != crc.finalize() {
        return Err(Error::Checkpoint(format!("{path:?}: payload CRC mismatch")));
    }
    store.params = params;
    store.momenta = momenta;
    Ok(step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamManifestSpec;

    fn specs() -> Vec<ParamManifestSpec> {
        vec![
            ParamManifestSpec {
                name: "conv1_w".into(),
                shape: Shape::of(&[4, 3, 2, 2]),
                init: "normal".into(),
                std: 0.1,
                bias_value: 0.0,
            },
            ParamManifestSpec {
                name: "conv1_b".into(),
                shape: Shape::of(&[4]),
                init: "zeros".into(),
                std: 0.0,
                bias_value: 0.0,
            },
        ]
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tmg_ckpt_{tag}_{}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut a = ParamStore::init(&specs(), 3);
        for v in a.momenta[0].as_mut_slice() {
            *v = 0.25;
        }
        let path = tmp("rt");
        save_checkpoint(&path, &a, 1234).unwrap();
        let mut b = ParamStore::init(&specs(), 999); // different init
        let step = load_checkpoint(&path, &mut b).unwrap();
        assert_eq!(step, 1234);
        assert_eq!(a.max_divergence(&b), 0.0);
    }

    #[test]
    fn detects_corruption() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("corrupt");
        save_checkpoint(&path, &a, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut b = ParamStore::init(&specs(), 3);
        assert!(load_checkpoint(&path, &mut b).is_err());
    }

    #[test]
    fn rejects_mismatched_manifest() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("mismatch");
        save_checkpoint(&path, &a, 1).unwrap();
        let mut other_specs = specs();
        other_specs[1].name = "renamed".into();
        let mut b = ParamStore::init(&other_specs, 3);
        assert!(load_checkpoint(&path, &mut b).is_err());
    }
}
