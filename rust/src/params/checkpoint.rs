//! Binary checkpoints: params + momenta + lifecycle state, CRC-protected.
//!
//! Format v2 (current, little-endian):
//!
//! ```text
//! magic u32 = 0x544D4743 ("TMGC"), version u32 = 2
//! step u64
//! worker u32, workers u32              -- which replica saved, of how many
//! exchange_fingerprint u64             -- resume-critical config hash
//! sampler_epoch u64, sampler_next_batch u64
//! lr f32 (bits)                        -- lr_at(step) when saved
//! n_tensors u32
//! per tensor: name_len u32, name bytes, rank u32, dims u32[rank]
//! payload: params f32s then momenta f32s, manifest order
//! crc32 u32 over payload bytes
//! ```
//!
//! v1 files (no lifecycle block) remain loadable — old checkpoints can
//! still be evaluated and even resumed from, minus the config
//! cross-checks the v2 state enables.
//!
//! Every write is **atomic**: the file is staged as `<name>.tmp`,
//! fsynced, then renamed over the destination — a kill mid-save can
//! never leave a truncated checkpoint under the real name, and
//! [`find_auto_resume`] additionally validates candidates (header parse
//! + declared-size check) so `--resume auto` skips anything corrupt.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::params::store::ParamStore;
use crate::tensor::{HostTensor, Shape};
use crate::util::crc32::Hasher;

const MAGIC: u32 = 0x544D_4743;
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;

/// Marker file in the checkpoint dir naming the newest periodic
/// checkpoint (worker-0 filename).  Advisory: `--resume auto` always
/// re-validates by scanning.
pub const LATEST_MARKER: &str = "LATEST";

/// Marker file naming the checkpoint with the best validation top-1
/// error so far (worker-0 filename + the error).  Retention pruning
/// never deletes the step it names.
pub const BEST_MARKER: &str = "BEST";

/// Training-lifecycle state a v2 checkpoint carries beyond the tensors
/// — everything needed to make `--resume` bit-exact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainState {
    /// Completed steps when saved (training resumes at this step).
    pub step: u64,
    /// Replica that wrote this file.
    pub worker: u32,
    /// Worker count of the saving run (must match on resume).
    pub workers: u32,
    /// Hash of the resume-critical config (see
    /// `TrainConfig::resume_fingerprint`): mismatch means the resumed
    /// run could not be bit-exact, so loading for resume fails fast.
    pub exchange_fingerprint: u64,
    /// Saving worker's sampler epoch after `step` batches.
    pub sampler_epoch: u64,
    /// Saving worker's next global batch number within that epoch.
    pub sampler_next_batch: u64,
    /// `lr_at(step)` when saved; cross-checked (warn only) on resume so
    /// a changed schedule is visible.
    pub lr: f32,
}

/// Parsed checkpoint header (no payload).
#[derive(Clone, Copy, Debug)]
pub struct CheckpointInfo {
    pub version: u32,
    pub step: u64,
    /// `Some` for v2 files, `None` for v1.
    pub state: Option<TrainState>,
}

/// Sibling path used to stage an atomic write.
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("ckpt"));
    name.push(".tmp");
    path.with_file_name(name)
}

/// Serialize a replica's state in the legacy v1 layout (step only).
/// Kept as a writer so v1 compatibility stays testable; new code saves
/// v2 via [`save_checkpoint_v2`].
pub fn save_checkpoint(path: &Path, store: &ParamStore, step: u64) -> Result<()> {
    write_checkpoint(path, store, step, None)
}

/// Serialize a replica's state plus the training-lifecycle block
/// (format v2), atomically.
pub fn save_checkpoint_v2(path: &Path, store: &ParamStore, state: &TrainState) -> Result<()> {
    write_checkpoint(path, store, state.step, Some(state))
}

fn write_checkpoint(
    path: &Path,
    store: &ParamStore,
    step: u64,
    state: Option<&TrainState>,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| Error::io(parent, e))?;
        }
    }
    let tmp = tmp_sibling(path);
    {
        let f = std::fs::File::create(&tmp).map_err(|e| Error::io(&tmp, e))?;
        let mut w = BufWriter::new(f);
        let put_u32 = |w: &mut BufWriter<std::fs::File>, v: u32| -> Result<()> {
            w.write_all(&v.to_le_bytes()).map_err(Error::RawIo)
        };
        let put_u64 = |w: &mut BufWriter<std::fs::File>, v: u64| -> Result<()> {
            w.write_all(&v.to_le_bytes()).map_err(Error::RawIo)
        };
        put_u32(&mut w, MAGIC)?;
        put_u32(&mut w, if state.is_some() { VERSION_V2 } else { VERSION_V1 })?;
        put_u64(&mut w, step)?;
        if let Some(st) = state {
            put_u32(&mut w, st.worker)?;
            put_u32(&mut w, st.workers)?;
            put_u64(&mut w, st.exchange_fingerprint)?;
            put_u64(&mut w, st.sampler_epoch)?;
            put_u64(&mut w, st.sampler_next_batch)?;
            put_u32(&mut w, st.lr.to_bits())?;
        }
        put_u32(&mut w, store.n_tensors() as u32)?;
        for (spec, p) in store.specs.iter().zip(&store.params) {
            put_u32(&mut w, spec.name.len() as u32)?;
            w.write_all(spec.name.as_bytes()).map_err(Error::RawIo)?;
            put_u32(&mut w, p.shape().rank() as u32)?;
            for &d in p.shape().dims() {
                put_u32(&mut w, d as u32)?;
            }
        }
        let mut crc = Hasher::new();
        let write_tensor = |w: &mut BufWriter<std::fs::File>,
                            t: &HostTensor,
                            crc: &mut Hasher|
         -> Result<()> {
            let mut bytes = Vec::with_capacity(t.numel() * 4);
            for v in t.as_slice() {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            crc.update(&bytes);
            w.write_all(&bytes).map_err(Error::RawIo)
        };
        for p in &store.params {
            write_tensor(&mut w, p, &mut crc)?;
        }
        for m in &store.momenta {
            write_tensor(&mut w, m, &mut crc)?;
        }
        put_u32(&mut w, crc.finalize())?;
        w.flush().map_err(Error::RawIo)?;
        // Durability before visibility: the rename below must never
        // publish a file whose bytes are still in the page cache only.
        w.get_ref().sync_all().map_err(Error::RawIo)?;
    }
    std::fs::rename(&tmp, path).map_err(|e| Error::io(path, e))
}

fn get_u32(r: &mut impl Read, path: &Path) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)
        .map_err(|e| Error::Checkpoint(format!("{path:?}: truncated ({e})")))?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64(r: &mut impl Read, path: &Path) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)
        .map_err(|e| Error::Checkpoint(format!("{path:?}: truncated ({e})")))?;
    Ok(u64::from_le_bytes(b))
}

/// Read magic/version/step/lifecycle block; returns the info and the
/// number of header bytes consumed so far.
fn read_prelude(r: &mut impl Read, path: &Path) -> Result<(CheckpointInfo, u64)> {
    if get_u32(r, path)? != MAGIC {
        return Err(Error::Checkpoint(format!("{path:?}: bad magic")));
    }
    let version = get_u32(r, path)?;
    let step = get_u64(r, path)?;
    let mut consumed = 16u64;
    let state = match version {
        VERSION_V1 => None,
        VERSION_V2 => {
            let worker = get_u32(r, path)?;
            let workers = get_u32(r, path)?;
            let exchange_fingerprint = get_u64(r, path)?;
            let sampler_epoch = get_u64(r, path)?;
            let sampler_next_batch = get_u64(r, path)?;
            let lr = f32::from_bits(get_u32(r, path)?);
            consumed += 36;
            Some(TrainState {
                step,
                worker,
                workers,
                exchange_fingerprint,
                sampler_epoch,
                sampler_next_batch,
                lr,
            })
        }
        v => {
            return Err(Error::Checkpoint(format!(
                "{path:?}: unsupported version {v} (this build reads v1/v2)"
            )))
        }
    };
    Ok((CheckpointInfo { version, step, state }, consumed))
}

/// Load a checkpoint into a store initialized from the same manifest;
/// returns the saved step.  Accepts v1 and v2 files; validates names,
/// shapes and CRC.
pub fn load_checkpoint(path: &Path, store: &mut ParamStore) -> Result<u64> {
    Ok(load_checkpoint_full(path, store)?.step)
}

/// [`load_checkpoint`] that also surfaces the v2 lifecycle state
/// (`None` for v1 files).
pub fn load_checkpoint_full(path: &Path, store: &mut ParamStore) -> Result<CheckpointInfo> {
    let f = std::fs::File::open(path).map_err(|e| Error::io(path, e))?;
    let mut r = BufReader::new(f);
    let (info, _) = read_prelude(&mut r, path)?;
    let n = get_u32(&mut r, path)? as usize;
    if n != store.n_tensors() {
        return Err(Error::Checkpoint(format!(
            "{path:?}: {n} tensors, store has {}",
            store.n_tensors()
        )));
    }
    for spec in &store.specs {
        let name_len = get_u32(&mut r, path)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name).map_err(Error::RawIo)?;
        let name = String::from_utf8(name)
            .map_err(|_| Error::Checkpoint("non-utf8 tensor name".into()))?;
        if name != spec.name {
            return Err(Error::Checkpoint(format!(
                "{path:?}: tensor {name:?} where {:?} expected (manifest changed?)",
                spec.name
            )));
        }
        let rank = get_u32(&mut r, path)? as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(get_u32(&mut r, path)? as usize);
        }
        if Shape(dims.clone()) != spec.shape {
            return Err(Error::Checkpoint(format!(
                "{path:?}: {name:?} has shape {dims:?}, manifest wants {}",
                spec.shape
            )));
        }
    }
    let mut crc = Hasher::new();
    let read_tensor = |r: &mut BufReader<std::fs::File>,
                       t: &mut HostTensor,
                       crc: &mut Hasher|
     -> Result<()> {
        let mut bytes = vec![0u8; t.numel() * 4];
        r.read_exact(&mut bytes)
            .map_err(|e| Error::Checkpoint(format!("{path:?}: truncated payload ({e})")))?;
        crc.update(&bytes);
        for (v, c) in t.as_mut_slice().iter_mut().zip(bytes.chunks_exact(4)) {
            *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    };
    let mut params = store.params.clone();
    let mut momenta = store.momenta.clone();
    for p in params.iter_mut() {
        read_tensor(&mut r, p, &mut crc)?;
    }
    for m in momenta.iter_mut() {
        read_tensor(&mut r, m, &mut crc)?;
    }
    let stored = get_u32(&mut r, path)?;
    if stored != crc.finalize() {
        return Err(Error::Checkpoint(format!("{path:?}: payload CRC mismatch")));
    }
    store.params = params;
    store.momenta = momenta;
    Ok(info)
}

/// Cheap validity probe, no payload read: parses the header and tensor
/// table and checks the on-disk length matches the declared payload, so
/// a truncated or garbage file is rejected without touching megabytes
/// of tensor data.  (The full CRC still runs at load time.)
pub fn peek_checkpoint(path: &Path) -> Result<CheckpointInfo> {
    probe_checkpoint(path, false)
}

/// Full validity check: [`peek_checkpoint`] plus a streamed CRC over
/// the payload, without needing a `ParamStore`.  `--resume auto` runs
/// this on candidates so a same-length bit-rotted file is *skipped*
/// (falling back to an older set) instead of being selected and then
/// failing the run at load time.
pub fn verify_checkpoint(path: &Path) -> Result<CheckpointInfo> {
    probe_checkpoint(path, true)
}

fn probe_checkpoint(path: &Path, check_crc: bool) -> Result<CheckpointInfo> {
    let f = std::fs::File::open(path).map_err(|e| Error::io(path, e))?;
    let actual_len = f.metadata().map_err(Error::RawIo)?.len();
    let mut r = BufReader::new(f);
    let (info, mut consumed) = read_prelude(&mut r, path)?;
    let n = get_u32(&mut r, path)? as usize;
    consumed += 4;
    if n > 65_536 {
        return Err(Error::Checkpoint(format!("{path:?}: implausible tensor count {n}")));
    }
    let mut total_elems = 0u64;
    for _ in 0..n {
        let name_len = get_u32(&mut r, path)? as usize;
        if name_len > 4_096 {
            return Err(Error::Checkpoint(format!("{path:?}: implausible tensor name")));
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)
            .map_err(|e| Error::Checkpoint(format!("{path:?}: truncated ({e})")))?;
        let rank = get_u32(&mut r, path)? as usize;
        if rank > 8 {
            return Err(Error::Checkpoint(format!("{path:?}: implausible rank {rank}")));
        }
        let mut elems = 1u64;
        for _ in 0..rank {
            elems = elems.saturating_mul(get_u32(&mut r, path)? as u64);
        }
        // Saturating throughout: garbage dims must yield a rejection,
        // never an overflow panic inside the validity probe itself.
        total_elems = total_elems.saturating_add(elems);
        consumed += 4 + name_len as u64 + 4 + 4 * rank as u64;
    }
    // Payload: params + momenta f32s, then the CRC word.
    let payload = total_elems.saturating_mul(8);
    let expected = consumed.saturating_add(payload).saturating_add(4);
    if actual_len != expected {
        return Err(Error::Checkpoint(format!(
            "{path:?}: {actual_len} bytes on disk, header declares {expected} (truncated?)"
        )));
    }
    if check_crc {
        let mut crc = Hasher::new();
        let mut buf = [0u8; 64 * 1024];
        let mut remaining = payload;
        while remaining > 0 {
            let take = remaining.min(buf.len() as u64) as usize;
            r.read_exact(&mut buf[..take])
                .map_err(|e| Error::Checkpoint(format!("{path:?}: truncated payload ({e})")))?;
            crc.update(&buf[..take]);
            remaining -= take as u64;
        }
        if get_u32(&mut r, path)? != crc.finalize() {
            return Err(Error::Checkpoint(format!("{path:?}: payload CRC mismatch")));
        }
    }
    Ok(info)
}

/// Canonical filename of worker `worker`'s periodic checkpoint at
/// `step` for a run called `name`.
pub fn periodic_checkpoint_name(name: &str, step: usize, worker: usize) -> String {
    format!("{name}_step{step}.w{worker}.ckpt")
}

/// Split a checkpoint filename into (stem, worker) when it carries a
/// `.w<N>.ckpt` per-worker suffix.
fn split_worker_suffix(fname: &str) -> Option<(&str, usize)> {
    let stem = fname.strip_suffix(".ckpt")?;
    let (head, w) = stem.rsplit_once(".w")?;
    if w.is_empty() || !w.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((head, w.parse().ok()?))
}

/// Worker `worker`'s sibling of a checkpoint path: per-worker files
/// (`....w<K>.ckpt`) map onto the worker's own file; a plain `.ckpt`
/// (a final/replica-0 snapshot) is shared by every worker.
pub fn worker_sibling(path: &Path, worker: usize) -> PathBuf {
    let fname = match path.file_name() {
        Some(f) => f.to_string_lossy().into_owned(),
        None => return path.to_path_buf(),
    };
    match split_worker_suffix(&fname) {
        Some((head, _)) => path.with_file_name(format!("{head}.w{worker}.ckpt")),
        None => path.to_path_buf(),
    }
}

/// A validated set of restore paths, one per worker (indices align
/// with worker ids; a shared single-file checkpoint repeats the path).
#[derive(Clone, Debug)]
pub struct ResumeSet {
    pub step: u64,
    pub paths: Vec<PathBuf>,
}

impl ResumeSet {
    /// True when every worker restores its own replica file.
    pub fn per_worker(&self) -> bool {
        self.paths.len() < 2 || self.paths[0] != self.paths[1]
    }
}

fn resume_set_checked(
    path: &Path,
    workers: usize,
    expect_fingerprint: Option<u64>,
    check_crc: bool,
) -> Result<ResumeSet> {
    let paths: Vec<PathBuf> = (0..workers.max(1)).map(|w| worker_sibling(path, w)).collect();
    let mut step: Option<u64> = None;
    for p in &paths {
        let info = if check_crc {
            verify_checkpoint(p)?
        } else {
            peek_checkpoint(p)?
        };
        if let Some(st) = info.state {
            if st.workers as usize != workers {
                return Err(Error::Checkpoint(format!(
                    "{p:?}: saved by a {}-worker run, resuming with {workers}",
                    st.workers
                )));
            }
            if let Some(fp) = expect_fingerprint {
                if st.exchange_fingerprint != fp {
                    return Err(Error::Checkpoint(format!(
                        "{p:?}: exchange/config fingerprint mismatch"
                    )));
                }
            }
        }
        match step {
            None => step = Some(info.step),
            Some(s) if s != info.step => {
                return Err(Error::Checkpoint(format!(
                    "{p:?}: step {} differs from sibling step {s}",
                    info.step
                )))
            }
            Some(_) => {}
        }
    }
    Ok(ResumeSet { step: step.unwrap_or(0), paths })
}

/// Resolve an explicit `--resume PATH` into per-worker restore paths:
/// every worker file must exist, parse, and agree on the step.  Errors
/// are hard — an explicitly named checkpoint that cannot be restored
/// should fail the run, not silently start fresh.  (No CRC pass here:
/// the load itself verifies it, and a hard failure is the right
/// outcome for an explicitly named file.)
pub fn resume_set_from_path(path: &Path, workers: usize) -> Result<ResumeSet> {
    resume_set_checked(path, workers, None, false)
}

/// `--resume auto`: newest checkpoint in `dir` whose full per-worker
/// set is valid (header + size + payload-CRC checks) and compatible
/// with this run (worker count + config fingerprint).
/// Corrupt/truncated/bit-rotted/foreign candidates are skipped, not
/// fatal — the scan falls back to the next-older intact set.
/// Per-worker sets win over a shared single file at the same step.
pub fn find_auto_resume(dir: &Path, workers: usize, fingerprint: u64) -> Result<Option<ResumeSet>> {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(None),
    };
    // Phase 1: cheap screening (header + size + fingerprint, no
    // payload read) over every anchor in the dir.
    let mut candidates: Vec<ResumeSet> = Vec::new();
    for entry in rd.flatten() {
        let fname = entry.file_name().to_string_lossy().into_owned();
        if !fname.ends_with(".ckpt") {
            continue;
        }
        // Anchor candidates on worker-0 files (siblings are derived)
        // and on plain shared checkpoints; skip .w1+/.tmp noise.
        match split_worker_suffix(&fname) {
            Some((_, 0)) | None => {}
            Some(_) => continue,
        }
        match resume_set_checked(&dir.join(&fname), workers, Some(fingerprint), false) {
            Ok(set) => candidates.push(set),
            Err(e) => log::debug!("--resume auto: skipping {fname:?}: {e}"),
        }
    }
    // Phase 2: newest first (per-worker sets ahead of a shared file at
    // the same step), CRC-stream the payloads and stop at the first
    // intact set — checkpoints can be hundreds of MB, so only what is
    // actually resumed from gets fully read.  A shared single-file set
    // repeats one path; verify it once.
    candidates
        .sort_by(|a, b| b.step.cmp(&a.step).then_with(|| b.per_worker().cmp(&a.per_worker())));
    'candidates: for set in candidates {
        let distinct = if set.per_worker() { set.paths.len() } else { 1 };
        for p in &set.paths[..distinct] {
            if let Err(e) = verify_checkpoint(p) {
                log::debug!("--resume auto: skipping step-{} set: {e}", set.step);
                continue 'candidates;
            }
        }
        return Ok(Some(set));
    }
    Ok(None)
}

/// Atomically write a small text marker file (`LATEST`/`BEST`) in the
/// checkpoint dir.
pub fn write_marker(dir: &Path, marker: &str, contents: &str) -> Result<()> {
    let path = dir.join(marker);
    let tmp = tmp_sibling(&path);
    {
        let mut f = std::fs::File::create(&tmp).map_err(|e| Error::io(&tmp, e))?;
        f.write_all(contents.as_bytes()).map_err(Error::RawIo)?;
        f.write_all(b"\n").map_err(Error::RawIo)?;
        f.sync_all().map_err(Error::RawIo)?;
    }
    std::fs::rename(&tmp, &path).map_err(|e| Error::io(&path, e))
}

/// Read a marker file's first whitespace-delimited token (the
/// checkpoint filename), if present.
pub fn read_marker(dir: &Path, marker: &str) -> Option<String> {
    let s = std::fs::read_to_string(dir.join(marker)).ok()?;
    s.split_whitespace().next().map(|t| t.to_string())
}

/// The validation top-1 error the `BEST` marker records
/// (`<file> top1_error=<err>`), if the marker exists and parses.  A
/// resumed run seeds its best-so-far from this, so the historical best
/// checkpoint is never displaced (or pruned) by a worse post-resume
/// eval.
pub fn best_marker_error(dir: &Path) -> Option<f32> {
    let s = std::fs::read_to_string(dir.join(BEST_MARKER)).ok()?;
    s.split_whitespace()
        .find_map(|t| t.strip_prefix("top1_error="))
        .and_then(|v| v.parse().ok())
}

/// Step number encoded in a periodic checkpoint filename for `name`,
/// e.g. `myrun_step120.w0.ckpt` → 120.
fn step_from_name(fname: &str, name: &str) -> Option<usize> {
    let rest = fname.strip_prefix(name)?.strip_prefix("_step")?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() {
        return None;
    }
    digits.parse().ok()
}

/// Retention policy: keep the newest `keep` *completed* periodic
/// checkpoint steps in addition to `current_step` (whose sibling files
/// other workers may still be writing) and the step named by the
/// `BEST` marker; delete the rest of this run's per-worker files.
/// Retaining `keep` full older sets besides the in-flight one means a
/// kill during the current step's writes always leaves at least one
/// complete, resumable set on disk.  `keep == 0` disables pruning.
/// Returns the number of files removed.
pub fn prune_checkpoints(
    dir: &Path,
    name: &str,
    workers: usize,
    keep: usize,
    current_step: usize,
) -> Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let best_step = read_marker(dir, BEST_MARKER).and_then(|f| step_from_name(&f, name));
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(0),
    };
    // Enumerate retired steps through *any* worker's file, not just
    // worker 0's: if a lagging worker writes its snapshot after the
    // step was pruned (possible when checkpoint_every < exchange
    // period), the orphan is picked up and removed on the next pass.
    let mut steps: Vec<usize> = rd
        .flatten()
        .filter_map(|e| {
            let fname = e.file_name().to_string_lossy().into_owned();
            split_worker_suffix(&fname).and_then(|_| step_from_name(&fname, name))
        })
        .filter(|&s| s < current_step)
        .collect();
    steps.sort_unstable_by(|a, b| b.cmp(a));
    steps.dedup();
    let mut removed = 0usize;
    // The (possibly still-in-flight) current step plus the `keep`
    // newest completed older steps survive.
    for &s in steps.iter().skip(keep) {
        if Some(s) == best_step {
            continue;
        }
        for w in 0..workers {
            let p = dir.join(periodic_checkpoint_name(name, s, w));
            if std::fs::remove_file(&p).is_ok() {
                removed += 1;
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamManifestSpec;

    fn specs() -> Vec<ParamManifestSpec> {
        vec![
            ParamManifestSpec {
                name: "conv1_w".into(),
                shape: Shape::of(&[4, 3, 2, 2]),
                init: "normal".into(),
                std: 0.1,
                bias_value: 0.0,
            },
            ParamManifestSpec {
                name: "conv1_b".into(),
                shape: Shape::of(&[4]),
                init: "zeros".into(),
                std: 0.0,
                bias_value: 0.0,
            },
        ]
    }

    fn state(step: u64, worker: u32, workers: u32) -> TrainState {
        TrainState {
            step,
            worker,
            workers,
            exchange_fingerprint: 0xFEED_F00D,
            sampler_epoch: 3,
            sampler_next_batch: 17,
            lr: 0.01,
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tmg_ckpt_{tag}_{}.bin", std::process::id()))
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tmg_ckptdir_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let mut a = ParamStore::init(&specs(), 3);
        for v in a.momenta[0].as_mut_slice() {
            *v = 0.25;
        }
        let path = tmp("rt");
        save_checkpoint(&path, &a, 1234).unwrap();
        let mut b = ParamStore::init(&specs(), 999); // different init
        let step = load_checkpoint(&path, &mut b).unwrap();
        assert_eq!(step, 1234);
        assert_eq!(a.max_divergence(&b), 0.0);
    }

    #[test]
    fn v2_roundtrip_carries_lifecycle_state() {
        let mut a = ParamStore::init(&specs(), 3);
        for v in a.momenta[1].as_mut_slice() {
            *v = -0.5;
        }
        let path = tmp("v2rt");
        let st = state(640, 1, 2);
        save_checkpoint_v2(&path, &a, &st).unwrap();
        let mut b = ParamStore::init(&specs(), 999);
        let info = load_checkpoint_full(&path, &mut b).unwrap();
        assert_eq!(info.version, 2);
        assert_eq!(info.step, 640);
        assert_eq!(info.state, Some(st));
        assert_eq!(a.max_divergence(&b), 0.0);
        // The plain loader reads v2 too (eval path).
        let mut c = ParamStore::init(&specs(), 7);
        assert_eq!(load_checkpoint(&path, &mut c).unwrap(), 640);
        // And peek agrees without reading the payload.
        let peeked = peek_checkpoint(&path).unwrap();
        assert_eq!(peeked.step, 640);
        assert_eq!(peeked.state, Some(st));
    }

    #[test]
    fn v1_files_still_load_without_state() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("v1compat");
        save_checkpoint(&path, &a, 9).unwrap();
        let mut b = ParamStore::init(&specs(), 0);
        let info = load_checkpoint_full(&path, &mut b).unwrap();
        assert_eq!((info.version, info.step), (1, 9));
        assert!(info.state.is_none());
        assert_eq!(peek_checkpoint(&path).unwrap().version, 1);
    }

    #[test]
    fn atomic_write_leaves_no_tmp_and_replaces_in_place() {
        let dir = tmp_dir("atomic");
        let path = dir.join("run.ckpt");
        let a = ParamStore::init(&specs(), 1);
        save_checkpoint_v2(&path, &a, &state(1, 0, 1)).unwrap();
        save_checkpoint_v2(&path, &a, &state(2, 0, 1)).unwrap(); // overwrite
        assert_eq!(peek_checkpoint(&path).unwrap().step, 2);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "staging files left behind: {leftovers:?}");
    }

    #[test]
    fn detects_corruption() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("corrupt");
        save_checkpoint(&path, &a, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut b = ParamStore::init(&specs(), 3);
        assert!(load_checkpoint(&path, &mut b).is_err());
    }

    #[test]
    fn truncated_files_fail_peek_and_load() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("trunc");
        save_checkpoint_v2(&path, &a, &state(5, 0, 1)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for cut in [10, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(peek_checkpoint(&path).is_err(), "peek accepted a {cut}-byte prefix");
            let mut b = ParamStore::init(&specs(), 3);
            assert!(
                load_checkpoint(&path, &mut b).is_err(),
                "load accepted a {cut}-byte prefix"
            );
        }
    }

    #[test]
    fn rejects_mismatched_manifest() {
        let a = ParamStore::init(&specs(), 3);
        let path = tmp("mismatch");
        save_checkpoint(&path, &a, 1).unwrap();
        let mut other_specs = specs();
        other_specs[1].name = "renamed".into();
        let mut b = ParamStore::init(&other_specs, 3);
        assert!(load_checkpoint(&path, &mut b).is_err());
    }

    #[test]
    fn worker_sibling_mapping() {
        let p = Path::new("/ck/run_step8.w0.ckpt");
        assert_eq!(worker_sibling(p, 1), PathBuf::from("/ck/run_step8.w1.ckpt"));
        assert_eq!(worker_sibling(p, 0), p);
        // Shared single file: every worker gets the same path.
        let shared = Path::new("/ck/run_step8.ckpt");
        assert_eq!(worker_sibling(shared, 3), shared);
        // A name whose ".w" is not a worker suffix stays untouched.
        let odd = Path::new("/ck/run.wfinal.ckpt");
        assert_eq!(worker_sibling(odd, 1), odd);
    }

    #[test]
    fn auto_resume_picks_newest_valid_set_and_skips_corrupt() {
        let dir = tmp_dir("auto");
        let a = ParamStore::init(&specs(), 1);
        let fp = 0xFEED_F00D;
        for (step, w) in [(2usize, 0usize), (2, 1), (4, 0), (4, 1)] {
            let st = state(step as u64, w as u32, 2);
            save_checkpoint_v2(&dir.join(periodic_checkpoint_name("run", step, w)), &a, &st)
                .unwrap();
        }
        let set = find_auto_resume(&dir, 2, fp).unwrap().expect("valid set");
        assert_eq!(set.step, 4);
        assert!(set.per_worker());
        assert_eq!(set.paths[1], dir.join("run_step4.w1.ckpt"));

        // Flip one payload byte in step 4's worker-1 file (length
        // unchanged): the streamed CRC check rejects the whole step-4
        // set and auto falls back to step 2 instead of selecting a
        // checkpoint that would fail at load time.
        let victim = dir.join("run_step4.w1.ckpt");
        let bytes = std::fs::read(&victim).unwrap();
        let mut rotted = bytes.clone();
        let mid = rotted.len() - 20; // inside the payload, before the CRC word
        rotted[mid] ^= 0x01;
        std::fs::write(&victim, &rotted).unwrap();
        let set = find_auto_resume(&dir, 2, fp).unwrap().expect("bit-rot fallback set");
        assert_eq!(set.step, 2);

        // Truncation is likewise rejected (declared-size check).
        std::fs::write(&victim, &bytes[..bytes.len() / 3]).unwrap();
        let set = find_auto_resume(&dir, 2, fp).unwrap().expect("fallback set");
        assert_eq!(set.step, 2);

        // A fingerprint mismatch (different run config) is also skipped.
        assert!(find_auto_resume(&dir, 2, 0xDEAD).unwrap().is_none());
        // Worker-count mismatch likewise.
        assert!(find_auto_resume(&dir, 3, fp).unwrap().is_none());
        // Empty/missing dir: no candidate, no error.
        assert!(find_auto_resume(Path::new("/nonexistent/ckpts"), 2, fp).unwrap().is_none());
    }

    #[test]
    fn explicit_resume_path_errors_loudly() {
        let dir = tmp_dir("explicit");
        let a = ParamStore::init(&specs(), 1);
        save_checkpoint_v2(
            &dir.join(periodic_checkpoint_name("run", 6, 0)),
            &a,
            &state(6, 0, 2),
        )
        .unwrap();
        // Worker 1's sibling is missing: explicit resume must fail.
        assert!(resume_set_from_path(&dir.join("run_step6.w0.ckpt"), 2).is_err());
        save_checkpoint_v2(
            &dir.join(periodic_checkpoint_name("run", 6, 1)),
            &a,
            &state(6, 1, 2),
        )
        .unwrap();
        let set = resume_set_from_path(&dir.join("run_step6.w0.ckpt"), 2).unwrap();
        assert_eq!(set.step, 6);
        // Pointing at the w1 file resolves the same set.
        let set = resume_set_from_path(&dir.join("run_step6.w1.ckpt"), 2).unwrap();
        assert_eq!(set.paths[0], dir.join("run_step6.w0.ckpt"));
    }

    #[test]
    fn markers_roundtrip_atomically() {
        let dir = tmp_dir("markers");
        assert!(read_marker(&dir, LATEST_MARKER).is_none());
        write_marker(&dir, LATEST_MARKER, "run_step4.w0.ckpt").unwrap();
        assert_eq!(read_marker(&dir, LATEST_MARKER).as_deref(), Some("run_step4.w0.ckpt"));
        write_marker(&dir, BEST_MARKER, "run_step2.w0.ckpt top1_error=0.5").unwrap();
        assert_eq!(read_marker(&dir, BEST_MARKER).as_deref(), Some("run_step2.w0.ckpt"));
        // The recorded error is recoverable (resume seeds best-so-far
        // from it so a worse post-resume eval can't displace the best).
        assert!((best_marker_error(&dir).unwrap() - 0.5).abs() < 1e-6);
        assert!(best_marker_error(&tmp_dir("markers_empty")).is_none());
    }

    #[test]
    fn pruning_keeps_newest_and_best() {
        let dir = tmp_dir("prune");
        let a = ParamStore::init(&specs(), 1);
        for step in [2usize, 4, 6, 8] {
            for w in 0..2usize {
                save_checkpoint_v2(
                    &dir.join(periodic_checkpoint_name("run", step, w)),
                    &a,
                    &state(step as u64, w as u32, 2),
                )
                .unwrap();
            }
        }
        write_marker(&dir, BEST_MARKER, "run_step2.w0.ckpt top1_error=0.4").unwrap();
        // keep=1: survivors are step 8 (current, possibly in-flight),
        // step 6 (the one guaranteed-complete older set) and step 2
        // (best-marked); step 4 is pruned for both workers.
        let removed = prune_checkpoints(&dir, "run", 2, 1, 8).unwrap();
        assert_eq!(removed, 2);
        assert!(!dir.join("run_step4.w0.ckpt").exists());
        assert!(!dir.join("run_step4.w1.ckpt").exists());
        for s in [2usize, 6, 8] {
            assert!(dir.join(periodic_checkpoint_name("run", s, 0)).exists(), "step {s}");
        }
        // keep=0 disables pruning entirely.
        assert_eq!(prune_checkpoints(&dir, "run", 2, 0, 8).unwrap(), 0);
    }
}
