//! `theano-mgpu` — a Rust + JAX + Pallas reproduction of
//! *"Theano-based Large-Scale Visual Recognition with Multiple GPUs"*
//! (Ding, Wang, Mao & Taylor, ICLR 2015 workshop).
//!
//! The paper's two coordination contributions — a parallel data-loading
//! pipeline (Fig 1) and naive 2-GPU data parallelism with per-step
//! parameter/momentum exchange-and-average (Fig 2) — are implemented as
//! a Rust coordinator (L3) over AOT-compiled JAX/Pallas train steps
//! (L2/L1) executed through PJRT.  Python never runs on the training
//! path: `make artifacts` lowers the model once to HLO text, and this
//! crate loads, compiles and drives it.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`util`], [`tensor`], [`config`], [`metrics`] — substrates.
//! - [`data`] — synthetic ImageNet-like corpus, shard files,
//!   preprocessing and the double-buffered prefetch loader (Fig 1).
//! - [`backend`] — the [`StepBackend`](backend::StepBackend) trait and
//!   its two substrates: the pure-Rust native CPU path (im2col +
//!   blocked SGEMM AlexNet, no artifacts needed) and the AOT-XLA path.
//! - [`runtime`] — PJRT client/executable wrappers + artifact manifest.
//! - [`params`] — parameter store, host init, averaging, checkpoints.
//! - [`comm`] — transports (P2P / host-staged / serialized), the
//!   N-worker [`Collective`](comm::Collective) fabric (no-op / pairwise
//!   Fig-2 / chunked ring all-reduce) and barriers.
//! - [`interconnect`] — PCIe topology model (same-switch P2P rule).
//! - [`coordinator`] — worker threads + the training/eval loops.
//! - [`serve`] — the dynamic-batching inference server behind
//!   `tmg serve` (request queue, replica pool, TCP line protocol).
//! - [`sim`] — calibrated discrete-event simulator regenerating the
//!   paper's Table 1 and the N-GPU scaling study.
//! - [`cli`] — the `tmg` command line.
//! - [`testing`] — in-repo property-testing mini-framework.

pub mod backend;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod interconnect;
pub mod metrics;
pub mod params;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tensor;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
