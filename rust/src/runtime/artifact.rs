//! `artifacts/manifest.json` schema (see python/compile/aot.py).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::{DType, Shape};
use crate::util::Json;

/// One named input or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Shape,
}

impl IoSpec {
    fn from_json(v: &Json) -> Result<IoSpec> {
        let name = v.str_field("name")?.to_string();
        let dtype_s = v.str_field("dtype")?;
        let dtype = DType::parse(dtype_s)
            .ok_or_else(|| Error::Manifest(format!("unknown dtype {dtype_s:?}")))?;
        let shape = Shape(
            v.arr_field("shape")?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::Manifest("non-integer shape dim".into()))
                })
                .collect::<Result<Vec<_>>>()?,
        );
        Ok(IoSpec { name, dtype, shape })
    }

    pub fn byte_size(&self) -> usize {
        self.shape.numel() * self.dtype.size_bytes()
    }
}

/// Parameter init recipe from the model entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamManifestSpec {
    pub name: String,
    pub shape: Shape,
    pub init: String,
    pub std: f32,
    pub bias_value: f32,
}

/// Model metadata (shared across that model's artifacts).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub image_hw: usize,
    pub in_channels: usize,
    pub num_classes: usize,
    pub params: Vec<ParamManifestSpec>,
}

impl ModelSpec {
    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.shape.numel()).sum()
    }
}

/// Artifact kind tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Train,
    Eval,
}

/// One compiled-step artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub model: String,
    pub backend: String,
    pub batch_size: usize,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| Error::io(&path, e))?;
        Self::parse(dir, &src)
    }

    pub fn parse(dir: &Path, src: &str) -> Result<Manifest> {
        let v = Json::parse(src)?;
        let version = v.num_field("version")? as u64;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported manifest version {version}")));
        }

        let mut models = Vec::new();
        for (name, m) in v
            .field("models")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("models is not an object".into()))?
        {
            let mut params = Vec::new();
            for p in m.arr_field("params")? {
                params.push(ParamManifestSpec {
                    name: p.str_field("name")?.to_string(),
                    shape: Shape(
                        p.arr_field("shape")?
                            .iter()
                            .map(|d| {
                                d.as_usize().ok_or_else(|| {
                                    Error::Manifest("non-integer param dim".into())
                                })
                            })
                            .collect::<Result<Vec<_>>>()?,
                    ),
                    init: p.str_field("init")?.to_string(),
                    std: p.num_field("std")? as f32,
                    bias_value: p.num_field("bias_value")? as f32,
                });
            }
            models.push(ModelSpec {
                name: name.clone(),
                image_hw: m.num_field("image_hw")? as usize,
                in_channels: m.num_field("in_channels")? as usize,
                num_classes: m.num_field("num_classes")? as usize,
                params,
            });
        }

        let mut artifacts = Vec::new();
        for a in v.arr_field("artifacts")? {
            let kind = match a.str_field("kind")? {
                "train" => ArtifactKind::Train,
                "eval" => ArtifactKind::Eval,
                other => return Err(Error::Manifest(format!("unknown kind {other:?}"))),
            };
            artifacts.push(ArtifactSpec {
                name: a.str_field("name")?.to_string(),
                kind,
                model: a.str_field("model")?.to_string(),
                backend: a.str_field("backend")?.to_string(),
                batch_size: a.num_field("batch_size")? as usize,
                file: dir.join(a.str_field("file")?),
                inputs: a
                    .arr_field("inputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .arr_field("outputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }

        let man = Manifest { dir: dir.to_path_buf(), models, artifacts };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        for a in &self.artifacts {
            let model = self.model(&a.model)?;
            // Train artifacts carry params + momenta after the 4 data
            // inputs and after the 2 scalar outputs.
            if a.kind == ArtifactKind::Train {
                let p = model.param_count();
                if a.inputs.len() != 4 + 2 * p {
                    return Err(Error::Manifest(format!(
                        "{}: expected {} inputs, manifest lists {}",
                        a.name,
                        4 + 2 * p,
                        a.inputs.len()
                    )));
                }
                if a.outputs.len() != 2 + 2 * p {
                    return Err(Error::Manifest(format!(
                        "{}: expected {} outputs, manifest lists {}",
                        a.name,
                        2 + 2 * p,
                        a.outputs.len()
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| Error::Manifest(format!("model {name:?} not in manifest")))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                let available: Vec<_> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
                Error::Manifest(format!(
                    "artifact {name:?} not found; available: {available:?} \
                     (run `make artifacts`?)"
                ))
            })
    }

    /// Find the eval artifact for a model, if present.
    pub fn eval_artifact_for(&self, model: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Eval && a.model == model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1,
      "models": {
        "m": {"image_hw": 8, "in_channels": 1, "num_classes": 2,
              "params": [{"name": "w", "shape": [2, 2], "init": "normal",
                          "std": 0.01, "bias_value": 0.0}]}
      },
      "artifacts": [
        {"name": "train_m_ref_b2", "kind": "train", "model": "m",
         "backend": "ref", "batch_size": 2, "file": "t.hlo.txt",
         "inputs": [
            {"name": "images", "dtype": "float32", "shape": [2,1,8,8]},
            {"name": "labels", "dtype": "int32", "shape": [2]},
            {"name": "lr", "dtype": "float32", "shape": []},
            {"name": "seed", "dtype": "int32", "shape": []},
            {"name": "w", "dtype": "float32", "shape": [2,2]},
            {"name": "w.m", "dtype": "float32", "shape": [2,2]}],
         "outputs": [
            {"name": "loss", "dtype": "float32", "shape": []},
            {"name": "correct1", "dtype": "int32", "shape": []},
            {"name": "w", "dtype": "float32", "shape": [2,2]},
            {"name": "w.m", "dtype": "float32", "shape": [2,2]}]}
      ]
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), MINI).unwrap();
        assert_eq!(m.models.len(), 1);
        let a = m.artifact("train_m_ref_b2").unwrap();
        assert_eq!(a.batch_size, 2);
        assert_eq!(a.inputs[0].shape.dims(), &[2, 1, 8, 8]);
        assert_eq!(a.inputs[0].byte_size(), 2 * 64 * 4);
        assert_eq!(m.model("m").unwrap().total_param_elements(), 4);
        assert!(m.artifact("zzz").is_err());
        assert!(m.eval_artifact_for("m").is_none());
    }

    #[test]
    fn rejects_wrong_io_count() {
        let bad = MINI.replace(
            r#"{"name": "w.m", "dtype": "float32", "shape": [2,2]}],
         "outputs""#,
            r#"],
         "outputs""#,
        );
        // Removing an input breaks the 4+2P invariant.
        assert!(Manifest::parse(Path::new("/tmp/a"), &bad).is_err());
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            let a = m.artifact("train_alexnet-micro_refconv_b8").unwrap();
            assert_eq!(a.batch_size, 8);
            let model = m.model("alexnet-micro").unwrap();
            assert_eq!(a.inputs.len(), 4 + 2 * model.param_count());
        }
    }
}
