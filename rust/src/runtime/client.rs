//! PJRT client wrapper — one per worker thread.
//!
//! The paper pinned one Theano process per GPU; here each worker thread
//! owns a `RuntimeClient` (PJRT CPU client) and compiles its own
//! executables from the shared HLO text.  Clients are intentionally
//! *not* shared across threads (the underlying handles are raw C++
//! pointers with no Sync guarantee).

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactSpec;
use crate::runtime::executable::StepExecutable;

/// A PJRT client plus compile entry points.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU PJRT client (the "virtual GPU" substrate).
    pub fn cpu() -> Result<Self> {
        Ok(RuntimeClient { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            return Err(Error::msg(format!(
                "HLO artifact {path:?} missing — run `make artifacts`, \
                 or train with `--backend native` (pure-Rust CPU step, \
                 no artifacts needed)"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::msg(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| {
            Error::Xla(format!(
                "{e}; the XLA path needs linked PJRT bindings — \
                 `--backend native` runs the pure-Rust CPU step instead"
            ))
        })
    }

    /// Load + compile one manifest artifact into a step executable.
    pub fn load_step(&self, spec: &ArtifactSpec) -> Result<StepExecutable> {
        let exe = self.compile_hlo_file(&spec.file)?;
        Ok(StepExecutable::new(exe, spec.clone()))
    }
}
