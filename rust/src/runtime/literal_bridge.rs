//! Host tensor <-> `xla::Literal` bridging.

use crate::error::{Error, Result};
use crate::runtime::artifact::IoSpec;
use crate::tensor::{DType, HostTensor, Shape};

/// Build an f32 literal from a host tensor.
pub fn tensor_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.as_slice());
    if t.shape().rank() == 0 {
        // vec1 of a single element reshaped to scalar.
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&t.shape().dims_i64())?)
    }
}

/// Build an i32 vector literal (labels).
pub fn i32_to_literal(v: &[i32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(v))
}

/// Scalar literals.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Pull an f32 literal back into a host tensor with the given shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: Shape) -> Result<HostTensor> {
    let v = lit.to_vec::<f32>()?;
    HostTensor::from_vec(shape, v)
}

/// Read a scalar from a literal.
pub fn literal_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

pub fn literal_i32(lit: &xla::Literal) -> Result<i32> {
    Ok(lit.get_first_element::<i32>()?)
}

/// Check a literal's element count against an IoSpec (cheap sanity
/// check on every step output in debug builds, on load in release).
pub fn check_against_spec(lit: &xla::Literal, spec: &IoSpec) -> Result<()> {
    let n = lit.element_count();
    if n != spec.shape.numel() {
        return Err(Error::Shape(format!(
            "output {:?}: literal has {n} elements, spec {} wants {}",
            spec.name,
            spec.shape,
            spec.shape.numel()
        )));
    }
    let ty = lit.ty()?;
    let ok = matches!(
        (ty, spec.dtype),
        (xla::ElementType::F32, DType::F32)
            | (xla::ElementType::S32, DType::I32)
            | (xla::ElementType::U8, DType::U8)
    );
    if !ok {
        return Err(Error::Shape(format!(
            "output {:?}: literal type {ty:?} vs spec {:?}",
            spec.name, spec.dtype
        )));
    }
    Ok(())
}
