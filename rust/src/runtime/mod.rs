//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the Theano-compiled-function analog.  `make artifacts` emits
//! `artifacts/*.hlo.txt` + `manifest.json` once; at run time each
//! worker thread builds a [`RuntimeClient`] (PJRT CPU client), loads
//! its train/eval [`StepExecutable`]s and drives them with literals
//! bridged from host tensors.  Python never runs here.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py and /opt/xla-example/README.md).

pub mod artifact;
pub mod client;
pub mod executable;
pub mod literal_bridge;

pub use artifact::{ArtifactSpec, IoSpec, Manifest, ModelSpec, ParamManifestSpec};
pub use client::RuntimeClient;
pub use executable::StepExecutable;
