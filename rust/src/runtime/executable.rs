//! A compiled step function with its manifest ABI.
//!
//! `run` takes literals in manifest input order and returns the
//! decomposed output tuple in manifest output order.  All jax modules
//! are lowered with `return_tuple=True`, so the executable produces a
//! single tuple buffer; we sync it to host and decompose — on the CPU
//! PJRT backend "device" memory is host memory, so this is the same
//! memcpy the paper's host<->GPU staging performed (and it is what the
//! calibration pass measures as the step cost).

use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactSpec;
use crate::runtime::literal_bridge::check_against_spec;

/// A loaded + compiled artifact.
pub struct StepExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl StepExecutable {
    pub fn new(exe: xla::PjRtLoadedExecutable, spec: ArtifactSpec) -> Self {
        StepExecutable { exe, spec }
    }

    /// Execute with literals in manifest input order.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{}: got {} inputs, ABI wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Xla("execute returned no outputs".into()))?;
        let tuple = buf.to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{}: executable returned {} outputs, ABI wants {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            )));
        }
        for (lit, spec) in outs.iter().zip(&self.spec.outputs) {
            check_against_spec(lit, spec)?;
        }
        Ok(outs)
    }
}
