//! Host-side stand-in for the `xla` (PJRT) bindings crate.
//!
//! The offline container carries no XLA/PJRT shared libraries, so this
//! vendored crate supplies the API surface the workspace compiles
//! against:
//!
//! - [`Literal`] is a *fully functional* host container (typed buffer +
//!   dims) — the literal bridge, parameter staging and all tests that
//!   traffic in literals work unchanged;
//! - [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] report
//!   [`Error::Unimplemented`]: executing AOT HLO requires the real
//!   bindings.  Callers already treat that exactly like missing
//!   artifacts (skip/fallback), so trainer-level tests degrade cleanly.
//!
//! Swapping the real bindings back in is a one-line Cargo.toml change;
//! no call site needs to move.

use std::fmt;

/// Error type mirroring the bindings' error surface.
#[derive(Debug)]
pub enum Error {
    Msg(String),
    Unimplemented(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Msg(m) => write!(f, "{m}"),
            Error::Unimplemented(what) => write!(
                f,
                "{what} is unavailable in this offline build (PJRT bindings not linked)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element type tags (the subset the manifest ABI uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

/// Typed storage behind a literal.
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
    Tuple(Vec<Literal>),
}

/// Rust scalar types that can back a literal.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn slice(data: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn slice(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn slice(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::U8(v)
    }
    fn slice(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::U8(v) => Some(v),
            _ => None,
        }
    }
}

/// A host literal: typed dense buffer plus dims (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::wrap(vec![v]) }
    }

    /// Tuple literal (what a PJRT tuple output decomposes from).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: LiteralData::Tuple(elems) }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::Msg("cannot reshape a tuple literal".into()));
        }
        if want < 0 || want as usize != self.element_count() {
            return Err(Error::Msg(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U8(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            LiteralData::F32(_) => Ok(ElementType::F32),
            LiteralData::I32(_) => Ok(ElementType::S32),
            LiteralData::U8(_) => Ok(ElementType::U8),
            LiteralData::Tuple(_) => Err(Error::Msg("tuple literal has no element type".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.data).map(|s| s.to_vec()).ok_or_else(|| {
            Error::Msg(format!("literal is not of the requested type {:?}", T::TY))
        })
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::slice(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error::Msg("literal empty or of the wrong type".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(v) => Ok(v),
            _ => Err(Error::Msg("literal is not a tuple".into())),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed (here: raw) HLO module text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Msg(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation handle wrapping a module proto.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// PJRT client stand-in.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { platform: "offline-stub-cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unimplemented("HLO compilation"))
    }
}

/// Compiled-executable stand-in (unreachable through the stub client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("HLO execution"))
    }
}

/// Device buffer stand-in.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        assert_eq!(s.reshape(&[]).unwrap().element_count(), 1);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn stub_client_reports_unimplemented() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "offline-stub-cpu");
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }
}
