//! Minimal in-repo implementation of the `log` facade.
//!
//! The offline crate set has no crates.io access, so this vendored
//! crate provides the subset of the real `log` API this workspace
//! uses: the `Log` trait, `set_logger`/`set_max_level`, and the five
//! level macros.  Semantics match the real facade: records are
//! dropped until a logger is installed, and levels above the max are
//! filtered before reaching the logger.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a record (level only in this implementation).
#[derive(Clone, Copy, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: metadata + preformatted arguments.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the maximum level that reaches the logger.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: filter, then dispatch to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments<'_>) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get().copied() {
        let record = Record { metadata: Metadata { level }, args };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Error, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Warn, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Info, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Debug, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Trace, format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_display() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
        assert_eq!(Level::Info.as_str(), "INFO");
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn logging_without_logger_is_a_noop() {
        set_max_level(LevelFilter::Trace);
        info!("no logger installed: {}", 42);
        set_max_level(LevelFilter::Off);
    }
}
