//! Property-based tests on coordinator invariants, via the in-repo
//! mini-proptest framework (`theano_mgpu::testing`).

use theano_mgpu::comm::collective::{ring_fabric, Collective};
use theano_mgpu::comm::link::transport_pair;
use theano_mgpu::config::TransportKind;
use theano_mgpu::data::sampler::EpochSampler;
use theano_mgpu::interconnect::routing::route;
use theano_mgpu::interconnect::topology::TopologyBuilder;
use theano_mgpu::params::average::{average_pair, average_weighted};
use theano_mgpu::params::ParamStore;
use theano_mgpu::runtime::artifact::ParamManifestSpec;
use theano_mgpu::tensor::Shape;
use theano_mgpu::testing::{props, props_err, Gen};
use theano_mgpu::util::{Json, Pcg32};

fn random_specs(g: &mut Gen) -> Vec<ParamManifestSpec> {
    let n = g.usize_in(1, 5);
    (0..n)
        .map(|i| ParamManifestSpec {
            name: format!("t{i}"),
            shape: Shape(g.shape(3, 128)),
            init: if g.bool() { "normal".into() } else { "zeros".into() },
            std: g.f32_in(0.01, 0.5),
            bias_value: 0.0,
        })
        .collect()
}

#[test]
fn prop_average_pair_is_symmetric_and_idempotent() {
    props("average symmetry", 200, |g| {
        let n = g.usize_in(1, 64);
        let a0 = g.vec_f32(n, -10.0, 10.0);
        let b0 = g.vec_f32(n, -10.0, 10.0);
        // Symmetric averaging: both orders give the midpoint.
        let mut a = a0.clone();
        average_pair(&mut a, &b0);
        let mut b = b0.clone();
        average_pair(&mut b, &a0);
        let sym = a.iter().zip(&b).all(|(x, y)| (x - y).abs() < 1e-5);
        // Averaging with itself is identity.
        let mut c = a0.clone();
        let c0 = a0.clone();
        average_pair(&mut c, &c0);
        let idem = c.iter().zip(&a0).all(|(x, y)| (x - y).abs() < 1e-6);
        sym && idem
    });
}

#[test]
fn prop_weighted_average_preserves_sum_weights_one() {
    props("weighted average convexity", 200, |g| {
        let n = g.usize_in(1, 32);
        let a0 = g.vec_f32(n, -5.0, 5.0);
        let b0 = g.vec_f32(n, -5.0, 5.0);
        let w = g.f32_in(0.0, 1.0);
        let mut a = a0.clone();
        average_weighted(&mut a, w, &b0, 1.0 - w);
        // Result bounded by min/max of the pair per element.
        a.iter().zip(a0.iter().zip(&b0)).all(|(r, (x, y))| {
            let lo = x.min(*y) - 1e-5;
            let hi = x.max(*y) + 1e-5;
            (lo..=hi).contains(r)
        })
    });
}

#[test]
fn prop_store_flatten_average_equals_tensorwise() {
    props_err("flatten/average equivalence", 60, |g| {
        let specs = random_specs(g);
        let mut a = ParamStore::init(&specs, g.rng().next_u64());
        let mut b = ParamStore::init(&specs, g.rng().next_u64());
        // Tensor-wise expected result.
        let mut expect = a.clone();
        for (t, u) in expect.params.iter_mut().zip(&b.params) {
            t.average_with(u).map_err(|e| e.to_string())?;
        }
        for (t, u) in expect.momenta.iter_mut().zip(&b.momenta) {
            t.average_with(u).map_err(|e| e.to_string())?;
        }
        // Flat exchange path.
        let fb = b.flatten(true);
        let fa = a.flatten(true);
        a.average_with_flat(&fb, true).map_err(|e| e.to_string())?;
        b.average_with_flat(&fa, true).map_err(|e| e.to_string())?;
        if a.max_divergence(&expect) > 1e-6 {
            return Err(format!("flat != tensorwise ({})", a.max_divergence(&expect)));
        }
        if a.max_divergence(&b) > 1e-6 {
            return Err("asymmetric result".into());
        }
        Ok(())
    });
}

#[test]
fn prop_exchange_seq_numbers_enforced() {
    props("seq skew detection", 50, |g| {
        let (mut a, mut b) = transport_pair(*g.pick(&[
            TransportKind::P2p,
            TransportKind::HostStaged,
            TransportKind::Serialized,
        ]));
        let n = g.usize_in(1, 64);
        let payload = g.vec_f32(n, -1.0, 1.0);
        let seq = g.rng().next_u64() % 1000;
        a.send(seq, &payload).unwrap();
        let mut out = Vec::new();
        let skewed = seq + 1 + g.rng().next_u64() % 5;
        b.recv(skewed, &mut out).is_err()
    });
}

#[test]
fn prop_transport_roundtrip_exact() {
    props("transport bit-exactness", 60, |g| {
        let kind = *g.pick(&[
            TransportKind::P2p,
            TransportKind::HostStaged,
            TransportKind::Serialized,
        ]);
        let (mut a, mut b) = transport_pair(kind);
        let n = g.usize_in(0, 512);
        // Include extreme values: serialization must be bit-exact.
        let mut payload = g.vec_f32(n, -1e30, 1e30);
        if n > 0 {
            payload[0] = f32::MIN_POSITIVE;
        }
        a.send(0, &payload).unwrap();
        let mut out = Vec::new();
        b.recv(0, &mut out).unwrap();
        out.iter().zip(&payload).all(|(x, y)| x.to_bits() == y.to_bits())
            && out.len() == payload.len()
    });
}

#[test]
fn prop_ring_average_equals_arithmetic_mean() {
    props_err("ring == mean", 12, |g| {
        let n = g.usize_in(2, 6);
        let len = g.usize_in(1, 200);
        let kind = *g.pick(&[
            TransportKind::P2p,
            TransportKind::HostStaged,
            TransportKind::Serialized,
        ]);
        let values: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, -100.0, 100.0)).collect();
        let mut expect = vec![0f32; len];
        for v in &values {
            for (e, x) in expect.iter_mut().zip(v) {
                *e += x / n as f32;
            }
        }
        let spec = ParamManifestSpec {
            name: "w".into(),
            shape: Shape(vec![len]),
            init: "zeros".into(),
            std: 0.0,
            bias_value: 0.0,
        };
        let joins: Vec<_> = ring_fabric(&vec![kind; n])
            .into_iter()
            .zip(values)
            .map(|(mut node, data)| {
                let spec = spec.clone();
                std::thread::spawn(move || {
                    let mut store = ParamStore::init(&[spec], 0);
                    store.params[0].as_mut_slice().copy_from_slice(&data);
                    node.all_reduce_average(&mut store, false).unwrap();
                    store.params[0].as_slice().to_vec()
                })
            })
            .collect();
        for j in joins {
            let got = j.join().unwrap();
            for (a, b) in got.iter().zip(&expect) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("ring {a} vs mean {b}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sampler_partitions_every_epoch() {
    props_err("sampler partition", 40, |g| {
        let workers = g.usize_in(1, 4);
        let batch = g.usize_in(1, 8);
        let batches_per_epoch = g.usize_in(workers.max(2), 12);
        let n = batch * batches_per_epoch;
        let seed = g.rng().next_u64();
        let mut samplers: Vec<_> = (0..workers)
            .map(|w| EpochSampler::new(n, batch, w, workers, seed))
            .collect();
        let rounds = batches_per_epoch / workers;
        let mut seen = std::collections::HashSet::new();
        let mut buf = Vec::new();
        for _ in 0..rounds {
            for s in samplers.iter_mut() {
                s.next_batch_indices(&mut buf);
                for &i in &buf {
                    if !seen.insert(i) {
                        return Err(format!("index {i} served twice in one epoch"));
                    }
                }
            }
        }
        let expect = rounds * workers * batch;
        if seen.len() != expect {
            return Err(format!("coverage {} != {expect}", seen.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_topology_routing_consistent() {
    props_err("routing consistency", 60, |g| {
        let s1 = g.usize_in(1, 4);
        let s2 = g.usize_in(0, 4);
        let mut builder = TopologyBuilder::new().switch_with(s1);
        if s2 > 0 {
            builder = builder.switch_with(s2);
        }
        let topo = builder.build().map_err(|e| e.to_string())?;
        let n = topo.devices();
        for a in 0..n {
            for b in 0..n {
                let r = route(&topo, a, b).map_err(|e| e.to_string())?;
                let same = topo.p2p_allowed(a, b).map_err(|e| e.to_string())?;
                let want = if same { TransportKind::P2p } else { TransportKind::HostStaged };
                if r.transport != want {
                    return Err(format!("({a},{b}): {:?} vs {:?}", r.transport, want));
                }
                // Symmetry.
                let rb = route(&topo, b, a).map_err(|e| e.to_string())?;
                if rb.transport != r.transport || rb.hops != r.hops {
                    return Err("asymmetric route".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    props("json number roundtrip", 300, |g| {
        let v = (g.rng().next_u32() as f64) * if g.bool() { -1.0 } else { 1.0 }
            / (1 + g.rng().next_u32() % 1000) as f64;
        let src = format!("{v:?}");
        match Json::parse(&src) {
            Ok(Json::Num(got)) => (got - v).abs() <= 1e-9 * v.abs().max(1.0),
            _ => false,
        }
    });
}

#[test]
fn prop_prng_below_bound() {
    props("pcg below in range", 500, |g| {
        let bound = 1 + g.rng().next_u32() % 10_000;
        let mut rng = Pcg32::seeded(g.rng().next_u64());
        rng.below(bound) < bound
    });
}
