//! Serve-level integration tests: dynamic batching (deadline AND
//! max-batch flush), bit-identical answers vs the eval path on the
//! same parameters, and graceful shutdown draining in-flight requests.
//!
//! Everything runs in-process on the native backend over an ephemeral
//! 127.0.0.1 port — no artifacts, no fixed port collisions.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use theano_mgpu::config::{DataConfig, TrainConfig};
use theano_mgpu::coordinator::eval::{evaluate, Engine};
use theano_mgpu::data::loader::open_split;
use theano_mgpu::params::ParamStore;
use theano_mgpu::serve::loadgen::ServeClient;
use theano_mgpu::serve::{hex_encode, ServeOpts, Server};

const VAL: usize = 24;

fn corpus(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_serve_{tag}_{}", std::process::id()));
    if !dir.join("meta.json").exists() {
        let spec =
            theano_mgpu::data::synth::SynthSpec { classes: 10, hw: 36, seed: 9, ..Default::default() };
        theano_mgpu::data::synth::generate_dataset(&dir, &spec, 64, VAL, 64).unwrap();
    }
    dir
}

fn serve_cfg(tag: &str) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.compute_threads = 1;
    cfg.batch_per_worker = 8;
    cfg.data = DataConfig {
        dir: corpus(tag),
        train_examples: 64,
        val_examples: VAL,
        shard_examples: 64,
        seed: 9,
        stored_hw: 36,
    };
    cfg
}

fn start(tag: &str, seed: u64, opts: ServeOpts) -> (TrainConfig, Arc<ParamStore>, Server) {
    let cfg = serve_cfg(tag);
    let model = theano_mgpu::backend::resolve_model(&cfg).unwrap();
    let store = Arc::new(ParamStore::init(&model.params, seed));
    let server = Server::start(&cfg, store.clone(), opts).unwrap();
    (cfg, store, server)
}

/// Raw stored-size pixels + label of one val example.
fn val_examples(cfg: &TrainConfig) -> Vec<(Vec<u8>, i32)> {
    let (mut dataset, _mean) = open_split(&cfg.data.dir, "val", 32, false).unwrap();
    let mut out = Vec::new();
    let mut buf = Vec::new();
    for i in 0..dataset.len() {
        let label = dataset.read_into(i, &mut buf).unwrap();
        out.push((buf.clone(), label as i32));
    }
    out
}

fn parse_topk(reply: &str) -> Vec<(usize, f32)> {
    assert!(reply.starts_with("ok "), "bad reply: {reply}");
    reply
        .split_whitespace()
        .skip(1)
        .map(|kv| {
            let (c, p) = kv.split_once(':').expect("class:prob");
            (c.parse::<usize>().unwrap(), p.parse::<f32>().unwrap())
        })
        .collect()
}

#[test]
fn lone_request_flushes_on_deadline() {
    let opts = ServeOpts {
        replicas: 1,
        max_batch: 8,
        deadline: Duration::from_millis(40),
        ..ServeOpts::default()
    };
    let (cfg, _store, server) = start("deadline", 1, opts);
    let addr = server.addr().to_string();
    let mut client = ServeClient::connect(&addr, Duration::from_secs(10)).unwrap();
    let info = client.hello().unwrap();
    assert_eq!(info.hw, 36);
    assert_eq!(info.classes, 10);
    let (pixels, _) = val_examples(&cfg).remove(0);
    let t = Instant::now();
    let reply = client.request(&format!("classify {}", hex_encode(&pixels))).unwrap();
    let elapsed = t.elapsed();
    assert!(reply.starts_with("ok "), "{reply}");
    // One lone request against max_batch 8: only the deadline can have
    // released it, and not before it aged.
    assert!(elapsed >= Duration::from_millis(20), "answered at {elapsed:?} — before deadline");
    let stats = client.request("stats").unwrap();
    assert!(stats.contains("served=1"), "{stats}");
    assert!(stats.contains("batches=1"), "{stats}");
    assert!(stats.contains("queue_p50_ms="), "{stats}");
    let snap = server.shutdown();
    assert_eq!((snap.served, snap.batches, snap.errors), (1, 1, 0));
}

#[test]
fn concurrent_requests_flush_on_max_batch() {
    // Deadline an hour away: the only way these four requests get
    // answered promptly is the size flush forming one batch of 4.
    let opts = ServeOpts {
        replicas: 1,
        max_batch: 4,
        deadline: Duration::from_secs(3600),
        ..ServeOpts::default()
    };
    let (cfg, _store, server) = start("maxbatch", 1, opts);
    let addr = server.addr().to_string();
    let examples = val_examples(&cfg);
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            let payload = hex_encode(&examples[i].0);
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(&addr, Duration::from_secs(10)).unwrap();
                c.request(&format!("classify {payload}")).unwrap()
            })
        })
        .collect();
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.starts_with("ok "), "{reply}");
    }
    assert_eq!(server.stats().size_counts()[4], 1, "one batch of exactly 4");
    let snap = server.shutdown();
    assert_eq!((snap.served, snap.batches, snap.errors), (4, 1, 0));
    assert!((snap.mean_fill - 4.0).abs() < 1e-9);
}

#[test]
fn replies_bit_identical_to_eval_path() {
    // Same parameters, two routes: (a) the evaluator walking the val
    // split in fixed batches of 8, (b) the server answering per-request
    // with dynamically formed batches.  Top-1/top-5 agreement must be
    // exact, and each wire probability must parse back to the very bits
    // the local Engine computes.
    let opts = ServeOpts {
        replicas: 2,
        max_batch: 4,
        deadline: Duration::from_millis(2),
        topk: 5,
        port: 0,
        ..ServeOpts::default()
    };
    let (cfg, store, server) = start("bitident", 33, opts);
    let addr = server.addr().to_string();
    let examples = val_examples(&cfg);

    // (a) the eval path.
    let mut backend = theano_mgpu::backend::build_eval_backend(&cfg).unwrap();
    let eval = evaluate(&cfg, backend.as_mut(), &store, 0).unwrap().expect("val present");
    assert_eq!(eval.examples, VAL);

    // Local reference predictions through the same Engine the replicas
    // use, whole split staged as one batch.
    let (dataset, mean) = open_split(&cfg.data.dir, "val", 32, false).unwrap();
    let stored_hw = dataset.height;
    let mut engine = Engine::new(backend.as_mut(), mean, stored_hw).unwrap();
    engine.begin(examples.len());
    for (bi, (pixels, _)) in examples.iter().enumerate() {
        engine.stage(bi, pixels).unwrap();
    }
    let local = engine.classify_staged(&store, 5).unwrap();

    // (b) the serve path, one request per example over one connection;
    // concurrent deadline flushes on the two replicas form small ragged
    // batches.
    let mut client = ServeClient::connect(&addr, Duration::from_secs(10)).unwrap();
    let (mut top1, mut top5) = (0usize, 0usize);
    for (i, (pixels, label)) in examples.iter().enumerate() {
        let reply = client.request(&format!("classify {}", hex_encode(pixels))).unwrap();
        let served = parse_topk(&reply);
        assert_eq!(served.len(), 5);
        if served[0].0 == *label as usize {
            top1 += 1;
        }
        if served.iter().any(|&(c, _)| c == *label as usize) {
            top5 += 1;
        }
        // Bit-exact agreement with the local engine, example by
        // example: same classes, same float bits after the wire
        // round-trip (f32 Display prints shortest-roundtrip decimals).
        let want: Vec<(usize, u32)> = local[i].iter().map(|&(c, p)| (c, p.to_bits())).collect();
        let got: Vec<(usize, u32)> = served.iter().map(|&(c, p)| (c, p.to_bits())).collect();
        assert_eq!(got, want, "example {i}");
    }
    assert_eq!(top1, eval.top1_correct, "serve top-1 diverged from tmg eval");
    assert_eq!(top5, eval.top5_correct, "serve top-5 diverged from tmg eval");
    let snap = server.shutdown();
    assert_eq!(snap.served, VAL as u64);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches >= 1);
}

#[test]
fn shutdown_drains_in_flight_requests() {
    // Six requests parked behind an hour-long deadline and an
    // unreachable max batch: shutdown must flush and answer all of
    // them — drain, not drop.
    let opts = ServeOpts {
        replicas: 1,
        max_batch: 64,
        deadline: Duration::from_secs(3600),
        ..ServeOpts::default()
    };
    let (cfg, _store, server) = start("drain", 1, opts);
    let addr = server.addr().to_string();
    let examples = val_examples(&cfg);
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let addr = addr.clone();
            let payload = hex_encode(&examples[i].0);
            std::thread::spawn(move || {
                let mut c = ServeClient::connect(&addr, Duration::from_secs(10)).unwrap();
                c.request(&format!("classify {payload}")).unwrap()
            })
        })
        .collect();
    // Wait until all six are actually queued (not merely connected)
    // before pulling the plug.
    let t = Instant::now();
    while server.queue_depth() < 6 {
        assert!(t.elapsed() < Duration::from_secs(30), "requests never queued");
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = server.shutdown();
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.starts_with("ok "), "in-flight request dropped: {reply}");
    }
    assert_eq!((snap.served, snap.batches, snap.errors), (6, 1, 0));
}

#[test]
fn idle_client_is_evicted_with_an_err_reply() {
    use std::io::{BufRead, BufReader};
    use std::net::TcpStream;

    let opts = ServeOpts {
        replicas: 1,
        idle_timeout: Duration::from_millis(300),
        ..ServeOpts::default()
    };
    let (_cfg, _store, server) = start("idle", 1, opts);
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // Send nothing: the server must say why it is hanging up, then
    // actually hang up — not keep the handler thread alive forever.
    let t = Instant::now();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("err idle"), "expected idle eviction, got: {line:?}");
    assert!(t.elapsed() >= Duration::from_millis(250), "evicted before the idle budget");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must be closed");
    drop(stream);
    server.shutdown();
}

#[test]
fn malformed_lines_get_err_replies_not_silent_drops() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let (_cfg, _store, server) = start("malformed", 1, ServeOpts::default());
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // A non-UTF-8 request line is answered, not silently dropped.
    stream.write_all(b"classify \xff\xfe\xfa\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("err request is not valid utf-8"), "{line:?}");

    // Bad hex in an otherwise well-formed line: still an err reply.
    line.clear();
    stream.write_all(b"classify zz\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("err "), "{line:?}");

    // The connection survives malformed requests and keeps serving.
    line.clear();
    stream.write_all(b"stats\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok "), "{line:?}");
    server.shutdown();
}
