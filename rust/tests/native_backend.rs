//! Native-backend correctness: finite-difference gradient checks for
//! the conv / FC / softmax layers, and the three-way parameter-shape
//! cross-check (analytic `ArchDesc` counts vs the derived `ModelSpec`
//! manifest vs a materialized `ParamStore`) for the whole AlexNet
//! family.
//!
//! The gradient checks probe every element with central differences
//! (`eps` scaled to the operand) and require rel-err < 1e-2, the
//! acceptance bar for f32 kernels.

use theano_mgpu::backend::native::gemm::{matmul_nn, matmul_nt, matmul_tn, scalar};
use theano_mgpu::backend::native::layers::{
    conv2d_backward, conv2d_forward, fc_backward, fc_forward, lrn_backward, lrn_forward,
    softmax_xent, Conv2dShape, FcShape, LrnShape,
};
use theano_mgpu::backend::native::model::model_spec_of;
use theano_mgpu::params::ParamStore;
use theano_mgpu::sim::flops::{alexnet, alexnet_micro, alexnet_tiny, alexnet_tiny_faithful};
use theano_mgpu::util::math::{rel_err, transpose};
use theano_mgpu::util::Pcg32;

const EPS: f32 = 1e-2;
const TOL: f32 = 1e-2;

fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// Check `analytic` against central differences of `loss` taken by
/// perturbing each element of `x` in place.
fn check_grad(tag: &str, x: &mut [f32], analytic: &[f32], mut loss: impl FnMut(&[f32]) -> f64) {
    assert_eq!(x.len(), analytic.len());
    for i in 0..x.len() {
        let orig = x[i];
        x[i] = orig + EPS;
        let lp = loss(x);
        x[i] = orig - EPS;
        let lm = loss(x);
        x[i] = orig;
        let numeric = ((lp - lm) / (2.0 * EPS as f64)) as f32;
        let e = rel_err(analytic[i], numeric);
        assert!(
            e < TOL,
            "{tag}[{i}]: analytic {} vs numeric {numeric} (rel err {e})",
            analytic[i]
        );
    }
}

#[test]
fn conv_gradients_match_finite_differences() {
    let s = Conv2dShape {
        batch: 2,
        cin: 2,
        cout: 3,
        k: 3,
        stride: 2,
        pad: 1,
        in_hw: 5,
        out_hw: 3,
        groups: 1,
    };
    let mut rng = Pcg32::seeded(11);
    let mut x = randn(&mut rng, s.batch * s.in_elems());
    let mut w = randn(&mut rng, s.w_elems());
    let mut b = randn(&mut rng, s.cout);
    // Scalar objective L = <y, r> for fixed random r, so dL/dy = r.
    let r = randn(&mut rng, s.batch * s.out_elems());

    let loss_with = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
        let mut y = vec![0.0; s.batch * s.out_elems()];
        let mut col = vec![0.0; s.col_elems()];
        conv2d_forward(x, w, b, &mut y, &mut col, &s);
        y.iter().zip(&r).map(|(a, c)| (a * c) as f64).sum()
    };

    let (mut dw, mut db) = (vec![0.0; w.len()], vec![0.0; b.len()]);
    let mut dx = vec![0.0; x.len()];
    let (mut col, mut dcol) = (vec![0.0; s.col_elems()], vec![0.0; s.col_elems()]);
    conv2d_backward(&x, &w, &r, &mut dw, &mut db, &mut dx, &mut col, &mut dcol, &s);

    let (xs, ws, bs) = (x.clone(), w.clone(), b.clone());
    check_grad("conv dx", &mut x, &dx, |x| loss_with(x, &ws, &bs));
    check_grad("conv dw", &mut w, &dw, |w| loss_with(&xs, w, &bs));
    check_grad("conv db", &mut b, &db, |b| loss_with(&xs, &ws, b));
}

#[test]
fn grouped_conv_gradients_match_finite_differences() {
    // groups = 2: weights are [cout, cin/2, k, k]; the backward must
    // route every gradient through its own group's slices only.
    let s = Conv2dShape {
        batch: 2,
        cin: 4,
        cout: 6,
        k: 3,
        stride: 2,
        pad: 1,
        in_hw: 5,
        out_hw: 3,
        groups: 2,
    };
    let mut rng = Pcg32::seeded(19);
    let mut x = randn(&mut rng, s.batch * s.in_elems());
    let mut w = randn(&mut rng, s.w_elems());
    let mut b = randn(&mut rng, s.cout);
    let r = randn(&mut rng, s.batch * s.out_elems());

    let loss_with = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
        let mut y = vec![0.0; s.batch * s.out_elems()];
        let mut col = vec![0.0; s.col_elems()];
        conv2d_forward(x, w, b, &mut y, &mut col, &s);
        y.iter().zip(&r).map(|(a, c)| (a * c) as f64).sum()
    };

    let (mut dw, mut db) = (vec![0.0; w.len()], vec![0.0; b.len()]);
    let mut dx = vec![0.0; x.len()];
    let (mut col, mut dcol) = (vec![0.0; s.col_elems()], vec![0.0; s.col_elems()]);
    conv2d_backward(&x, &w, &r, &mut dw, &mut db, &mut dx, &mut col, &mut dcol, &s);

    let (xs, ws, bs) = (x.clone(), w.clone(), b.clone());
    check_grad("gconv dx", &mut x, &dx, |x| loss_with(x, &ws, &bs));
    check_grad("gconv dw", &mut w, &dw, |w| loss_with(&xs, w, &bs));
    check_grad("gconv db", &mut b, &db, |b| loss_with(&xs, &ws, b));
}

#[test]
fn lrn_gradients_match_finite_differences() {
    // Aggressive alpha so the cross-channel correction term carries
    // real weight (with the paper's 1e-4 the check would mostly probe
    // the diagonal).
    let s = LrnShape {
        batch: 2,
        channels: 6,
        hw: 3,
        radius: 2,
        bias: 2.0,
        alpha: 0.4,
        beta: 0.75,
    };
    let mut rng = Pcg32::seeded(29);
    let mut x = randn(&mut rng, s.batch * s.elems());
    let r = randn(&mut rng, s.batch * s.elems());

    let loss = |x: &[f32]| -> f64 {
        let mut y = vec![0.0; x.len()];
        lrn_forward(x, &mut y, &s);
        y.iter().zip(&r).map(|(a, c)| (a * c) as f64).sum()
    };

    let mut y = vec![0.0; x.len()];
    lrn_forward(&x, &mut y, &s);
    let mut dx = vec![0.0; x.len()];
    lrn_backward(&x, &y, &r, &mut dx, &s);
    check_grad("lrn dx", &mut x, &dx, loss);
}

#[test]
fn lrn_forward_matches_python_reference_constants() {
    // Pinned against f64 evaluations of the exact formula of
    // python/compile/kernels/ref.py::lrn_ref (cross-channel window sum
    // with edge clipping, scale = (bias + alpha/n · Σ x²)^beta).
    //
    // Case 1: the paper's constants (radius 2, k = 2, alpha = 1e-4,
    // beta = 0.75) over 6 channels of a 2x2 plane, with values large
    // enough that the alpha term actually moves the denominator.
    let s = LrnShape {
        batch: 1,
        channels: 6,
        hw: 2,
        radius: 2,
        bias: 2.0,
        alpha: 1e-4,
        beta: 0.75,
    };
    #[rustfmt::skip]
    let x = vec![
         3.0, -11.0,   7.5,  0.25,
        -6.0,   4.0,  -2.5,  9.0,
        12.0,  -8.0,   0.0,  5.5,
        -1.5,  10.0, -13.0,  2.0,
         8.0,  -3.0,   6.0, -7.0,
         0.5,   2.5,  -9.5, 14.0,
    ];
    #[rustfmt::skip]
    let want = [
        1.781286295e0, -6.530796428e0, 4.457437421e0, 1.485269099e-1,
        -3.562512587e0, 2.373059062e0, -1.483933160e0, 5.346808530e0,
        7.121613596e0, -4.745798748e0, 0.000000000e0, 3.266295194e0,
        -8.902599747e-1, 5.937343198e0, -7.712413118e0, 1.186004121e0,
        4.749332423e0, -1.781416317e0, 3.559741648e0, -4.153528888e0,
        2.971535857e-1, 1.485225287e0, -5.636257609e0, 8.308937689e0,
    ];
    let mut y = vec![0.0f32; x.len()];
    lrn_forward(&x, &mut y, &s);
    for (i, (got, w)) in y.iter().zip(&want).enumerate() {
        let e = rel_err(*got, *w as f32);
        assert!(e < 1e-5, "case1[{i}]: {got} vs {w} (rel err {e})");
    }

    // Case 2: radius 1 with a window-dominated denominator
    // (bias = 1, alpha = 0.9), 3 channels of a 1x1 plane.
    let s2 = LrnShape {
        batch: 1,
        channels: 3,
        hw: 1,
        radius: 1,
        bias: 1.0,
        alpha: 0.9,
        beta: 0.75,
    };
    let x2 = vec![1.0f32, -2.0, 3.0];
    let want2 = [5.029733719e-1, -5.808011772e-1, 9.109073255e-1];
    let mut y2 = vec![0.0f32; 3];
    lrn_forward(&x2, &mut y2, &s2);
    for (i, (got, w)) in y2.iter().zip(&want2).enumerate() {
        let e = rel_err(*got, *w as f32);
        assert!(e < 1e-5, "case2[{i}]: {got} vs {w} (rel err {e})");
    }
}

#[test]
fn fc_gradients_match_finite_differences() {
    let s = FcShape { batch: 3, din: 5, dout: 4 };
    let mut rng = Pcg32::seeded(13);
    let mut x = randn(&mut rng, s.batch * s.din);
    let mut w = randn(&mut rng, s.dout * s.din);
    let mut b = randn(&mut rng, s.dout);
    let r = randn(&mut rng, s.batch * s.dout);

    let loss_with = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
        let mut y = vec![0.0; s.batch * s.dout];
        fc_forward(x, w, b, &mut y, &s);
        y.iter().zip(&r).map(|(a, c)| (a * c) as f64).sum()
    };

    let (mut dw, mut db) = (vec![0.0; w.len()], vec![0.0; b.len()]);
    let mut dx = vec![0.0; x.len()];
    fc_backward(&x, &w, &r, &mut dw, &mut db, &mut dx, &s);

    let (xs, ws, bs) = (x.clone(), w.clone(), b.clone());
    check_grad("fc dx", &mut x, &dx, |x| loss_with(x, &ws, &bs));
    check_grad("fc dw", &mut w, &dw, |w| loss_with(&xs, w, &bs));
    check_grad("fc db", &mut b, &db, |b| loss_with(&xs, &ws, b));
}

#[test]
fn softmax_xent_gradient_matches_finite_differences() {
    let s = FcShape { batch: 4, din: 0, dout: 6 };
    let mut rng = Pcg32::seeded(17);
    let mut logits = randn(&mut rng, s.batch * s.dout);
    let labels: Vec<i32> = (0..s.batch).map(|_| rng.below(s.dout as u32) as i32).collect();

    let mut probs = vec![0.0; logits.len()];
    let mut dlogits = vec![0.0; logits.len()];
    softmax_xent(&logits, &labels, &mut probs, &mut dlogits, &s);

    let labels2 = labels.clone();
    check_grad("softmax dlogits", &mut logits, &dlogits, |l| {
        let mut p = vec![0.0; l.len()];
        let mut d = vec![0.0; l.len()];
        softmax_xent(l, &labels2, &mut p, &mut d, &s).0 as f64
    });
}

/// The packed GEMM kernels against an f64-accumulated naive product
/// *and* the pre-packing scalar kernels, at sizes shaped like the
/// layers the gradchecks probe.  `rel_err` floors its denominator, so
/// near-zero sums compare absolutely — no fragile absolute epsilons.
#[test]
fn packed_gemm_matches_f64_reference() {
    let mut rng = Pcg32::seeded(23);
    for (m, k, n) in [(3, 18, 9), (4, 130, 6), (7, 29, 31)] {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for t in 0..k {
                    acc += (a[i * k + t] as f64) * (b[t * n + j] as f64);
                }
                want[i * n + j] = acc as f32;
            }
        }
        let at = transpose(m, k, &a);
        let bt = transpose(k, n, &b);
        let mut nn = vec![0.0; m * n];
        matmul_nn(m, k, n, &a, &b, &mut nn);
        let mut nt = vec![0.0; m * n];
        matmul_nt(m, k, n, &a, &bt, &mut nt);
        let mut tn = vec![0.0; m * n];
        matmul_tn(m, k, n, &at, &b, &mut tn);
        let mut old = vec![0.0; m * n];
        scalar::matmul_nn(m, k, n, &a, &b, &mut old);
        for (tag, got) in [("nn", &nn), ("nt", &nt), ("tn", &tn), ("scalar", &old)] {
            for (i, (x, y)) in got.iter().zip(&want).enumerate() {
                let e = rel_err(*x, *y);
                assert!(e < 1e-4, "{tag} {m}x{k}x{n} [{i}]: {x} vs {y} (rel err {e})");
            }
        }
    }
}

#[test]
fn param_shapes_reconcile_across_all_three_layers_of_truth() {
    // ArchDesc::param_elements (analytic) == ModelSpec manifest
    // (derived) == ParamStore::total_elements (materialized), for every
    // arch in the family.
    for arch in [alexnet_micro(), alexnet_tiny(), alexnet_tiny_faithful(), alexnet()] {
        let spec = model_spec_of(&arch);
        assert_eq!(
            spec.total_param_elements() as u64,
            arch.param_elements(),
            "{}: ModelSpec disagrees with ArchDesc",
            arch.name
        );
        // Materializing full AlexNet means two 244 MB allocations of
        // N(0, σ²) draws — keep the store check to the CPU-scale archs.
        if arch.param_elements() < 1_000_000 {
            let store = ParamStore::init(&spec.params, 1);
            assert_eq!(
                store.total_elements() as u64,
                arch.param_elements(),
                "{}: ParamStore disagrees with ArchDesc",
                arch.name
            );
            assert_eq!(store.n_tensors(), spec.params.len());
        }
    }
}

#[test]
fn faithful_alexnet_param_count_is_canonical_three_ways() {
    // The grouped/LRN AlexNet must land exactly on the canonical
    // 60,965,224 parameters of Krizhevsky 2012 — analytically, in the
    // derived manifest, and in a materialized store.  (This is the one
    // test that pays for the two ~244 MB fc weight allocations.)
    let arch = alexnet();
    assert_eq!(arch.param_elements(), 60_965_224);
    let spec = model_spec_of(&arch);
    assert_eq!(spec.total_param_elements() as u64, 60_965_224);
    let store = ParamStore::init(&spec.params, 1);
    assert_eq!(store.total_elements() as u64, 60_965_224);
    assert_eq!(store.n_tensors(), spec.params.len());
}

#[test]
fn derived_specs_have_sane_init_recipes() {
    let spec = model_spec_of(&alexnet_micro());
    for p in &spec.params {
        if p.name.ends_with(".w") {
            assert_eq!(p.init, "normal", "{}", p.name);
            assert!(p.std > 0.0 && p.std < 1.0, "{}: std {}", p.name, p.std);
        } else {
            assert_eq!(p.init, "zeros", "{}", p.name);
        }
    }
    // He init: conv1 fan-in is 3·5² = 75.
    assert!((spec.params[0].std - (2.0f32 / 75.0).sqrt()).abs() < 1e-6);
}
