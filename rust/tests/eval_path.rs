//! Eval-path regression tests for the PR-8 bugfixes:
//!
//! - an empty/absent val split is a distinguishable "no data" outcome
//!   (`Ok(None)`), not an `EvalResult::default()` masquerading as 100%
//!   error;
//! - the hoisted staging buffer in `Engine` produces *exactly* the
//!   numbers the old fresh-`HostTensor::zeros`-per-batch loop produced,
//!   including on the ragged final batch.

use std::path::PathBuf;

use theano_mgpu::backend::build_eval_backend;
use theano_mgpu::config::{DataConfig, TrainConfig};
use theano_mgpu::coordinator::eval::{evaluate, EvalResult};
use theano_mgpu::data::loader::{open_split, open_split_optional};
use theano_mgpu::data::preprocess::{preprocess_into, Augment};
use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::params::ParamStore;
use theano_mgpu::tensor::{HostTensor, Shape};

/// Generate a corpus with `val` validation examples (0 = no val split
/// at all — `gen-data --val 0` writes no val shard files).
fn corpus(tag: &str, val: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_evalpath_{tag}_{}", std::process::id()));
    if !dir.join("meta.json").exists() {
        let spec = SynthSpec { classes: 10, hw: 36, seed: 5, ..Default::default() };
        generate_dataset(&dir, &spec, 64, val, 64).unwrap();
    }
    dir
}

fn eval_cfg(tag: &str, val: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    cfg.compute_threads = 1;
    cfg.batch_per_worker = 8;
    cfg.data = DataConfig {
        dir: corpus(tag, val),
        train_examples: 64,
        val_examples: val,
        shard_examples: 64,
        seed: 5,
        stored_hw: 36,
    };
    cfg
}

#[test]
fn absent_val_split_is_none_not_full_error() {
    let cfg = eval_cfg("noval", 0);
    // The split truly is absent on disk...
    assert!(open_split_optional(&cfg.data.dir, "val", 32, false).unwrap().is_none());
    // ...while the train split opens fine through the same probe.
    assert!(open_split_optional(&cfg.data.dir, "train", 32, false).unwrap().is_some());
    // And evaluate() reports "nothing to measure" instead of the old
    // EvalResult::default() (whose top1_error() read as 100%).
    let mut backend = build_eval_backend(&cfg).unwrap();
    let store = ParamStore::init(&backend.model().params, 1);
    let r = evaluate(&cfg, backend.as_mut(), &store, 0).unwrap();
    assert!(r.is_none());
    // Real errors still surface as errors, not None: a corpus dir that
    // does not exist is not "no data".
    let mut bad = cfg.clone();
    bad.data.dir = PathBuf::from("/nonexistent/tmg_corpus");
    assert!(evaluate(&bad, backend.as_mut(), &store, 0).is_err());
}

/// The old eval loop, verbatim: a fresh zeroed tensor every batch.
/// Kept here as the reference the hoisted-buffer path must match.
fn evaluate_fresh_alloc(cfg: &TrainConfig, store: &ParamStore) -> EvalResult {
    let mut backend = build_eval_backend(cfg).unwrap();
    let batch = cfg.batch_per_worker.max(1);
    let crop_hw = backend.model().image_hw;
    let (mut dataset, mean) = open_split(&cfg.data.dir, "val", crop_hw, false).unwrap();
    let stored_hw = dataset.height;
    let channels = dataset.channels;
    let total = dataset.len();
    let mut out = EvalResult::default();
    let mut loss_sum = 0f64;
    let mut pix_buf: Vec<u8> = Vec::new();
    let stride = channels * crop_hw * crop_hw;
    let mut start = 0usize;
    while start < total {
        let n = (total - start).min(batch);
        let mut images = HostTensor::zeros(Shape::of(&[n, channels, crop_hw, crop_hw]));
        let mut labels = Vec::with_capacity(n);
        let slice = images.as_mut_slice();
        for bi in 0..n {
            let label = dataset.read_into(start + bi, &mut pix_buf).unwrap();
            preprocess_into(
                &pix_buf,
                &mean,
                stored_hw,
                crop_hw,
                Augment::center(stored_hw, crop_hw),
                &mut slice[bi * stride..(bi + 1) * stride],
            )
            .unwrap();
            labels.push(label as i32);
        }
        let r = backend.eval_batch(&images, &labels, store).unwrap();
        loss_sum += r.loss as f64 * n as f64;
        out.top1_correct += r.top1 as usize;
        out.top5_correct += r.top5 as usize;
        out.examples += n;
        start += n;
    }
    out.mean_loss = (loss_sum / out.examples as f64) as f32;
    out
}

#[test]
fn hoisted_buffer_matches_fresh_alloc_including_ragged_tail() {
    // 20 examples at batch 8: two full batches + a ragged 4 — the
    // reused buffer must shrink-to-fit logically (begin(n)) and still
    // produce identical numbers.
    let cfg = eval_cfg("reuse", 20);
    let mut backend = build_eval_backend(&cfg).unwrap();
    let store = ParamStore::init(&backend.model().params, 3);
    let reused = evaluate(&cfg, backend.as_mut(), &store, 0).unwrap().expect("val present");
    assert_eq!(reused.examples, 20, "ragged tail must be evaluated");
    let fresh = evaluate_fresh_alloc(&cfg, &store);
    // Exact equality — same counts AND bit-equal mean loss.
    assert_eq!(reused, fresh);
    assert_eq!(reused.mean_loss.to_bits(), fresh.mean_loss.to_bits());
    // max_batches semantics unchanged: cap at 1 batch of 8.
    let capped = evaluate(&cfg, backend.as_mut(), &store, 1).unwrap().unwrap();
    assert_eq!(capped.examples, 8);
}
