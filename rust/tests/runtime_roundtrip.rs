//! Integration: AOT HLO artifacts load, compile and execute correctly
//! through the PJRT runtime (the L2 <-> L3 contract).
//!
//! Requires `make artifacts`; tests skip (with a notice) if absent.

use std::path::Path;

use theano_mgpu::params::ParamStore;
use theano_mgpu::runtime::literal_bridge::*;
use theano_mgpu::runtime::{Manifest, RuntimeClient};
use theano_mgpu::tensor::{HostTensor, Shape};
use theano_mgpu::util::Pcg32;

fn manifest() -> Option<Manifest> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

fn run_one_step(
    m: &Manifest,
    artifact: &str,
    seed: u64,
) -> (f32, i32, ParamStore) {
    let spec = m.artifact(artifact).unwrap();
    let model = m.model(&spec.model).unwrap();
    let client = RuntimeClient::cpu().unwrap();
    let exe = client.load_step(spec).unwrap();

    let b = spec.batch_size;
    let hw = model.image_hw;
    let mut rng = Pcg32::seeded(seed);
    let mut images = HostTensor::zeros(Shape::of(&[b, model.in_channels, hw, hw]));
    rng.fill_normal(images.as_mut_slice(), 1.0);
    let labels: Vec<i32> = (0..b).map(|_| rng.below(model.num_classes as u32) as i32).collect();
    let mut store = ParamStore::init(&model.params, seed);

    let mut inputs = Vec::new();
    inputs.push(tensor_to_literal(&images).unwrap());
    inputs.push(i32_to_literal(&labels).unwrap());
    inputs.push(f32_scalar(0.01));
    inputs.push(i32_scalar(0));
    for p in &store.params {
        inputs.push(tensor_to_literal(p).unwrap());
    }
    for mm in &store.momenta {
        inputs.push(tensor_to_literal(mm).unwrap());
    }
    let outs = exe.run(&inputs).unwrap();
    let loss = literal_f32(&outs[0]).unwrap();
    let correct1 = literal_i32(&outs[1]).unwrap();
    let n = store.n_tensors();
    let new_p: Vec<HostTensor> = outs[2..2 + n]
        .iter()
        .zip(&store.specs)
        .map(|(l, s)| literal_to_tensor(l, s.shape.clone()).unwrap())
        .collect();
    let new_m: Vec<HostTensor> = outs[2 + n..]
        .iter()
        .zip(&store.specs)
        .map(|(l, s)| literal_to_tensor(l, s.shape.clone()).unwrap())
        .collect();
    store.update_from(new_p, new_m).unwrap();
    (loss, correct1, store)
}

#[test]
fn micro_refconv_step_executes() {
    let Some(m) = manifest() else { return };
    let (loss, correct1, store) = run_one_step(&m, "train_alexnet-micro_refconv_b8", 3);
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!((0..=8).contains(&correct1));
    // Momentum must be nonzero after one update.
    let mnorm: f32 = store.momenta.iter().map(|t| t.as_slice().iter().map(|v| v.abs()).sum::<f32>()).sum();
    assert!(mnorm > 0.0);
}

#[test]
fn pallas_backends_agree_with_refconv() {
    let Some(m) = manifest() else { return };
    let (loss_ref, _, store_ref) = run_one_step(&m, "train_alexnet-micro_refconv_b8", 7);
    for backend in ["convnet", "cudnn_r1", "cudnn_r2"] {
        let name = format!("train_alexnet-micro_{backend}_b8");
        let (loss, _, store) = run_one_step(&m, &name, 7);
        assert!(
            (loss - loss_ref).abs() < 1e-3 * loss_ref.abs().max(1.0),
            "{backend}: loss {loss} vs refconv {loss_ref}"
        );
        let div = store.max_divergence(&store_ref);
        assert!(div < 5e-3, "{backend}: param divergence {div}");
    }
}

#[test]
fn step_is_deterministic() {
    let Some(m) = manifest() else { return };
    let (l1, c1, s1) = run_one_step(&m, "train_alexnet-micro_cudnn_r2_b8", 11);
    let (l2, c2, s2) = run_one_step(&m, "train_alexnet-micro_cudnn_r2_b8", 11);
    assert_eq!(l1, l2);
    assert_eq!(c1, c2);
    assert_eq!(s1.max_divergence(&s2), 0.0);
}

#[test]
fn eval_artifact_counts_consistent() {
    let Some(m) = manifest() else { return };
    let spec = m.artifact("eval_alexnet-micro_refconv_b8").unwrap();
    let model = m.model(&spec.model).unwrap();
    let client = RuntimeClient::cpu().unwrap();
    let exe = client.load_step(spec).unwrap();
    let b = spec.batch_size;
    let hw = model.image_hw;
    let mut rng = Pcg32::seeded(5);
    let mut images = HostTensor::zeros(Shape::of(&[b, model.in_channels, hw, hw]));
    rng.fill_normal(images.as_mut_slice(), 1.0);
    let labels: Vec<i32> = (0..b).map(|i| (i % model.num_classes) as i32).collect();
    let store = ParamStore::init(&model.params, 5);
    let mut inputs = vec![
        tensor_to_literal(&images).unwrap(),
        i32_to_literal(&labels).unwrap(),
    ];
    for p in &store.params {
        inputs.push(tensor_to_literal(p).unwrap());
    }
    let outs = exe.run(&inputs).unwrap();
    let c1 = literal_i32(&outs[1]).unwrap();
    let c5 = literal_i32(&outs[2]).unwrap();
    assert!(0 <= c1 && c1 <= c5 && c5 <= b as i32, "c1 {c1} c5 {c5}");
}

#[test]
fn wrong_input_count_is_rejected() {
    let Some(m) = manifest() else { return };
    let spec = m.artifact("train_alexnet-micro_refconv_b8").unwrap();
    let client = RuntimeClient::cpu().unwrap();
    let exe = client.load_step(spec).unwrap();
    let err = match exe.run(&[f32_scalar(1.0)]) {
        Err(e) => e,
        Ok(_) => panic!("under-supplied inputs must be rejected"),
    };
    assert!(format!("{err}").contains("inputs"));
}
