//! End-to-end multi-process distributed training over loopback TCP,
//! with real OS processes and real `kill -9` fault injection.
//!
//! Two invariants from the paper-reproduction contract:
//!
//! 1. A 2-process TCP ring produces **bit-identical** parameters to
//!    the same-config in-memory (threaded) run — the transport is
//!    outside the numerics.
//! 2. SIGKILLing one rank mid-run and restarting every rank with
//!    `--resume auto` (shared checkpoint dir) reassembles the run
//!    bit-exactly: the final checkpoint equals the uninterrupted
//!    baseline's, byte for byte.
//!
//! Both tests drive the actual `tmg` binary (`CARGO_BIN_EXE_tmg`), so
//! the rendezvous, handshake, deadline and supervisor paths are the
//! shipped ones, not test doubles.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use theano_mgpu::data::synth::{generate_dataset, SynthSpec};
use theano_mgpu::params::{load_checkpoint, ParamStore};

const TRAIN: usize = 256;
const VAL: usize = 32;

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tmg_dist_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One shared corpus per test: generated up front so two spawned ranks
/// never race on first-use generation.
fn fresh_corpus(tag: &str) -> PathBuf {
    let dir = fresh_dir(&format!("{tag}_data"));
    let spec = SynthSpec { classes: 10, hw: 36, seed: 13, ..Default::default() };
    generate_dataset(&dir, &spec, TRAIN, VAL, 128).unwrap();
    dir
}

/// Reserve `n` distinct free loopback ports (bind, record, release).
fn free_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect()
}

/// The flag set shared by every run in a test — everything
/// resume-critical is pinned explicitly so the in-memory baseline and
/// the distributed ranks train the same function.
fn common_args(data: &Path, ckpt: &Path, steps: usize, every: usize) -> Vec<String> {
    [
        "train",
        "--model",
        "alexnet-micro",
        "--backend",
        "native",
        "--batch",
        "8",
        "--threads",
        "1",
        "--seed",
        "11",
        "--checkpoint-keep",
        "16",
    ]
    .into_iter()
    .map(String::from)
    .chain([
        "--steps".into(),
        steps.to_string(),
        "--checkpoint-every".into(),
        every.to_string(),
        "--data-dir".into(),
        data.display().to_string(),
        "--checkpoint-dir".into(),
        ckpt.display().to_string(),
    ])
    .collect()
}

fn spawn_rank(common: &[String], rank: usize, peers: &str, resume: bool) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_tmg"));
    cmd.args(common)
        .args(["--rank", &rank.to_string(), "--peers", peers])
        .args(["--connect-timeout-ms", "60000", "--io-timeout-ms", "8000"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    if resume {
        cmd.args(["--resume", "auto"]);
    }
    cmd.spawn().expect("spawn tmg rank")
}

/// Wait for a child, asserting success and returning its stdout.
fn finish_ok(child: Child, who: &str) -> String {
    let out = child.wait_with_output().expect("wait tmg");
    assert!(
        out.status.success(),
        "{who} failed ({:?})\n--- stdout ---\n{}\n--- stderr ---\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Max absolute parameter difference between two checkpoint files,
/// loaded through the same path training uses.
fn checkpoint_divergence(a: &Path, b: &Path) -> f32 {
    let mut cfg = theano_mgpu::config::TrainConfig::default();
    cfg.model = "alexnet-micro".into();
    cfg.backend = "native".into();
    let model = theano_mgpu::backend::resolve_model(&cfg).unwrap();
    let mut sa = ParamStore::init(&model.params, 1);
    let mut sb = ParamStore::init(&model.params, 2);
    load_checkpoint(a, &mut sa).unwrap();
    load_checkpoint(b, &mut sb).unwrap();
    sa.max_divergence(&sb)
}

#[test]
fn tcp_two_process_run_is_bit_identical_to_in_memory() {
    let data = fresh_corpus("bitident");
    let mem_ckpt = fresh_dir("bitident_mem");
    let tcp_ckpt = fresh_dir("bitident_tcp");
    let steps = 4;

    // Baseline: the ordinary in-memory 2-worker run (threads in one
    // process, channel transports).
    let mut base = Command::new(env!("CARGO_BIN_EXE_tmg"));
    base.args(common_args(&data, &mem_ckpt, steps, 0))
        .args(["--workers", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    finish_ok(base.spawn().expect("spawn baseline"), "in-memory baseline");

    // The same run as two OS processes over loopback TCP.
    let peers = free_addrs(2).join(",");
    let common = common_args(&data, &tcp_ckpt, steps, 0);
    let r0 = spawn_rank(&common, 0, &peers, false);
    let r1 = spawn_rank(&common, 1, &peers, false);
    finish_ok(r1, "tcp rank 1");
    finish_ok(r0, "tcp rank 0");

    // Same function, same bits: the final checkpoints must be
    // byte-identical, and the loaded parameters exactly equal.
    let mem_final = mem_ckpt.join(format!("default_step{steps}.ckpt"));
    let tcp_final = tcp_ckpt.join(format!("default_step{steps}.ckpt"));
    let mem_bytes = std::fs::read(&mem_final).unwrap();
    let tcp_bytes = std::fs::read(&tcp_final).unwrap();
    assert_eq!(
        mem_bytes, tcp_bytes,
        "TCP run's final checkpoint differs from the in-memory run's"
    );
    assert_eq!(checkpoint_divergence(&mem_final, &tcp_final), 0.0);
}

#[test]
fn kill_nine_then_resume_auto_reassembles_bit_exactly() {
    let data = fresh_corpus("kill9");
    let base_ckpt = fresh_dir("kill9_base");
    let dist_ckpt = fresh_dir("kill9_dist");
    // Kill at the step-2 checkpoint with 8 more steps to go: rank 1
    // cannot race to completion before the SIGKILL lands.
    let (steps, every) = (10, 2);

    // Uninterrupted baseline (in-memory, same config).
    let mut base = Command::new(env!("CARGO_BIN_EXE_tmg"));
    base.args(common_args(&data, &base_ckpt, steps, every))
        .args(["--workers", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    finish_ok(base.spawn().expect("spawn baseline"), "uninterrupted baseline");

    // Launch the 2-rank TCP run, then SIGKILL rank 1 as soon as both
    // ranks have a step-2 checkpoint on disk (a complete resume set).
    let peers = free_addrs(2).join(",");
    let common = common_args(&data, &dist_ckpt, steps, every);
    let r0 = spawn_rank(&common, 0, &peers, false);
    let mut r1 = spawn_rank(&common, 1, &peers, false);

    let set = [dist_ckpt.join("default_step2.w0.ckpt"), dist_ckpt.join("default_step2.w1.ckpt")];
    let deadline = Instant::now() + Duration::from_secs(180);
    while !set.iter().all(|p| p.exists()) {
        assert!(Instant::now() < deadline, "step-2 checkpoint set never appeared");
        assert!(
            r1.try_wait().expect("poll rank 1").is_none(),
            "rank 1 exited before it could be killed"
        );
        std::thread::sleep(Duration::from_millis(3));
    }
    r1.kill().expect("SIGKILL rank 1"); // Child::kill is SIGKILL on unix
    let _ = r1.wait();

    // The survivor must notice the dead peer (deadline or EOF in the
    // collective error path) and exit non-zero — not hang.
    let out0 = r0.wait_with_output().expect("wait rank 0");
    assert!(
        !out0.status.success(),
        "rank 0 should have failed after its peer was SIGKILLed\n--- stdout ---\n{}",
        String::from_utf8_lossy(&out0.stdout)
    );

    // Supervised recovery: restart every rank with --resume auto on
    // fresh ports (the old ones may sit in TIME_WAIT).  Both ranks
    // must resolve the same newest *complete* checkpoint set.
    let peers = free_addrs(2).join(",");
    let r0 = spawn_rank(&common, 0, &peers, true);
    let r1 = spawn_rank(&common, 1, &peers, true);
    let out1 = finish_ok(r1, "resumed rank 1");
    let out0 = finish_ok(r0, "resumed rank 0");
    assert!(
        out0.contains("resumed from checkpoint at step"),
        "rank 0 did not resume from a checkpoint:\n{out0}"
    );
    assert!(
        out1.contains("resumed from checkpoint at step"),
        "rank 1 did not resume from a checkpoint:\n{out1}"
    );

    // Bit-exact reassembly: final checkpoint identical to the
    // uninterrupted run's, max parameter divergence exactly 0.0.
    let base_final = base_ckpt.join(format!("default_step{steps}.ckpt"));
    let dist_final = dist_ckpt.join(format!("default_step{steps}.ckpt"));
    assert_eq!(
        std::fs::read(&base_final).unwrap(),
        std::fs::read(&dist_final).unwrap(),
        "kill-9 + --resume auto did not reassemble the baseline bits"
    );
    assert_eq!(checkpoint_divergence(&base_final, &dist_final), 0.0);
}
